"""Regression gate for serve-engine benchmarks.

Compares a freshly produced BENCH_serve_engine.json against the committed
baseline and fails (exit 1) when any matched **relative** metric drops by
more than ``--max-drop`` (default 20%). The gated metrics are same-run
ratios — engine-vs-lockstep speedup, paged-vs-contiguous and warm-vs-cold
prefix-cache concurrency, the chunked-vs-per-request prefill speedup, and
the prefix-cache warm-over-cold speedup — because absolute tokens/s is a
property of the runner (a CI machine differs from the baseline's machine by
far more than any real regression), while each row's ratio divides out the
hardware: a >20% ratio drop means the engine lost ground against its own
baseline measured in the same process. Absolute tok/s keys are printed for
context but never gate. Rows are matched on their identifying keys (cell,
backend, bound); cells present in only one file are reported but not fatal,
so adding a cell never breaks the gate.

Usage (the scheduled CI job):
    git show HEAD:BENCH_serve_engine.json > /tmp/baseline.json
    python -m benchmarks.run serve_engine_bench
    python benchmarks/compare.py BENCH_serve_engine.json /tmp/baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys

# same-run ratios: machine-invariant, gate-worthy
GATED_KEYS = ("speedup", "speedup_vs_per_batch", "concurrency_ratio",
              "guarded_frac")
# absolute throughputs: printed for context only
INFO_KEYS = ("engine_tok_per_s", "paged_tok_per_s", "chunked_tok_per_s",
             "guarded_tok_per_s", "warm_tok_per_s")


def row_key(row: dict) -> tuple:
    return (row.get("cell", "engine_vs_lockstep"), row.get("backend", ""),
            row.get("bound", False))


# Baseline values below this are unusable as a ratio denominator: a relative
# metric (speedup, concurrency ratio) is O(1) by construction, so a ~0 means
# the baseline row is degenerate (empty run, placeholder), not a real number.
EPS = 1e-9


def _numeric(val) -> bool:
    return isinstance(val, (int, float)) and not isinstance(val, bool)


def compare(new: dict, base: dict, max_drop: float) -> int:
    base_rows = {row_key(r): r for r in base.get("results", [])}
    failures = []
    skips = 0
    for row in new.get("results", []):
        ref = base_rows.get(row_key(row))
        if ref is None:
            print(f"new cell (no baseline): {row_key(row)}")
            continue
        for key in INFO_KEYS:
            if key in row and key in ref and _numeric(row[key]) \
                    and _numeric(ref[key]) and abs(ref[key]) > EPS:
                print(f"info {row_key(row)} {key}: {ref[key]} -> {row[key]} "
                      f"({row[key] / ref[key]:.2f}x, not gated)")
        for key in GATED_KEYS:
            in_new, in_ref = key in row, key in ref
            if not in_new and not in_ref:
                continue                       # cell doesn't carry this metric
            if not in_ref:
                # older baseline predates this metric — report, don't gate
                print(f"skip {row_key(row)} {key}: missing from baseline "
                      f"(new={row[key]!r})")
                skips += 1
                continue
            if not in_new:
                # the metric vanished from the new run: loud, but non-fatal
                # (renamed/retired metrics shouldn't brick the gate)
                print(f"WARN {row_key(row)} {key}: in baseline "
                      f"({ref[key]!r}) but missing from new run")
                skips += 1
                continue
            if not _numeric(ref[key]) or not _numeric(row[key]) \
                    or abs(ref[key]) <= EPS:
                print(f"skip {row_key(row)} {key}: unusable baseline value "
                      f"{ref[key]!r} (new={row[key]!r})")
                skips += 1
                continue
            ratio = row[key] / ref[key]
            status = "FAIL" if ratio < 1.0 - max_drop else "ok"
            print(f"{status} {row_key(row)} {key}: {ref[key]} -> {row[key]} "
                  f"({ratio:.2f}x)")
            if ratio < 1.0 - max_drop:
                failures.append((row_key(row), key, ratio))
    if failures:
        print(f"\n{len(failures)} relative metric(s) dropped more than "
              f"{max_drop:.0%} vs the committed baseline")
        return 1
    tail = f" ({skips} skipped, see above)" if skips else ""
    print(f"\nall matched relative metrics within tolerance{tail}")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="freshly produced bench json")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument("--max-drop", type=float, default=0.2,
                    help="fatal fractional throughput drop (default 0.2)")
    args = ap.parse_args()
    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    sys.exit(compare(new, base, args.max_drop))


if __name__ == "__main__":
    main()
