"""Render the roofline table (markdown) from experiments/dryrun.jsonl.

Run:  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
import os


def load(path: str):
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def fmt_table(recs, mesh: str) -> str:
    rows = []
    head = ("| arch | shape | kind | t_comp (s) | t_mem (s) | t_coll (s) | "
            "dominant | mem/dev (GiB) | MODEL/HLO flops | roofline | note |")
    sep = "|" + "---|" * 11
    rows.append(head)
    rows.append(sep)
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — "
                        f"| — | — | SKIP: {r['reason']} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['kind']} "
                        f"| — | — | — | — | — | — | — | FAIL {r['error'][:60]} |")
            continue
        a = r["analytic"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {a['t_compute_s']:.4f} | {a['t_memory_s']:.4f} "
            f"| {a['t_collective_s']:.4f} | {a['dominant']} "
            f"| {r['bytes_per_device']/2**30:.2f} "
            f"| {a['useful_flops_frac']:.2f} | {a['roofline_frac']:.1%} "
            f"| n_micro={r.get('n_micro','—')} coll_ops={r['collectives']['count']} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=os.path.join(
        os.path.dirname(__file__), "..", "experiments", "dryrun.jsonl"))
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(args.path)
    print(fmt_table(recs, args.mesh))


if __name__ == "__main__":
    main()
