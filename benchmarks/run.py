"""Benchmark harness — one function per paper table. Prints
``name,us_per_call,derived`` CSV rows (derived = the table's headline metric).

Run:  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _timeit(fn, *args, reps: int = 3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / reps * 1e6, out


def table1_cells():
    """Table I: approximate cell truth tables, error rate, error probability."""
    from repro.core import pe
    us, cases = _timeit(pe.error_cases, pe.approx_ppc, nppc=False)
    num, den = pe.cell_error_probability(pe.approx_ppc, nppc=False)
    print(f"table1_ppc_error_rate,{us:.1f},{len(cases)}/16")
    print(f"table1_ppc_error_prob,{us:.1f},{num}/{den}")
    num_n, _ = pe.cell_error_probability(pe.approx_nppc, nppc=True)
    print(f"table1_nppc_error_prob,{us:.1f},{num_n}/{den}")


def table2_cells():
    """Table II: cell-level PDP + the paper's savings claims."""
    from repro.core import energy
    us, claims = _timeit(energy.cell_energy_claims)
    for k, v in claims.items():
        print(f"table2_{k},{us:.1f},{v:.3f}")


def table3_pe():
    """Table III: PE-level energy/PADP savings."""
    from repro.core import energy
    us, claims = _timeit(energy.pe_energy_claims)
    for k, v in claims.items():
        print(f"table3_{k},{us:.1f},{v:.3f}")


def table4_sa(fast: bool = False):
    """Table IV: SA-level PDP across sizes + GEMM energy extrapolation."""
    from repro.core import energy
    us, claims = _timeit(energy.sa_energy_claims)
    for k, v in claims.items():
        print(f"table4_{k},{us:.1f},{v:.3f}")
    for sa in (8, 16):
        e_ex = energy.gemm_energy_estimate(256, 256, 256, design="exact_ref6",
                                           sa_dim=sa)
        e_ap = energy.gemm_energy_estimate(256, 256, 256,
                                           design="proposed_approx", sa_dim=sa)
        print(f"table4_gemm256_sa{sa}_saving,0.0,"
              f"{1 - e_ap['energy_nJ'] / e_ex['energy_nJ']:.3f}")


def table5_errors(fast: bool = False):
    """Table V: NMED/MRED of the 8-bit PE vs k (ours vs paper)."""
    from repro.core import errors
    paper_signed = {2: (0.0001, 0.0037), 4: (0.0004, 0.0130),
                    6: (0.0022, 0.0481), 8: (0.0081, 0.2418)}
    ks = (2, 6) if fast else (2, 4, 6, 8)
    for k in ks:
        us, m = _timeit(errors.pe_error_metrics, 8, k, True, reps=1)
        pn, pm = paper_signed[k]
        print(f"table5_signed_k{k}_nmed,{us:.0f},{m['NMED']:.5f} (paper {pn})")
        print(f"table5_signed_k{k}_mred,{us:.0f},{m['MRED']:.5f} (paper {pm})")


def table6_apps(fast: bool = False):
    """Table VI: DCT / edge / BDCN application quality."""
    from repro.apps import bdcn, dct, edge
    size = 64 if fast else 128
    ks = (2, 8) if fast else (2, 4, 6, 8)
    us, res = _timeit(dct.run, size, ks, reps=1)
    for k, v in res.items():
        print(f"table6_dct_k{k},{us:.0f},psnr={v['psnr']:.2f}dB ssim={v['ssim']:.3f}")
    us, res = _timeit(edge.run, size, ks, reps=1)
    for k, v in res.items():
        print(f"table6_edge_k{k},{us:.0f},psnr={v['psnr']:.2f}dB ssim={v['ssim']:.3f}")
    us, res = _timeit(bdcn.run, 48 if fast else 64, ks, reps=1)
    for k, v in res.items():
        print(f"table6_bdcn_k{k},{us:.0f},psnr={v['psnr']:.2f}dB ssim={v['ssim']:.3f}")


def fig9_fig10_pareto(fast: bool = False):
    """Figs. 9/10: PDP vs NMED/MRED trade-off of the signed 8-bit PE vs k.
    PDP from the energy model (approx cells in the low-k columns, exact above),
    error from the exhaustive sweep."""
    from repro.core import energy, errors
    from repro.core.emulate import nppc_count, ppc_count
    ks = (2, 6) if fast else (2, 4, 5, 6, 8)
    n = 8
    exact_pdp = energy.pe_energy_from_cells("proposed_exact", n)
    for k in ks:
        frac = min(1.0, k / (2 * n - 1))     # fraction of columns approximated
        pdp = ((1 - frac) * energy.pe_energy_from_cells("proposed_exact", n)
               + frac * energy.pe_energy_from_cells("proposed_approx", n))
        m = errors.pe_error_metrics(n, k, signed=True)
        print(f"fig9_k{k},0.0,pdp={pdp:.0f}aJ({pdp/exact_pdp:.2f}x) "
              f"nmed={m['NMED']:.5f} mred={m['MRED']:.5f}")


def latency_wavefront():
    """Latency formula 3N-2 [11] from the cycle-accurate SA model."""
    from repro.core import systolic
    rng = np.random.default_rng(0)
    for n in (3, 4, 8):
        a = rng.integers(-8, 8, (n, n))
        b = rng.integers(-8, 8, (n, n))
        us, (out, cycles) = _timeit(systolic.simulate, a, b, reps=1)
        ok = np.array_equal(out, a @ b)
        print(f"latency_sa{n},{us:.0f},{cycles}cyc(3N-2={3*n-2}) exact={ok}")


def kernels_bench(fast: bool = False):
    """Pallas kernels (interpret mode on CPU): exact vs approx vs onehot."""
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.core import lut
    rng = np.random.default_rng(0)
    m = 128 if fast else 256
    a = jnp.asarray(rng.integers(-128, 128, (m, m)), jnp.int32)
    b = jnp.asarray(rng.integers(-128, 128, (m, m)), jnp.int32)
    us, _ = _timeit(lambda: np.asarray(ops.systolic_matmul(a, b)), reps=2)
    print(f"kernel_exact_{m}cube,{us:.0f},int8->int32")
    us, _ = _timeit(lambda: np.asarray(ops.approx_matmul(a, b, k=4)), reps=2)
    print(f"kernel_approx_lut_{m}cube,{us:.0f},k=4")
    tb = lut.build_onehot_weights(np.asarray(b), k=4)
    us, _ = _timeit(lambda: np.asarray(lut.onehot_matmul(a, tb)), reps=2)
    print(f"kernel_approx_onehot_{m}cube,{us:.0f},k=4 (MXU rewrite)")


def gemm_backends_bench(fast: bool = False):
    """Backend sweep: approx_lut vs approx_onehot vs approx_delta across
    M/N/K and k. Prints CSV rows and records the sweep (plus the delta-vs-lut
    speedup this PR's MXU-resident path must sustain) in
    BENCH_gemm_backends.json at the repo root."""
    import json
    import os
    import jax
    import jax.numpy as jnp
    from repro.core import error_delta, lut
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    sizes = [128, 256] if fast else [128, 256, 512]
    ks = (4,) if fast else (2, 4, 6)
    onehot_cap = 256   # the (K*256, N) f32 T_B at 512^3 is ~270 MB — skipped
    results = []
    for m in sizes:
        a = jnp.asarray(rng.integers(-128, 128, (m, m)), jnp.int32)
        b = jnp.asarray(rng.integers(-128, 128, (m, m)), jnp.int32)
        for kf in ks:
            reps = 1 if m >= 512 else 2
            us_lut, out_lut = _timeit(
                lambda: np.asarray(ops.approx_matmul(a, b, k=kf)), reps=reps)
            row = {"m": m, "n": m, "k_dim": m, "k": kf}
            rank = error_delta.rank_for_exact(8, kf, True, 24)
            us_delta, out_d = _timeit(
                lambda: np.asarray(ops.approx_delta_matmul(a, b, k=kf)),
                reps=reps)
            exact = bool(np.array_equal(out_d, out_lut))
            results.append({**row, "backend": "approx_lut",
                            "us_per_call": round(us_lut, 1)})
            results.append({**row, "backend": "approx_delta", "rank": rank,
                            "us_per_call": round(us_delta, 1),
                            "bit_exact_vs_lut": exact,
                            "speedup_vs_lut": round(us_lut / us_delta, 2)})
            print(f"bench_lut_{m}cube_k{kf},{us_lut:.0f},gather path")
            print(f"bench_delta_{m}cube_k{kf},{us_delta:.0f},rank={rank} "
                  f"exact={exact} speedup={us_lut / us_delta:.2f}x")
            if m <= onehot_cap:
                t_b = lut.build_onehot_weights(np.asarray(b), k=kf)
                us_oh, _ = _timeit(
                    lambda: np.asarray(lut.onehot_matmul(a, t_b)), reps=reps)
                results.append({**row, "backend": "approx_onehot",
                                "us_per_call": round(us_oh, 1),
                                "note": "T_B prebuilt (fixed weights)"})
                print(f"bench_onehot_{m}cube_k{kf},{us_oh:.0f},T_B prebuilt")
            else:
                print(f"bench_onehot_{m}cube_k{kf},0,skipped (T_B > "
                      f"{onehot_cap}^3 memory cap)")
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_gemm_backends.json")
    with open(path, "w") as f:
        json.dump({"device": jax.default_backend(),
                   "mode": "interpret" if jax.default_backend() != "tpu"
                   else "mosaic",
                   "fast": fast, "results": results}, f, indent=1)
    print(f"bench_backends_json,0,{os.path.normpath(path)}")


def apps_bench(fast: bool = False):
    """Application-workload backend sweep: DCT / edge / BDCN GEMMs routed
    through GemmPolicy, backend x k x image size. The weight-stationary
    ``approx_delta`` path (prepared G_B/F_A factors) must beat the
    ``approx_lut`` gather path; results recorded in BENCH_apps_backends.json
    with per-point bit-exactness vs the lut backend."""
    import json
    import os
    import jax
    from repro.apps import bdcn, dct, edge, images

    backends = ("approx_lut", "approx_onehot", "approx_delta")
    sizes = (64,) if fast else (128, 256)
    ks = (4,) if fast else (2, 4, 6)
    results = []

    def sweep(app, size, kf, fn):
        ref = None
        for be in backends:
            # sub-10ms workloads on a shared CPU need several reps to settle
            reps = 2 if size >= 256 else 6
            if be == "approx_onehot":
                reps = 1
            us, out = _timeit(fn, be, reps=reps)
            if be == "approx_lut":
                ref = (us, out)
            exact = bool(np.array_equal(out, ref[1]))
            row = {"app": app, "size": size, "k": kf, "backend": be,
                   "us_per_call": round(us, 1), "bit_exact_vs_lut": exact}
            if be != "approx_lut":
                row["speedup_vs_lut"] = round(ref[0] / us, 2)
            results.append(row)
            print(f"apps_{app}_{size}px_k{kf}_{be},{us:.0f},"
                  f"exact={exact}" + (f" speedup={ref[0] / us:.2f}x"
                                      if be != "approx_lut" else ""))

    for size in sizes:
        img = images.test_image(size, 0)
        blocks = images.to_blocks(img)
        for kf in ks:
            sweep("dct", size, kf,
                  lambda be, b=blocks, k=kf:
                  dct.forward_dct_blocks(b, k, policy=be))
            sweep("edge", size, kf,
                  lambda be, i=img, k=kf:
                  np.asarray(edge.conv_gemm(i, edge.LAPLACIAN, k, policy=be)))
    bdcn_size = 48 if fast else 64
    ws = bdcn.make_weights([8, 16, 16, 16], 0)
    img = images.test_image(bdcn_size, 0)
    for kf in ks:
        sweep("bdcn", bdcn_size, kf,
              lambda be, k=kf: bdcn.bdcn_forward(img, ws, k, policy=be))
    summary = {}
    for app in ("dct", "edge", "bdcn"):
        sp = [r["speedup_vs_lut"] for r in results
              if r["app"] == app and r["backend"] == "approx_delta"]
        if sp:
            summary[f"{app}_delta_geomean_speedup_vs_lut"] = round(
                float(np.exp(np.mean(np.log(sp)))), 2)
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_apps_backends.json")
    with open(path, "w") as f:
        json.dump({"device": jax.default_backend(),
                   "mode": "interpret" if jax.default_backend() != "tpu"
                   else "mosaic",
                   "fast": fast,
                   "note": "approx_delta runs weight-stationary (prepared "
                           "weight-restricted rank-r' factors); approx_onehot "
                           "prepares T_B where the weights sit on the right",
                   "summary": summary,
                   "results": results}, f, indent=1)
    for k, v in summary.items():
        print(f"bench_apps_{k},0,{v}x")
    print(f"bench_apps_json,0,{os.path.normpath(path)}")


def serve_bound_bench(fast: bool = False):
    """Decode throughput, bound (weight-stationary) vs unbound params.

    Builds the reduced smollm decode step under ``mxu_int8`` and
    ``approx_delta`` policies, measures tokens/s with raw params (weights
    quantized + factors rebuilt every step) vs ``gemm.bind``-bound params
    (all weight work done once), checks the two decode streams are
    bit-exact, and records the sweep in BENCH_serve_bound.json.
    """
    import json
    import os
    import time
    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, reduced
    from repro.core import gemm
    from repro.models import get_model

    cfg = reduced(ARCHS["smollm-360m"])
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, pl = 2, 8
    gl = 4 if fast else 8
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, pl)), jnp.int32)
    results = []
    for backend in ("mxu_int8", "approx_delta"):
        pol = gemm.GemmPolicy(backend=backend, k=4)
        dec = jax.jit(lambda p, t, c, pos:
                      model.decode_step(p, t, c, pos, policy=pol))
        pre = jax.jit(lambda p, bt, c: model.prefill(p, bt, c, policy=pol))
        t0 = time.perf_counter()
        bound = model.bind_params(params, pol)
        bind_s = time.perf_counter() - t0
        row = {"backend": backend, "batch": b, "gen_len": gl,
               "bind_s": round(bind_s, 3)}
        streams = {}
        for name, p in (("unbound", params), ("bound", bound)):
            cache = model.init_cache(b, pl + gl + 1)
            logits, cache = pre(p, {"tokens": prompts}, cache)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            # warmup decode (compile) — block so async dispatch of the warmup
            # (and prefill) can't bleed into the timed region
            jax.block_until_ready(dec(p, tok, cache, jnp.int32(pl)))
            toks = [np.asarray(tok)]
            t0 = time.perf_counter()
            for i in range(gl):
                logits, cache = dec(p, tok, cache, jnp.int32(pl + i))
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                toks.append(np.asarray(tok))
            jax.block_until_ready(logits)
            dt = time.perf_counter() - t0
            streams[name] = (np.concatenate(toks, axis=1),
                             np.asarray(logits))
            row[f"{name}_us_per_tok"] = round(dt / (b * gl) * 1e6, 1)
            row[f"{name}_tok_per_s"] = round(b * gl / dt, 1)
        row["bit_exact"] = bool(
            np.array_equal(streams["unbound"][1], streams["bound"][1])
            and np.array_equal(streams["unbound"][0], streams["bound"][0]))
        row["speedup"] = round(row["unbound_us_per_tok"]
                               / row["bound_us_per_tok"], 2)
        results.append(row)
        print(f"serve_bound_{backend},{row['bound_us_per_tok']:.0f},"
              f"speedup={row['speedup']}x exact={row['bit_exact']} "
              f"bind={row['bind_s']}s")
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serve_bound.json")
    with open(path, "w") as f:
        json.dump({"device": jax.default_backend(),
                   "mode": "interpret" if jax.default_backend() != "tpu"
                   else "mosaic",
                   "fast": fast, "arch": "smollm-360m (reduced)",
                   "note": "bound = gemm.bind(params, policy): weight "
                           "quantization + backend factors built once; "
                           "unbound re-derives them inside every decode step",
                   "results": results}, f, indent=1)
    print(f"bench_serve_bound_json,0,{os.path.normpath(path)}")


def serve_engine_bench(fast: bool = False):
    """Continuous-batching engine vs padded lockstep on a ragged Poisson trace.

    Replays one fixed ragged trace (heavy-tailed gen lengths, Poisson
    arrivals) through (a) the **padded lockstep loop** — the pre-engine
    serving semantics: one fixed (prompt_len, gen_len) = the trace maxima,
    requests grouped into arrival-order batches, every request padded to the
    slowest one; (b) a per-batch-padded lockstep variant (each batch padded
    only to its own maxima — a stronger baseline, recorded for reference);
    and (c) `launch.engine.ServeEngine` (paged + chunked prefill) with the
    same number of slots. Useful-token throughput (each request's own
    tokens / wall time) per backend x bind cell, plus the vectorized
    `gemm.bind` latency, recorded in BENCH_serve_engine.json.

    Two PR-5 cells ride along: **capacity** (max concurrent requests at one
    fixed KV budget, paged block pool vs contiguous per-slot regions) and
    **chunked_prefill** (useful tokens/s on a bursty arrival trace, chunked
    prefill vs the contiguous engine's one-request-per-dispatch prefill).
    The PR-9 **multi_step_n{4,8}** cells measure fused decode horizons
    (`ServeEngine(multi_step=n)`) against the per-step engine on a
    decode-heavy trace, recording syncs-per-token alongside throughput.
    The PR-10 **prefix_cache** / **prefix_capacity** cells measure prefix
    caching on a repeated shared-prefix trace: warm-over-cold useful tok/s
    and peak concurrency at a fixed block budget, with in-bench stream
    parity (cached == uncached, bit for bit).
    The scheduled CI job diffs this file against the committed baseline and
    fails on a >20% drop in the same-run relative metrics — engine-vs-lockstep speedup, concurrency ratio, chunked-prefill speedup, multi-step speedup, prefix-cache speedup and concurrency (benchmarks/compare.py).
    """
    import json
    import os
    import time
    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, reduced
    from repro.core import gemm
    from repro.launch import engine as engine_mod
    from repro.launch.serve import lockstep_generate
    from repro.models import get_model

    cfg = reduced(ARCHS["smollm-360m"])
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    slots = 4
    n_req = 12 if fast else 16
    # heavy-tailed gen lengths: most requests are short, a few are long — the
    # regime where lockstep pads every request to the slowest one
    trace = engine_mod.make_poisson_trace(
        n_req, rate=4.0, vocab_size=cfg.vocab_size, prompt_lens=(4, 6),
        gen_lens=(6, 8, 10, 12, 56), seed=0)
    pl_max = max(len(r.prompt) for r in trace)
    gl_max = max(r.max_new_tokens for r in trace)
    max_len = pl_max + gl_max
    useful = sum(r.max_new_tokens for r in trace)
    grid = [("exact", False), ("mxu_int8", True), ("approx_delta", True)]
    if not fast:
        grid.insert(2, ("mxu_int8", False))
    results = []
    for backend, bind in grid:
        pol = gemm.GemmPolicy(backend=backend, k=4)
        bind_s = 0.0
        p = params
        if bind:
            t0 = time.perf_counter()
            p = model.bind_params(params, pol)
            bind_s = time.perf_counter() - t0

        def run_lockstep(per_batch: bool):
            done = 0
            for i in range(0, len(trace), slots):
                group = trace[i:i + slots]
                pl = (max(len(r.prompt) for r in group) if per_batch
                      else pl_max)
                gl = (max(r.max_new_tokens for r in group) if per_batch
                      else gl_max)
                prompts = np.stack([np.pad(r.prompt, (0, pl - len(r.prompt)))
                                    for r in group])
                lockstep_generate(cfg, model, p, jnp.asarray(prompts), gl,
                                  policy=pol)
                done += len(group) * gl
            return done

        def run_engine(paged_kernel=None):
            eng = engine_mod.ServeEngine(cfg, p, policy=pol, max_slots=slots,
                                         max_len=max_len,
                                         paged_kernel=paged_kernel)
            eng.run(list(trace))
            return eng.stats

        # warm every compile cache, then time (min over reps — the shared
        # CPU is noisy and these runs are sub-second)
        run_lockstep(False), run_lockstep(True), run_engine()
        reps = 2 if fast else 3
        lock_s = min(engine_mod.elapsed(
            lambda: run_lockstep(False))[1] for _ in range(reps))
        lock_pb_s = min(engine_mod.elapsed(
            lambda: run_lockstep(True))[1] for _ in range(reps))
        eng_s, st = np.inf, None
        for _ in range(reps):
            st_i, dt = engine_mod.elapsed(run_engine)
            if dt < eng_s:
                eng_s, st = dt, st_i
        assert st["generated_tokens"] == useful, (st, useful)
        padded = run_lockstep(False)
        row = {"cell": "engine_vs_lockstep",
               "backend": backend, "bound": bind, "bind_s": round(bind_s, 3),
               "slots": slots, "requests": n_req,
               "useful_tokens": useful, "lockstep_padded_tokens": padded,
               "lockstep_tok_per_s": round(useful / lock_s, 1),
               "lockstep_per_batch_tok_per_s": round(useful / lock_pb_s, 1),
               "engine_tok_per_s": round(useful / eng_s, 1),
               "engine_decode_steps": st["decode_steps"],
               "slot_utilization": st["slot_utilization"],
               "block_utilization": st["block_utilization"],
               "speedup": round(lock_s / eng_s, 2),
               "speedup_vs_per_batch": round(lock_pb_s / eng_s, 2)}
        results.append(row)
        print(f"serve_engine_{backend}{'_bound' if bind else ''},"
              f"{eng_s / useful * 1e6:.0f},speedup={row['speedup']}x "
              f"(vs per-batch-padded {row['speedup_vs_per_batch']}x) "
              f"engine={row['engine_tok_per_s']}tok/s "
              f"lockstep={row['lockstep_tok_per_s']}tok/s "
              f"bind={bind_s:.2f}s")

        # --- paged_kernel cell: fused in-kernel-table-walk attention --------
        # Same trace, same lockstep baseline; the engine swaps the per-layer
        # gather + wide chunked_attention for kernels.paged_attention
        # (n_splits=1, the bit-exact serving contract). `speedup` is gated by
        # benchmarks/compare.py exactly like the gather row's.
        run_engine(1)                                   # warm compile caches
        pk_s, st_pk = np.inf, None
        for _ in range(reps):
            st_i, dt = engine_mod.elapsed(lambda: run_engine(1))
            if dt < pk_s:
                pk_s, st_pk = dt, st_i
        assert st_pk["generated_tokens"] == useful, (st_pk, useful)
        row_pk = {"cell": "paged_kernel",
                  "backend": backend, "bound": bind, "slots": slots,
                  "requests": n_req, "useful_tokens": useful, "n_splits": 1,
                  "engine_tok_per_s": round(useful / pk_s, 1),
                  "gather_engine_tok_per_s": round(useful / eng_s, 1),
                  "lockstep_tok_per_s": round(useful / lock_s, 1),
                  "engine_decode_steps": st_pk["decode_steps"],
                  "speedup": round(lock_s / pk_s, 2),
                  "speedup_vs_gather": round(eng_s / pk_s, 2)}
        results.append(row_pk)
        print(f"serve_paged_kernel_{backend}{'_bound' if bind else ''},"
              f"{pk_s / useful * 1e6:.0f},speedup={row_pk['speedup']}x "
              f"(vs gather engine {row_pk['speedup_vs_gather']}x) "
              f"engine={row_pk['engine_tok_per_s']}tok/s")

    # --- capacity cell: concurrent requests at one fixed KV budget ----------
    cap_len, cap_bs, cap_slots_c = 32, 4, 4
    budget_blocks = cap_slots_c * (cap_len // cap_bs)   # contiguous budget
    n_cap = 12 if fast else 18
    cap_trace = [engine_mod.Request(
        rid=r, prompt=np.random.default_rng(r).integers(
            0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=6)
        for r in range(n_cap)]                          # 3 blocks each

    def run_cap(paged):
        if paged:
            eng = engine_mod.ServeEngine(
                cfg, params, max_slots=n_cap, max_len=cap_len,
                block_size=cap_bs, n_blocks=budget_blocks, prefill_chunk=6)
        else:
            eng = engine_mod.ServeEngine(cfg, params, max_slots=cap_slots_c,
                                         max_len=cap_len, paged=False)
        eng.run(list(cap_trace))
        return eng.stats, eng.stats["peak_active_slots"]

    run_cap(True), run_cap(False)                       # warm compile caches
    (st_p, peak_p), dt_p = engine_mod.elapsed(lambda: run_cap(True))
    (st_c, peak_c), dt_c = engine_mod.elapsed(lambda: run_cap(False))
    useful_cap = sum(r.max_new_tokens for r in cap_trace)
    row = {"cell": "capacity", "kv_budget_tokens": budget_blocks * cap_bs,
           "block_size": cap_bs, "requests": n_cap,
           "paged_peak_concurrent": int(peak_p),
           "contiguous_peak_concurrent": int(peak_c),
           "concurrency_ratio": round(peak_p / peak_c, 2),
           "paged_tok_per_s": round(useful_cap / dt_p, 1),
           "contiguous_tok_per_s": round(useful_cap / dt_c, 1),
           "paged_block_utilization": st_p["block_utilization"]}
    results.append(row)
    print(f"serve_capacity,{dt_p / useful_cap * 1e6:.0f},"
          f"paged={peak_p}req vs contiguous={peak_c}req at "
          f"{row['kv_budget_tokens']}tok budget "
          f"({row['concurrency_ratio']}x concurrency)")

    # --- chunked-prefill cell: bursty arrivals, heterogeneous prompts -------
    # Real traffic carries many distinct prompt lengths. The contiguous
    # engine's fused prefill-on-admit jit-specializes per prompt length, so a
    # bursty heterogeneous trace pays one compilation per new length *at
    # serve time*; chunked prefill feeds prompts through the shared batched
    # step and compiles at most prefill_chunk widths. Measured **cold**
    # (single shot, each path paying its own jit specializations — the
    # admission overhead the ROADMAP item targets), with steady-state warm
    # numbers recorded alongside.
    n_cp = 10 if fast else 16
    rng_cp = np.random.default_rng(7)
    t_arr = 0.0
    cp_trace = []
    for r in range(n_cp):
        t_arr += rng_cp.exponential(0.5)
        cp_trace.append(engine_mod.Request(
            rid=r,
            prompt=rng_cp.integers(0, cfg.vocab_size,
                                   8 + r).astype(np.int32),
            max_new_tokens=6, arrival=int(t_arr)))      # 16 distinct lengths

    def run_cp(paged):
        eng = engine_mod.ServeEngine(
            cfg, params, max_slots=4, max_len=8 + n_cp + 8, paged=paged,
            **({"block_size": cap_bs, "prefill_chunk": 8} if paged else {}))
        eng.run(list(cp_trace))
        return eng.stats

    useful_cp = sum(r.max_new_tokens for r in cp_trace)
    _, cold_p = engine_mod.elapsed(lambda: run_cp(True))
    _, cold_c = engine_mod.elapsed(lambda: run_cp(False))
    reps = 2 if fast else 3
    warm_p = min(engine_mod.elapsed(lambda: run_cp(True))[1]
                 for _ in range(reps))
    warm_c = min(engine_mod.elapsed(lambda: run_cp(False))[1]
                 for _ in range(reps))
    row = {"cell": "chunked_prefill", "requests": n_cp,
           "distinct_prompt_lens": n_cp, "prefill_chunk": 8,
           "chunked_tok_per_s": round(useful_cp / cold_p, 1),
           "per_request_tok_per_s": round(useful_cp / cold_c, 1),
           "speedup": round(cold_c / cold_p, 2),
           "warm_chunked_tok_per_s": round(useful_cp / warm_p, 1),
           "warm_per_request_tok_per_s": round(useful_cp / warm_c, 1)}
    results.append(row)
    print(f"serve_chunked_prefill,{cold_p / useful_cp * 1e6:.0f},"
          f"{row['speedup']}x vs per-request prefill on {n_cp} distinct "
          f"prompt lengths ({row['chunked_tok_per_s']} vs "
          f"{row['per_request_tok_per_s']} tok/s cold; warm "
          f"{row['warm_chunked_tok_per_s']} vs "
          f"{row['warm_per_request_tok_per_s']})")
    # --- multi-step cell: fused decode horizons, syncs-per-token ------------
    # Decode-heavy trace (long generations, short prompts): the regime where
    # the per-token host sync dominates the scheduler. n=1 is the per-step
    # engine; n in {4, 8} dispatch fused lax.scan horizons with on-device
    # retirement (one (n, B) token sync per horizon). `speedup` (useful
    # tok/s vs the same-run n=1 row) is gated by benchmarks/compare.py like
    # every relative metric; syncs_per_token records the 1/n sync bound.
    n_ms = 8 if fast else 12
    ms_trace = engine_mod.make_poisson_trace(
        n_ms, rate=4.0, vocab_size=cfg.vocab_size, prompt_lens=(4, 6),
        gen_lens=(48, 64, 96), seed=3)
    useful_ms = sum(r.max_new_tokens for r in ms_trace)
    ms_len = (max(len(r.prompt) for r in ms_trace)
              + max(r.max_new_tokens for r in ms_trace))
    pol_ms = gemm.GemmPolicy(backend="mxu_int8", k=4)
    p_ms = model.bind_params(params, pol_ms)

    def run_ms(n):
        eng = engine_mod.ServeEngine(cfg, p_ms, policy=pol_ms,
                                     max_slots=slots, max_len=ms_len,
                                     multi_step=n)
        fin = eng.run(list(ms_trace))
        return eng.stats, {rid: f.tokens for rid, f in fin.items()}

    base_ms, base_streams = None, None
    for n in (1, 4, 8):
        run_ms(n)                                       # warm compile caches
        ms_s, st_ms, streams = np.inf, None, None
        for _ in range(reps):
            (st_i, str_i), dt = engine_mod.elapsed(lambda: run_ms(n))
            if dt < ms_s:
                ms_s, st_ms, streams = dt, st_i, str_i
        assert st_ms["generated_tokens"] == useful_ms, (st_ms, useful_ms)
        if n == 1:
            base_ms, base_streams = ms_s, streams
        else:                                           # parity is the gate
            for rid in base_streams:
                np.testing.assert_array_equal(base_streams[rid], streams[rid])
        row = {"cell": f"multi_step_n{n}", "backend": "mxu_int8",
               "bound": True, "n": n, "slots": slots, "requests": n_ms,
               "useful_tokens": useful_ms,
               "engine_tok_per_s": round(useful_ms / ms_s, 1),
               "per_step_tok_per_s": round(useful_ms / base_ms, 1),
               "host_syncs": st_ms["host_syncs"],
               "syncs_per_token": st_ms["syncs_per_token"],
               "speedup": round(base_ms / ms_s, 2)}
        results.append(row)
        print(f"serve_multi_step_n{n},{ms_s / useful_ms * 1e6:.0f},"
              f"speedup={row['speedup']}x vs per-step "
              f"({row['engine_tok_per_s']} vs {row['per_step_tok_per_s']} "
              f"tok/s), {row['syncs_per_token']} syncs/token")

    # --- prefix-cache cells: repeated shared-prefix traffic (PR 10) ---------
    # Real serving repeats itself: one system prompt heads every request.
    # Cell 1 (prefix_cache): warm engine (block sharing on) vs cold (off) on
    # the same shared-prefix trace — `speedup` is warm-over-cold useful
    # tok/s, gated by benchmarks/compare.py. Cell 2 (prefix_capacity): at
    # one fixed small block budget, shared prefixes shrink each request's
    # fresh-block footprint, so more requests fit concurrently —
    # `concurrency_ratio` (warm peak / cold peak) is gated the same way.
    # Both cells assert stream parity in-bench: sharing must not move a bit.
    n_px = 6 if fast else 8
    rng_px = np.random.default_rng(11)
    sys_prompt = rng_px.integers(0, cfg.vocab_size, 48).astype(np.int32)
    px_trace = []
    for r in range(n_px):
        tail = rng_px.integers(0, cfg.vocab_size, 2).astype(np.int32)
        # the seeder runs alone; followers arrive once its prefill has
        # published the shared blocks (50 tokens / chunk 8 = 7 steps)
        px_trace.append(engine_mod.Request(
            rid=r, prompt=np.concatenate([sys_prompt, tail]),
            max_new_tokens=4, arrival=0 if r == 0 else 7))
    useful_px = sum(r.max_new_tokens for r in px_trace)

    def run_px(warm):
        eng = engine_mod.ServeEngine(
            cfg, params, max_slots=2, max_len=64, prefix_cache=warm)
        fin = eng.run([engine_mod.Request(
            rid=r.rid, prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens,
            arrival=r.arrival) for r in px_trace])
        return eng.stats, {rid: f.tokens for rid, f in fin.items()}

    (st_w, str_w), _ = engine_mod.elapsed(lambda: run_px(True))   # warm jit
    (st_c, str_c), _ = engine_mod.elapsed(lambda: run_px(False))
    for rid in str_c:                                   # parity is the gate
        np.testing.assert_array_equal(str_w[rid], str_c[rid])
    warm_s = min(engine_mod.elapsed(lambda: run_px(True))[1]
                 for _ in range(reps))
    cold_s = min(engine_mod.elapsed(lambda: run_px(False))[1]
                 for _ in range(reps))
    row = {"cell": "prefix_cache", "requests": n_px,
           "shared_prompt_tokens": int(len(sys_prompt)),
           "prefix_hits": st_w["prefix_hits"],
           "prefix_tokens_skipped": st_w["prefix_tokens_skipped"],
           "warm_tok_per_s": round(useful_px / warm_s, 1),
           "cold_tok_per_s": round(useful_px / cold_s, 1),
           "speedup": round(cold_s / warm_s, 2)}
    results.append(row)
    print(f"serve_prefix_cache,{warm_s / useful_px * 1e6:.0f},"
          f"speedup={row['speedup']}x warm vs cold "
          f"({row['warm_tok_per_s']} vs {row['cold_tok_per_s']} tok/s), "
          f"{row['prefix_hits']} hits / "
          f"{row['prefix_tokens_skipped']} tokens skipped")

    n_pc = 5 if fast else 7
    pc_bs, pc_blocks = 4, 12
    rng_pc = np.random.default_rng(13)
    pc_head = rng_pc.integers(0, cfg.vocab_size, 16).astype(np.int32)
    pc_trace = [engine_mod.Request(
        rid=r,
        prompt=np.concatenate(
            [pc_head, rng_pc.integers(0, cfg.vocab_size, 2).astype(np.int32)]),
        max_new_tokens=4, arrival=0 if r == 0 else 10)
        for r in range(n_pc)]                           # 6 blocks, 4 shared

    def run_pc(warm):
        eng = engine_mod.ServeEngine(
            cfg, params, max_slots=n_pc, max_len=24, block_size=pc_bs,
            n_blocks=pc_blocks, prefill_chunk=6, prefix_cache=warm)
        fin = eng.run([engine_mod.Request(
            rid=r.rid, prompt=r.prompt.copy(), max_new_tokens=r.max_new_tokens,
            arrival=r.arrival) for r in pc_trace])
        return (eng.stats["peak_active_slots"],
                {rid: f.tokens for rid, f in fin.items()})

    peak_w, pcs_w = run_pc(True)
    peak_c2, pcs_c = run_pc(False)
    for rid in pcs_c:
        np.testing.assert_array_equal(pcs_w[rid], pcs_c[rid])
    row = {"cell": "prefix_capacity", "block_budget": pc_blocks,
           "block_size": pc_bs, "requests": n_pc,
           "blocks_per_request": 6, "shared_blocks_per_request": 4,
           "warm_peak_concurrent": int(peak_w),
           "cold_peak_concurrent": int(peak_c2),
           "concurrency_ratio": round(peak_w / peak_c2, 2)}
    results.append(row)
    print(f"serve_prefix_capacity,0,"
          f"warm={peak_w}req vs cold={peak_c2}req at "
          f"{pc_blocks} blocks ({row['concurrency_ratio']}x concurrency)")

    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serve_engine.json")
    with open(path, "w") as f:
        json.dump({"device": jax.default_backend(),
                   "mode": "interpret" if jax.default_backend() != "tpu"
                   else "mosaic",
                   "fast": fast, "arch": "smollm-360m (reduced)",
                   "note": "ragged Poisson trace; lockstep pads every batch "
                           "to its longest prompt/gen; engine = continuous "
                           "batching with per-slot ragged decode; bind_s = "
                           "vectorized gemm.bind latency",
                   "results": results}, f, indent=1)
    print(f"bench_serve_engine_json,0,{os.path.normpath(path)}")


def abft_guard_bench(fast: bool = False):
    """ABFT guard overhead: guarded vs unguarded engine decode throughput.

    Serves one fixed ragged Poisson trace through the paged engine per
    backend, with ``guard='none'`` and ``guard='detect'`` (same params, same
    compiled-step caches warmed), and records useful-tokens/s for both plus
    their same-run ratio ``guarded_frac = guarded / unguarded`` — the
    fraction of throughput that survives checksums + between-step scrubbing.
    The scheduled CI job gates on that ratio (benchmarks/compare.py,
    >20% drop fails): absolute tok/s is machine-bound, the fraction is not.
    Streams are asserted bit-identical between the two runs — the guard must
    observe, never perturb.
    """
    import json
    import os
    import jax
    from repro.configs import ARCHS, reduced
    from repro.core import gemm
    from repro.launch import engine as engine_mod
    from repro.models import get_model

    cfg = reduced(ARCHS["smollm-360m"])
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n_req = 8 if fast else 12
    trace = engine_mod.make_poisson_trace(
        n_req, rate=3.0, vocab_size=cfg.vocab_size, prompt_lens=(4, 6),
        gen_lens=(6, 8, 12), seed=0)
    useful = sum(r.max_new_tokens for r in trace)
    backends = (("exact", False), ("approx_lut", True)) if fast else \
        (("exact", False), ("mxu_int8", True), ("approx_lut", True),
         ("approx_delta", True))
    results = []
    for backend, bind in backends:
        p = model.bind_params(params, gemm.GemmPolicy(backend=backend, k=4)) \
            if bind else params

        def run(guard):
            pol = gemm.GemmPolicy(backend=backend, k=4, guard=guard)
            eng = engine_mod.ServeEngine(cfg, p, policy=pol, max_slots=4,
                                         max_len=24)
            fin = eng.run(list(trace))
            assert eng.events["faults_detected"] == 0, eng.events
            return {rid: f.tokens for rid, f in fin.items()}

        base = run("none")
        guarded = run("detect")                 # also warms both caches
        for rid in base:
            np.testing.assert_array_equal(base[rid], guarded[rid])
        reps = 2 if fast else 3
        none_s = min(engine_mod.elapsed(lambda: run("none"))[1]
                     for _ in range(reps))
        det_s = min(engine_mod.elapsed(lambda: run("detect"))[1]
                    for _ in range(reps))
        row = {"cell": "abft_guard", "backend": backend, "bound": bind,
               "requests": n_req, "useful_tokens": useful,
               "unguarded_tok_per_s": round(useful / none_s, 1),
               "guarded_tok_per_s": round(useful / det_s, 1),
               "guarded_frac": round(none_s / det_s, 3)}
        results.append(row)
        print(f"abft_guard_{backend}{'_bound' if bind else ''},"
              f"{det_s / useful * 1e6:.0f},"
              f"guarded={row['guarded_tok_per_s']}tok/s "
              f"unguarded={row['unguarded_tok_per_s']}tok/s "
              f"({row['guarded_frac']:.0%} survives the guard)")
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_abft.json")
    with open(path, "w") as f:
        json.dump({"device": jax.default_backend(),
                   "mode": "interpret" if jax.default_backend() != "tpu"
                   else "mosaic",
                   "fast": fast, "arch": "smollm-360m (reduced)",
                   "note": "guard='detect' vs guard='none' on one ragged "
                           "Poisson trace through the paged engine; "
                           "guarded_frac = guarded/unguarded tok/s "
                           "(same-run ratio, gated in CI)",
                   "results": results}, f, indent=1)
    print(f"bench_abft_json,0,{os.path.normpath(path)}")


def roofline_summary():
    """Dry-run roofline table (reads experiments/dryrun.jsonl if present)."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun.jsonl")
    if not os.path.exists(path):
        print("roofline_summary,0,skipped (run repro.launch.dryrun --all first)")
        return
    n_ok = n_skip = n_err = 0
    worst = (None, 1e9)
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r["status"] == "ok":
                n_ok += 1
                rf = r.get("analytic", {}).get("roofline_frac", 0)
                if r["mesh"] == "16x16" and rf < worst[1]:
                    worst = (f"{r['arch']}x{r['shape']}", rf)
            elif r["status"] == "skipped":
                n_skip += 1
            else:
                n_err += 1
    print(f"roofline_cells,0,{n_ok}ok/{n_skip}skip/{n_err}fail")
    if worst[0]:
        print(f"roofline_worst_cell,0,{worst[0]}@{worst[1]:.1%}")


def analysis_bench(fast=False):
    """Static-analysis wall time: full-repo lint + kernel audit (<10s budget).

    The CLI gate runs on every tier-1 push, so the whole pass must stay
    interactive-fast; the budget is asserted, not just reported.
    """
    import pathlib
    import time
    from repro import analysis

    root = pathlib.Path(__file__).resolve().parent.parent
    t0 = time.perf_counter()
    report = analysis.run(root=str(root))
    wall_s = time.perf_counter() - t0
    budget_s = 10.0
    assert wall_s < budget_s, (
        f"static analysis took {wall_s:.1f}s (> {budget_s:.0f}s budget) — "
        "the tier-1 CLI gate must stay interactive-fast")
    assert not report.active(), [f.format() for f in report.active()]
    meta = report.meta
    print(f"analysis_full_pass,{wall_s * 1e6:.0f},"
          f"{meta.get('lint_files', 0)}files+{meta.get('audit_cells', 0)}cells "
          f"in {wall_s:.2f}s (budget {budget_s:.0f}s, 0 findings)")


BENCHES = {
    "table1_cells": lambda fast: table1_cells(),
    "table2_cells": lambda fast: table2_cells(),
    "table3_pe": lambda fast: table3_pe(),
    "table4_sa": table4_sa,
    "table5_errors": table5_errors,
    "table6_apps": table6_apps,
    "fig9_fig10_pareto": fig9_fig10_pareto,
    "latency_wavefront": lambda fast: latency_wavefront(),
    "kernels_bench": kernels_bench,
    "gemm_backends_bench": gemm_backends_bench,
    "apps_bench": apps_bench,
    "serve_bound_bench": serve_bound_bench,
    "serve_engine_bench": serve_engine_bench,
    "abft_guard_bench": abft_guard_bench,
    "analysis_bench": analysis_bench,
    "roofline_summary": lambda fast: roofline_summary(),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("benches", nargs="*", choices=[[], *BENCHES],
                    help="benchmarks to run (default: all), e.g. "
                         "`python -m benchmarks.run serve_bound_bench`")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    names = args.benches or list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name](args.fast)


if __name__ == "__main__":
    main()
