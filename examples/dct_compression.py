"""DCT image compression through the approximate systolic array (paper §V-A,
Table VI). PSNR/SSIM vs the exact-arithmetic pipeline at several k.

Run:  PYTHONPATH=src python examples/dct_compression.py [--size 128]
          [--backend approx_oracle|approx_lut|approx_delta|approx_onehot]

``approx_oracle`` (default) is the paper's fused-MAC simulation;
``approx_delta`` runs the same pipeline MXU-resident via the weight-stationary
error-delta decomposition (bit-identical to ``approx_lut``).
"""
import argparse

from repro.apps import dct


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--backend", default=None,
                    help="GemmPolicy backend (default: the paper's "
                         "fused-MAC oracle)")
    args = ap.parse_args()
    paper = {2: (45.97, 0.991), 4: (38.21, 0.955), 6: (35.67, 0.923),
             8: (28.43, 0.872)}
    be = args.backend or dct.DEFAULT_BACKEND
    print(f"8x8 integer DCT on a {args.size}x{args.size} image "
          f"(backend {be}, approx vs exact pipeline):")
    for k, v in dct.run(size=args.size, policy=args.backend).items():
        pp, ps = paper.get(k, (float('nan'),) * 2)
        print(f"  k={k}: PSNR {v['psnr']:6.2f} dB (paper {pp:5.2f})   "
              f"SSIM {v['ssim']:.3f} (paper {ps:.3f})")


if __name__ == "__main__":
    main()
