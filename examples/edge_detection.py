"""Kernel- and CNN-based edge detection on the approximate PE (paper §V-B,
Table VI). The CNN (BDCN-style) uses the paper's hybrid policy: first two blocks
approximate, later blocks exact — expressed as GemmPolicy per-layer overrides.

Run:  PYTHONPATH=src python examples/edge_detection.py [--size 128]
          [--backend approx_lut|approx_delta|approx_onehot]

``approx_delta`` runs the convolution GEMMs MXU-resident with the
weight-stationary prepared kernel factors (bit-identical to ``approx_lut``,
up to ~70x faster on the 256px im2col GEMM — see BENCH_apps_backends.json).
"""
import argparse

from repro.apps import bdcn, edge


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--bdcn-size", type=int, default=64)
    ap.add_argument("--backend", default=None,
                    help="GemmPolicy backend for the approximate GEMMs "
                         "(default approx_lut, the paper's table model)")
    args = ap.parse_args()
    paper_edge = {2: (30.45, 0.910), 4: (20.51, 0.894), 6: (12.76, 0.678),
                  8: (11.41, 0.651)}
    paper_bdcn = {2: (75.98, 1.0), 4: (68.55, 1.0), 6: (51.52, 0.999),
                  8: (34.60, 0.995)}
    be = args.backend or edge.DEFAULT_BACKEND
    print(f"Laplacian-kernel edge detection (backend {be}, approx vs exact):")
    for k, v in edge.run(size=args.size, policy=args.backend).items():
        pp, ps = paper_edge[k]
        print(f"  k={k}: PSNR {v['psnr']:6.2f} dB (paper {pp:5.2f})   "
              f"SSIM {v['ssim']:.3f} (paper {ps:.3f})")
    print("BDCN-style CNN edge detection (hybrid approx, first 2 blocks):")
    for k, v in bdcn.run(size=args.bdcn_size, policy=args.backend).items():
        pp, ps = paper_bdcn[k]
        print(f"  k={k}: PSNR {v['psnr']:6.2f} dB (paper {pp:5.2f})   "
              f"SSIM {v['ssim']:.3f} (paper {ps:.3f})")
    print("-> CNN-based consistently beats kernel-based (paper's key claim)")


if __name__ == "__main__":
    main()
