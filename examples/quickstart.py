"""Quickstart: the paper's technique in five minutes.

1. exact vs approximate PE on a single MAC,
2. approximate GEMM through the Pallas kernel (interpret mode on CPU),
3. error metrics at several approximation factors,
4. energy-model estimate for the same GEMM on the paper's 8x8 systolic array.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import emulate, energy, errors
from repro.kernels import ops


def main():
    print("== 1. one fused MAC: a*b + c on the 8-bit signed PE ==")
    a, b, c = 117, -93, 1500
    exact = int(emulate.pe_mac(np.int32(a), np.int32(b), np.int32(c), k=0))
    print(f"   exact   (k=0): {a}*{b}+{c} = {exact}  (true {a*b+c})")
    for k in (2, 4, 6, 8):
        approx = int(emulate.pe_mac(np.int32(a), np.int32(b), np.int32(c), k=k))
        print(f"   approx  (k={k}): {approx}   ED={approx - exact}")

    print("\n== 2. approximate GEMM via the Pallas kernel ==")
    rng = np.random.default_rng(0)
    A = rng.integers(-128, 128, (64, 48)).astype(np.int32)
    B = rng.integers(-128, 128, (48, 32)).astype(np.int32)
    exact_out = np.asarray(ops.systolic_matmul(jnp.asarray(A), jnp.asarray(B)))
    approx_out = np.asarray(ops.approx_matmul(jnp.asarray(A), jnp.asarray(B), k=4))
    m = errors.gemm_error_metrics(approx_out, exact_out)
    print(f"   64x48x32 GEMM, k=4: ER {m['ER']:.3f}  NMED {m['NMED']:.5f}  "
          f"MRED {m['MRED']:.5f}")
    # same result, MXU-resident: exact matmul + rank-r error correction
    # (docs/backends.md) — the fast path for the approximate GEMM
    delta_out = np.asarray(ops.approx_delta_matmul(jnp.asarray(A),
                                                   jnp.asarray(B), k=4))
    print(f"   approx_delta (exact+rank-r correction) bit-identical: "
          f"{np.array_equal(delta_out, approx_out)}")

    print("\n== 3. PE error metrics (Table V reproduction) ==")
    for k in (2, 4, 6, 8):
        em = errors.pe_error_metrics(8, k, signed=True)
        print(f"   k={k}: NMED {em['NMED']:.4f}  MRED {em['MRED']:.4f}")

    print("\n== 4. energy estimate (90nm model from paper Tables II-IV) ==")
    for design in ("exact_ref6", "proposed_exact", "approx_ref5",
                   "proposed_approx"):
        e = energy.gemm_energy_estimate(64, 48, 32, design=design, sa_dim=8)
        print(f"   {design:16s}: {e['energy_nJ']:8.1f} nJ  "
              f"({e['energy_per_mac_fJ']:.1f} fJ/MAC)")
    claims = energy.sa_energy_claims()
    print(f"   -> proposed approx saves {claims['sa8_approx_vs_exact_ref6']:.0%} "
          f"vs exact [6] at the 8x8 SA level (paper: 68%)")


if __name__ == "__main__":
    main()
