"""End-to-end driver: train a ~100M-class LM for a few hundred steps with the
full production stack (microbatched step, AdamW + cosine schedule, async
checkpointing, straggler watchdog, resume).

On this CPU container the default trains a width-reduced smollm variant (~10M
params) so a few hundred steps finish in minutes; pass --full on a TPU slice to
train the real config on the production mesh (same code path).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.configs import ARCHS
from repro.configs.base import ShapeSpec
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import mesh as mesh_mod
from repro.launch.steps import TrainHParams, assemble_train
from repro.models import get_model
from repro.train.loop import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--full", action="store_true",
                    help="full smollm-360m on the production mesh (TPU)")
    args = ap.parse_args()

    base = ARCHS["smollm-360m"]
    if args.full:
        cfg, shape = base, base.shape("train_4k")
        mesh = mesh_mod.make_production_mesh()
    else:
        cfg = dataclasses.replace(base, n_layers=6, d_model=256, n_heads=4,
                                  n_kv_heads=2, head_dim=64, d_ff=768,
                                  vocab_size=8192)
        shape = ShapeSpec("small", "train", args.seq_len, args.batch)
        mesh = mesh_mod.make_debug_mesh(1, 1)
    print(f"params: {cfg.param_count()/1e6:.1f}M  tokens/step: "
          f"{shape.global_batch * shape.seq_len}")
    hp = TrainHParams(n_micro=2, peak_lr=1e-3, warmup_steps=20,
                      total_steps=args.steps)
    step, arg_specs, in_sh, out_sh, hp = assemble_train(cfg, shape, mesh, hp)
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0, 1))
        model = get_model(cfg)
        stats = train(cfg, shape, jitted, model.init_params,
                      LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                                 ckpt_every=100),
                      n_micro=hp.n_micro,
                      data=SyntheticLM(cfg, shape, DataConfig(n_micro=hp.n_micro)))
    print(f"loss {stats['first_loss']:.3f} -> {stats['last_loss']:.3f} over "
          f"{stats['steps']} steps")
    assert stats["last_loss"] < stats["first_loss"], "loss must decrease"


if __name__ == "__main__":
    main()
