"""repro — energy-efficient exact/approximate systolic-array matmul, in JAX.

Reproduction + TPU-native extension of Jaswal et al., "Energy Efficient Exact and
Approximate Systolic Array Architecture for Matrix Multiplication" (VLSID 2026).
"""
__version__ = "0.1.0"
