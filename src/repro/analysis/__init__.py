"""Static analysis for the repro stack: kernel contracts + repo invariants.

Two engines, one finding stream (see docs/analysis.md):

* `kernel_audit` — every Pallas `pallas_call` entry point, abstractly
  evaluated (shape/dtype only) over the autotune/engine-reachable geometry
  grid against the TPU lowering rules (tiling, divisibility, VMEM, SMEM
  dtypes, index-map bounds). Its planners (`gemm_block_plan`,
  `prune_paged_plan`) are consumed by `launch.autotune` and `kernels.ops`
  so the TPU path never launches an auditor-rejected geometry.
* `lint` — AST rules over ``src/repro/`` for the serving-stack invariants:
  no GEMM bypass, ``layer=`` on model `dot` calls, no host syncs in jit
  steps, no global RNG, PRNG key discipline.

CLI: ``python -m repro.analysis`` (nonzero exit on new findings).
"""
from __future__ import annotations

import pathlib
from typing import Optional

from .findings import Finding, Report  # noqa: F401 (public API)


def run(root=".", *, vmem_budget: Optional[int] = None,
        tools: str = "lint,audit") -> Report:
    """Run the selected engines over the repo at ``root``; one merged Report."""
    from . import kernel_audit, lint

    root = pathlib.Path(root)
    report = Report(meta={"root": str(root), "tools": tools})
    wanted = {t.strip() for t in tools.split(",") if t.strip()}
    if "lint" in wanted:
        findings, _ = lint.lint_tree(root)
        report.extend(findings)
        report.meta["lint_files"] = len(
            list((root / "src" / "repro").rglob("*.py")))
    if "audit" in wanted:
        audit_report = kernel_audit.audit(vmem_budget)
        report.extend(audit_report.findings)
        report.meta["audit_cells"] = audit_report.meta["cells"]
        report.meta["vmem_budget"] = audit_report.meta["vmem_budget"]
    return report
