"""CLI: ``python -m repro.analysis`` — lint + kernel audit, gate on new findings.

Exit status 0 when every finding is suppressed or baselined, 1 otherwise
(the tier-1 ``analysis`` CI job runs ``--format json`` and relies on the
exit code). ``--write-baseline`` snapshots current unsuppressed findings as
accepted debt — review that diff like code.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from . import baseline as baseline_mod
from . import run


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static kernel-contract audit + repo invariant lint.")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--root", default=".", help="repo root (default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help="baseline path (default: <root>/analysis_baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="snapshot current unsuppressed findings and exit 0")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="per-core VMEM budget for the audit (default 16 MiB)")
    ap.add_argument("--only", choices=("lint", "audit"), default=None,
                    help="run a single engine")
    args = ap.parse_args(argv)

    root = pathlib.Path(args.root)
    tools = args.only or "lint,audit"
    report = run(root, vmem_budget=args.vmem_budget, tools=tools)

    baseline_path = pathlib.Path(
        args.baseline) if args.baseline else root / baseline_mod.DEFAULT_NAME
    if args.write_baseline:
        fps = baseline_mod.save(baseline_path, report)
        print(f"wrote {len(fps)} fingerprint(s) to {baseline_path}")
        return 0

    base = baseline_mod.load(baseline_path)
    new = report.active(base)

    if args.format == "json":
        print(report.to_json(base))
    else:
        for f in report.findings:
            print(f.format())
        n_sup = sum(f.suppressed for f in report.findings)
        n_base = len(report.active()) - len(new)
        print(f"{len(report.findings)} finding(s): {len(new)} new, "
              f"{n_sup} suppressed, {n_base} baselined "
              f"(lint files: {report.meta.get('lint_files', '-')}, "
              f"audit cells: {report.meta.get('audit_cells', '-')})")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
