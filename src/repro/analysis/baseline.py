"""Checked-in finding baseline.

The baseline is the set of *accepted* finding fingerprints: the CLI gate
fails only on findings that are neither suppressed at the site nor present
here, so adopting a new rule on a tree with known debt doesn't block every
PR while the debt is paid down. The shipped tree's baseline is empty (zero
unsuppressed findings) and the workflow keeps it honest:

* ``python -m repro.analysis --write-baseline`` snapshots the current
  unsuppressed findings (run it when intentionally accepting debt, with the
  diff reviewed like code);
* fingerprints hash (tool, rule, path, site) — not line numbers — so the
  baseline survives unrelated edits, and a *fixed* finding leaves a stale
  entry that ``--prune`` (or the next --write-baseline) removes.
"""
from __future__ import annotations

import json
import pathlib
from typing import List

from .findings import SCHEMA_VERSION, Report

DEFAULT_NAME = "analysis_baseline.json"


def load(path) -> List[str]:
    path = pathlib.Path(path)
    if not path.exists():
        return []
    d = json.loads(path.read_text())
    if d.get("schema_version") != SCHEMA_VERSION:
        raise ValueError(
            f"unsupported baseline schema {d.get('schema_version')!r} in "
            f"{path}; regenerate with --write-baseline")
    return list(d.get("fingerprints", []))


def save(path, report: Report) -> List[str]:
    """Snapshot the report's unsuppressed findings as the new baseline."""
    fps = sorted({f.fingerprint for f in report.active()})
    pathlib.Path(path).write_text(json.dumps(
        {"schema_version": SCHEMA_VERSION, "fingerprints": fps},
        indent=1) + "\n")
    return fps
