"""Abstract TPU-lowering contracts for Pallas kernel geometries.

This is a *leaf* module: pure Python, no jax / repro imports, so kernel
modules can build contract descriptions for the auditor without dragging in
the analysis package at kernel-import time (and the auditor can evaluate
thousands of geometry cells in milliseconds — shapes and dtypes only, no
tracing, no execution).

A `KernelGeometry` mirrors one concrete `pallas_call` invocation: the grid,
every operand's full shape / dtype / BlockSpec block + index map, the
scalar-prefetch operands, and scratch allocations. `check_geometry` decides,
per the Mosaic lowering rules in the TPU Pallas guide, whether that cell can
lower:

* ``grid-empty`` — a grid dimension is zero or negative.
* ``tile-misaligned`` — a VMEM block's (sublane, lane) dims are neither a
  multiple of the dtype's minimum tile — (8,128) f32/i32, (16,128) bf16,
  (32,128) int8 — nor the full array extent in that axis (Mosaic pads one
  trailing edge tile; arbitrary interior misalignment does not lower).
* ``block-divisibility`` — the array extent is not a multiple of the block
  extent in an axis the kernel does not mask in-kernel (``masked_axes``),
  so the remainder cells would read/write out of range.
* ``vmem-overflow`` — the per-cell footprint (streamed operands are
  double-buffered, ×2; grid-invariant operands are resident, ×1; scratch ×1)
  exceeds the VMEM budget.
* ``smem-illegal-dtype`` — a scalar-prefetch operand is not int32 (the only
  dtype the stack puts in SMEM).
* ``index-oob`` — an index map, enumerated over the grid (or its corners
  when the grid is large), returns a block index outside
  ``ceil(shape/block)`` in some axis.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

from .findings import Finding

# Minimum (sublane, lane) tile per dtype, from the TPU Pallas guide.
MIN_TILE: Dict[str, Tuple[int, int]] = {
    "float32": (8, 128),
    "int32": (8, 128),
    "uint32": (8, 128),
    "bfloat16": (16, 128),
    "float16": (16, 128),
    "int8": (32, 128),
    "uint8": (32, 128),
    "float8_e4m3fn": (32, 128),
    "float8_e5m2": (32, 128),
}

ITEMSIZE: Dict[str, int] = {
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2,
    "int8": 1, "uint8": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
}

DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024  # bytes per core

# Exhaustive index-map enumeration up to this many grid cells; beyond it the
# check falls back to the grid's corner cells.
_ENUM_CAP = 4096

SMEM_LEGAL_DTYPES = ("int32",)


@dataclasses.dataclass(frozen=True)
class OperandSpec:
    """One input/output of a `pallas_call`, as the BlockSpec sees it.

    ``block=None`` means the operand rides in whole (``memory_space='any'``,
    manually DMA'd by the kernel) and is exempt from VMEM blocking checks —
    its working-set cost must instead appear in ``KernelGeometry.scratch``.
    """
    name: str
    shape: Tuple[int, ...]
    dtype: str
    block: Optional[Tuple[int, ...]] = None
    index_map: Optional[Callable] = None
    memory_space: str = "vmem"          # 'vmem' | 'any' | 'smem'
    masked_axes: Tuple[int, ...] = ()   # remainder handled by in-kernel masking

    def block_bytes(self) -> int:
        if self.block is None:
            return 0
        return math.prod(self.block) * ITEMSIZE[self.dtype]


@dataclasses.dataclass(frozen=True)
class ScalarSpec:
    """A scalar-prefetch operand (lives in SMEM)."""
    name: str
    shape: Tuple[int, ...]
    dtype: str


@dataclasses.dataclass(frozen=True)
class KernelGeometry:
    """One concrete kernel launch geometry, ready for static checking."""
    kernel: str                              # dotted module-level name
    grid: Tuple[int, ...]
    operands: Tuple[OperandSpec, ...]        # inputs then outputs
    scalar_prefetch: Tuple[ScalarSpec, ...] = ()
    scratch_bytes: int = 0                   # VMEM scratch, already summed
    tag: str = ""                            # human-readable geometry id
    suppress: Dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def site(self) -> str:
        return f"{self.kernel}[{self.tag}]" if self.tag else self.kernel


class _ZeroRef:
    """Abstract stand-in for a scalar-prefetch ref inside an index map.

    Index maps may read scalar operands (``ref[i]``); statically we model
    every such read as 0, which matches the checks' conservative needs (the
    real values only *select* among in-range blocks in this codebase).
    """

    def __getitem__(self, _):
        return 0

    def __index__(self):
        return 0


def _call_index_map(index_map: Callable, cell: Tuple[int, ...]):
    try:
        params = inspect.signature(index_map).parameters
    except (TypeError, ValueError):
        return index_map(*cell)
    n_pos = 0
    has_var = False
    for p in params.values():
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            n_pos += 1
        elif p.kind == p.VAR_POSITIONAL:
            has_var = True
    if has_var or n_pos <= len(cell):
        return index_map(*cell)
    return index_map(*cell, *(_ZeroRef() for _ in range(n_pos - len(cell))))


def _grid_cells(grid: Sequence[int]):
    if math.prod(grid) <= _ENUM_CAP:
        return itertools.product(*(range(g) for g in grid))
    corners = [sorted({0, g - 1}) for g in grid]
    return itertools.product(*corners)


def _normalize(idx) -> Tuple[int, ...]:
    if isinstance(idx, tuple):
        return tuple(int(i) for i in idx)
    return (int(idx),)


def check_geometry(geom: KernelGeometry,
                   vmem_budget: int = DEFAULT_VMEM_BUDGET) -> list:
    """Statically check one geometry; returns a list of Findings.

    Each (rule, operand) pair yields at most one finding, so fixture tests
    and baselines stay stable as grids grow.
    """
    out = []

    def emit(rule, message, severity="error"):
        reason = geom.suppress.get(rule, "")
        out.append(Finding(
            tool="audit", rule=rule, severity=severity,
            path=geom.kernel, line=0, site=f"{geom.site}:{message_site}",
            message=message, suppressed=bool(reason),
            suppress_reason=reason))

    message_site = "grid"
    if any(g <= 0 for g in geom.grid) or not geom.grid:
        emit("grid-empty", f"grid {geom.grid} has a non-positive dimension")
        return out  # nothing else is meaningful on an empty grid

    resident: Dict[str, bool] = {}
    for op in geom.operands:
        message_site = op.name
        if op.block is None or op.memory_space != "vmem":
            continue
        if len(op.block) != len(op.shape):
            emit("block-divisibility",
                 f"block rank {len(op.block)} != array rank {len(op.shape)}")
            continue

        # --- (sublane, lane) tiling alignment -------------------------------
        tile = MIN_TILE.get(op.dtype)
        if tile is not None:
            checks = []
            if len(op.block) >= 2:
                checks = [(-2, tile[0]), (-1, tile[1])]
            elif len(op.block) == 1:
                checks = [(-1, tile[1])]
            bad = []
            for axis, t in checks:
                b, full = op.block[axis], op.shape[axis]
                if b % t != 0 and b != full:
                    bad.append(f"dim {axis}: {b} (min tile {t}, extent {full})")
            if bad:
                emit("tile-misaligned",
                     f"block {op.block} {op.dtype} not aligned to min tile "
                     f"{tile}: " + "; ".join(bad))

        # --- grid/block divisibility (unless masked in-kernel) --------------
        bad = [a for a in range(len(op.block))
               if op.shape[a] % op.block[a] != 0 and a not in op.masked_axes]
        if bad:
            emit("block-divisibility",
                 f"shape {op.shape} not divisible by block {op.block} in "
                 f"axes {bad} and kernel does not mask the remainder")

        # --- index-map bounds ----------------------------------------------
        if op.index_map is not None:
            n_blocks = tuple(-(-s // b) for s, b in zip(op.shape, op.block))
            seen = set()
            oob = None
            for cell in _grid_cells(geom.grid):
                idx = _normalize(_call_index_map(op.index_map, cell))
                seen.add(idx)
                if len(idx) != len(op.block):
                    oob = (cell, idx, "rank mismatch")
                    break
                if any(not (0 <= i < nb) for i, nb in zip(idx, n_blocks)):
                    oob = (cell, idx, f"limits {n_blocks}")
                    break
            if oob is not None:
                cell, idx, why = oob
                emit("index-oob",
                     f"index map returns block {idx} at grid cell {cell} "
                     f"({why})")
            resident[op.name] = len(seen) <= 1

    # --- per-cell VMEM footprint -------------------------------------------
    message_site = "vmem"
    footprint = geom.scratch_bytes
    parts = [f"scratch={geom.scratch_bytes}"] if geom.scratch_bytes else []
    for op in geom.operands:
        if op.block is None or op.memory_space != "vmem":
            continue
        mult = 1 if resident.get(op.name, op.index_map is None) else 2
        footprint += op.block_bytes() * mult
        parts.append(f"{op.name}={op.block_bytes()}x{mult}")
    if footprint > vmem_budget:
        emit("vmem-overflow",
             f"per-cell footprint {footprint}B exceeds VMEM budget "
             f"{vmem_budget}B ({', '.join(parts)})")

    # --- scalar prefetch dtypes --------------------------------------------
    for sp in geom.scalar_prefetch:
        message_site = sp.name
        if sp.dtype not in SMEM_LEGAL_DTYPES:
            emit("smem-illegal-dtype",
                 f"scalar-prefetch operand '{sp.name}' is {sp.dtype}; SMEM "
                 f"operands must be one of {SMEM_LEGAL_DTYPES}")

    return out


def scratch_bytes(*shapes_dtypes) -> int:
    """Sum VMEM scratch bytes for (shape, dtype) pairs."""
    return sum(math.prod(s) * ITEMSIZE[d] for s, d in shapes_dtypes)
