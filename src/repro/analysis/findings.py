"""Typed findings shared by the kernel auditor and the AST linter.

A `Finding` is one violation of one rule at one site. Both engines emit the
same shape so the CLI, the baseline, and CI consume a single stream:

* ``tool`` — which engine produced it (``"audit"`` | ``"lint"``).
* ``rule`` — stable kebab-case rule id (the catalog lives in docs/analysis.md).
* ``path`` — repo-relative source file (lint) or dotted kernel module (audit).
* ``line`` — 1-based source line (lint); 0 for geometry findings, which have
  no meaningful line.
* ``site`` — stable site id: the offending source snippet (lint) or the
  kernel + geometry cell (audit). Fingerprints hash (tool, rule, path, site)
  and deliberately *exclude* the line number, so a checked-in baseline
  survives unrelated edits that shift lines.
* ``suppressed`` — the finding matched an explicit per-site suppression
  (``# lint: allow(rule): reason`` comment, or a registry-level
  ``suppress={rule: reason}`` on a kernel contract). Suppressed findings are
  reported for transparency but never gate.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Iterable, List

SCHEMA_VERSION = 1

SEVERITIES = ("error", "warn")
TOOLS = ("audit", "lint")


@dataclasses.dataclass(frozen=True)
class Finding:
    tool: str
    rule: str
    severity: str
    path: str
    line: int
    site: str
    message: str
    suppressed: bool = False
    suppress_reason: str = ""

    def __post_init__(self):
        assert self.tool in TOOLS, self.tool
        assert self.severity in SEVERITIES, self.severity

    @property
    def fingerprint(self) -> str:
        h = hashlib.blake2b(digest_size=8)
        for part in (self.tool, self.rule, self.path, self.site):
            h.update(part.encode())
            h.update(b"\0")
        return h.hexdigest()

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Finding":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        tag = " [suppressed]" if self.suppressed else ""
        return (f"{loc}: {self.severity}: {self.tool}/{self.rule}{tag}: "
                f"{self.message}  ({self.site})")


@dataclasses.dataclass
class Report:
    """One analysis run: every finding (suppressed included) plus run metadata.

    ``active()`` is the gating stream: findings that are neither suppressed
    at the site nor present in the baseline.
    """
    findings: List[Finding] = dataclasses.field(default_factory=list)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def extend(self, fs: Iterable[Finding]) -> None:
        self.findings.extend(fs)

    def active(self, baseline_fingerprints: Iterable[str] = ()) -> List[Finding]:
        base = set(baseline_fingerprints)
        return [f for f in self.findings
                if not f.suppressed and f.fingerprint not in base]

    def to_dict(self, baseline_fingerprints: Iterable[str] = ()) -> Dict[str, Any]:
        new = self.active(baseline_fingerprints)
        return {
            "schema_version": SCHEMA_VERSION,
            "meta": dict(self.meta),
            "counts": {
                "total": len(self.findings),
                "suppressed": sum(f.suppressed for f in self.findings),
                "new": len(new),
            },
            "findings": [f.to_dict() for f in self.findings],
            "new_fingerprints": sorted(f.fingerprint for f in new),
        }

    def to_json(self, baseline_fingerprints: Iterable[str] = ()) -> str:
        return json.dumps(self.to_dict(baseline_fingerprints), indent=1,
                          sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Report":
        d = json.loads(text)
        if d.get("schema_version") != SCHEMA_VERSION:
            raise ValueError(f"unsupported report schema: "
                             f"{d.get('schema_version')!r}")
        return cls(findings=[Finding.from_dict(f) for f in d["findings"]],
                   meta=d.get("meta", {}))
