"""Kernel contract auditor: every `pallas_call` entry point, statically.

The four Pallas kernel modules each expose a ``tpu_contract`` hook that
mirrors their `pallas_call` geometry (grid, BlockSpecs, scalar prefetch,
scratch) as a pure-Python `contracts.KernelGeometry`. This module owns:

* **the registry** (`AUDITS`) — one geometry generator per kernel, spanning
  the grid `launch/autotune.py` and the serve engine can actually request
  (`audit()` runs every cell through `contracts.check_geometry`);
* **`gemm_block_plan`** — the TPU block picker for the GEMM kernels:
  `kernels.ops`' preference/alignment arithmetic, then shrink-until-clean
  through the lowering contract, so the TPU path never launches blocks the
  auditor rejects;
* **`prune_paged_plan`** — the same pruning for `autotune.paged_kernel_plan`
  (shrinks ``kv_chunk`` until the decode-geometry cell is statically clean).

Everything here is shape/dtype arithmetic — no tracing, no arrays — so a
full-repo audit is a tier-1-budget operation (see benchmarks `analysis_bench`).
"""
from __future__ import annotations

import math
from typing import Iterable, List, Optional, Tuple

from . import contracts
from .findings import Finding, Report

DEFAULT_VMEM_BUDGET = contracts.DEFAULT_VMEM_BUDGET

# MXU tile edge; mirrors kernels.ops._blocks' TPU alignment (a test pins the
# two against each other so they cannot drift)
MXU_ALIGN = 128


class ContractViolation(Exception):
    """A planner could not reach a statically-clean geometry."""

    def __init__(self, findings: List[Finding]):
        self.findings = findings
        super().__init__("; ".join(f.format() for f in findings))


def _blocks(dim: int, pref: int, align: int = MXU_ALIGN) -> int:
    if dim <= align:
        return dim if dim > 0 else align
    b = min(pref, dim)
    return max(align, (b // align) * align)


def _pad(dim: int, mult: int) -> int:
    return dim + (-dim) % mult


def _clean(geom, vmem_budget: int) -> List[Finding]:
    return [f for f in contracts.check_geometry(geom, vmem_budget)
            if not f.suppressed]


# ---------------------------------------------------------------------------
# GEMM block planning (delta / systolic / LUT kernels)
# ---------------------------------------------------------------------------

def _gemm_module(kernel: str):
    from repro.kernels import approx_gemm, delta_gemm, systolic_gemm
    return {
        "delta": delta_gemm, "approx_delta": delta_gemm,
        "systolic": systolic_gemm, "mxu_int8": systolic_gemm,
        "lut": approx_gemm, "approx_lut": approx_gemm,
    }[kernel]


def _gemm_contract(mod, m: int, n: int, k: int, bm: int, bn: int, bk: int,
                   rank: int, span: int):
    mp, np_, kp = _pad(m, bm), _pad(n, bn), _pad(k, bk)
    if mod.__name__.endswith("delta_gemm"):
        return mod.tpu_contract(mp, np_, kp, rank=rank, span=span,
                                bm=bm, bn=bn, bk=bk)
    if mod.__name__.endswith("approx_gemm"):
        return mod.tpu_contract(mp, np_, kp, span=span, bm=bm, bn=bn, bk=bk)
    return mod.tpu_contract(mp, np_, kp, bm=bm, bn=bn, bk=bk)


def gemm_block_plan(m: int, n: int, k: int, *, kernel: str = "delta",
                    rank: int = 21, span: int = 256,
                    prefs: Optional[Tuple[int, int, int]] = None,
                    vmem_budget: Optional[int] = None
                    ) -> Tuple[int, int, int]:
    """Pick TPU (bm, bn, bk) for a GEMM kernel, pruned through its contract.

    Starts from `kernels.ops`' preference/alignment arithmetic (``prefs``
    overrides the kernel's DEFAULT_B* preferences) and halves the largest
    MXU-aligned block until `contracts.check_geometry` reports the cell
    clean. Raises ContractViolation if even the minimum blocks cannot lower
    (misaligned-by-construction inputs — never the wrappers' output).
    """
    mod = _gemm_module(kernel)
    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    pm, pn, pk = prefs or (mod.DEFAULT_BM, mod.DEFAULT_BN, mod.DEFAULT_BK)
    bm = _blocks(m, pm)
    bn = _blocks(n, pn)
    bk = _blocks(k, pk)
    while True:
        fs = _clean(_gemm_contract(mod, m, n, k, bm, bn, bk, rank, span),
                    budget)
        if not fs:
            return bm, bn, bk
        # shrink the largest still-shrinkable block (stay MXU-aligned);
        # blocks at or below one MXU tile have nothing left to give
        cands = [(b, i) for i, b in enumerate((bm, bn, bk))
                 if b > MXU_ALIGN and b % MXU_ALIGN == 0]
        if not cands:
            raise ContractViolation(fs)
        _, which = max(cands)
        new = [bm, bn, bk]
        half = new[which] // 2
        new[which] = max(MXU_ALIGN, (half // MXU_ALIGN) * MXU_ALIGN)
        bm, bn, bk = new


# ---------------------------------------------------------------------------
# Paged-attention plan pruning (consumed by autotune.paged_kernel_plan)
# ---------------------------------------------------------------------------

def check_paged_geometry(kv_chunk: int, n_splits: int, *, max_len: int,
                         block_size: int, batch: int, kv_heads: int,
                         head_dim: int, q_per_kv: int = 1, q_len: int = 1,
                         n_pool: Optional[int] = None,
                         kv_dtype: str = "float32",
                         vmem_budget: Optional[int] = None) -> List[Finding]:
    """Findings for one paged-attention launch geometry (decode by default)."""
    from repro.kernels import paged_attention
    width = -(-max_len // block_size)
    n_pool = n_pool if n_pool is not None else width * batch + 1
    geom = paged_attention.tpu_contract(
        batch=batch, q_len=q_len, kv_heads=kv_heads, q_per_kv=q_per_kv,
        head_dim=head_dim, n_pool=n_pool, block_size=block_size,
        table_width=width, chunk=kv_chunk, q_chunk=max(q_len, 1),
        n_splits=n_splits, kv_dtype=kv_dtype)
    return _clean(geom, vmem_budget or DEFAULT_VMEM_BUDGET)


def prune_paged_plan(kv_chunk: int, n_splits: int, *, max_len: int,
                     block_size: int, batch: int, kv_heads: int,
                     head_dim: int, q_per_kv: int = 1,
                     n_pool: Optional[int] = None, kv_dtype: str = "float32",
                     vmem_budget: Optional[int] = None) -> Tuple[int, int]:
    """Shrink (kv_chunk, n_splits) until the decode cell is statically clean.

    The post-DMA-staging kernel's VMEM footprint is driven by the chunk-sized
    K/V scratch, so halving ``kv_chunk`` (kept a multiple of ``block_size``)
    strictly shrinks the cell; termination at ``kv_chunk == block_size``
    raises ContractViolation (a geometry no chunk size can lower — e.g. a
    single KV block over the budget).
    """
    width = -(-max_len // block_size)
    skv = width * block_size
    while True:
        fs = check_paged_geometry(
            kv_chunk, n_splits, max_len=max_len, block_size=block_size,
            batch=batch, kv_heads=kv_heads, head_dim=head_dim,
            q_per_kv=q_per_kv, n_pool=n_pool, kv_dtype=kv_dtype,
            vmem_budget=vmem_budget)
        if not fs:
            return kv_chunk, n_splits
        if kv_chunk <= block_size:
            raise ContractViolation(fs)
        half = kv_chunk // 2
        kv_chunk = max(block_size, half - half % block_size)
        nk = -(-skv // kv_chunk)
        n_splits = max(1, min(n_splits, nk))


def flash_kv_envelope(head_dim: int, *, dtype: str = "float32",
                      vmem_budget: Optional[int] = None) -> int:
    """Largest padded S_kv (multiple of 128) flash_attention can lower.

    The flash kernel holds a row's whole padded KV in VMEM per grid cell, so
    its context envelope is VMEM-bounded; beyond it callers must go through
    the paged kernel (whose footprint is chunk-sized). Documented in
    docs/analysis.md.
    """
    from repro.kernels import flash_attention
    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    skv = 128
    while True:
        nxt = skv * 2
        geom = flash_attention.tpu_contract(1, 1, 128, nxt, head_dim,
                                            dtype=dtype)
        if _clean(geom, budget):
            return skv
        skv = nxt


# ---------------------------------------------------------------------------
# Audit registry: the autotune/engine-reachable geometry grids
# ---------------------------------------------------------------------------

# (M, N, K) operating points the GEMM wrappers see: decode token rows,
# app-batch shapes (DCT/im2col pads), model layer shapes, the benchmark 512^3
# and 4096^3 ceilings
_GEMM_SHAPES = (
    (1, 256, 64), (8, 512, 256), (100, 100, 100), (256, 1024, 256),
    (512, 512, 512), (2048, 4096, 1024), (4096, 4096, 4096),
)
_DELTA_RANKS = (0, 1, 10, 21)


def _audit_gemm(kernel: str, vmem_budget: int) -> Iterable:
    mod = _gemm_module(kernel)
    ranks = _DELTA_RANKS if kernel == "delta" else (0,)
    for m, n, k in _GEMM_SHAPES:
        for rank in ranks:
            bm, bn, bk = gemm_block_plan(m, n, k, kernel=kernel, rank=rank,
                                         vmem_budget=vmem_budget)
            yield _gemm_contract(mod, m, n, k, bm, bn, bk, rank, 256)


# (B, H, Sq, Skv, D, dtype) cells for the flash prefill kernel, inside the
# VMEM envelope (see flash_kv_envelope); callers pad Sq/Skv to block multiples
_FLASH_GEOMS = (
    (1, 8, 128, 128, 64, "float32"),
    (4, 8, 512, 1024, 64, "float32"),
    (2, 16, 1024, 1024, 128, "float32"),
    (1, 32, 4096, 4096, 128, "float32"),
    (1, 8, 2048, 2048, 256, "float32"),
    (2, 16, 1024, 2048, 128, "bfloat16"),
)


def _audit_flash(vmem_budget: int) -> Iterable:
    from repro.kernels import flash_attention
    for b, h, sq, skv, d, dtype in _FLASH_GEOMS:
        yield flash_attention.tpu_contract(b, h, sq, skv, d, dtype=dtype)


# Paged serving operating points: (max_len, block_size, batch, kv_heads,
# q_per_kv, head_dim, q_len, kv_dtype, allow_splits). First row is the
# ServeEngine default geometry (max_slots=4, max_len=64, block_size=8); the
# rest cover the config families (gemma2/qwen GQA, 27B head widths) and the
# long-context split-KV mode at production pool sizes.
_PAGED_GEOMS = (
    (64, 8, 4, 4, 2, 64, 1, "float32", False),
    (64, 8, 4, 1, 8, 64, 16, "float32", False),     # chunked-prefill cell
    (1024, 16, 8, 8, 4, 128, 1, "float32", False),
    (4096, 16, 8, 8, 4, 128, 1, "float32", True),
    (4096, 16, 8, 16, 2, 128, 1, "int8", True),
    (8192, 32, 4, 8, 6, 256, 1, "float32", True),
    (32768, 16, 1, 8, 4, 128, 1, "float32", True),  # long-context single slot
)


def _audit_paged(vmem_budget: int) -> Iterable:
    from repro.kernels import paged_attention
    from repro.launch.autotune import paged_kernel_plan
    for (max_len, bs, batch, kh, g, d, q_len, kv_dtype,
         allow_splits) in _PAGED_GEOMS:
        kv_chunk, n_splits = paged_kernel_plan(
            max_len, bs, batch=batch, kv_heads=kh,
            allow_splits=allow_splits, head_dim=d, q_per_kv=g,
            kv_dtype=kv_dtype, vmem_budget=vmem_budget)
        width = -(-max_len // bs)
        yield paged_attention.tpu_contract(
            batch=batch, q_len=q_len, kv_heads=kh, q_per_kv=g, head_dim=d,
            n_pool=width * batch + 1, block_size=bs, table_width=width,
            chunk=kv_chunk, q_chunk=max(q_len, 1), n_splits=n_splits,
            kv_dtype=kv_dtype)


AUDITS = {
    "systolic_gemm": lambda budget: _audit_gemm("systolic", budget),
    "approx_gemm": lambda budget: _audit_gemm("lut", budget),
    "delta_gemm": lambda budget: _audit_gemm("delta", budget),
    "flash_attention": _audit_flash,
    "paged_attention": _audit_paged,
}


def audit(vmem_budget: Optional[int] = None) -> Report:
    """Audit every registered kernel over its reachable geometry grid."""
    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    report = Report(meta={"tool": "audit", "vmem_budget": budget,
                          "kernels": sorted(AUDITS)})
    cells = 0
    for name in sorted(AUDITS):
        for geom in AUDITS[name](budget):
            cells += 1
            report.extend(contracts.check_geometry(geom, budget))
    report.meta["cells"] = cells
    return report
