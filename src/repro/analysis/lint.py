"""AST invariant linter over ``src/repro/``.

Subsumes (and retires) the regex grep guard that used to live in
``tests/test_no_gemm_bypass.py``. Rules:

* ``gemm-bypass`` — in ``models/``, every GEMM over parameter leaves must
  route through ``core.gemm.dot``. ``jnp.matmul`` is banned outright;
  ``jnp.einsum`` only for the sanctioned activation/state contractions in
  ``SANCTIONED_EINSUMS``; ``@`` / ``jnp.dot`` / ``lax.dot_general`` only
  for the sanctioned gating projections in ``SANCTIONED_OPERATOR_GEMMS``.
* ``dot-layer`` — in ``models/``, every ``dot(...)`` / ``gemm.dot(...)``
  call must pass ``layer=`` so per-layer policy overrides can target it.
* ``host-sync-in-step`` — inside the jit-step functions built by
  ``launch/steps.py`` / ``launch/engine.py`` (the nested defs of
  ``make_*_step`` / ``_build_steps`` / ``_build_paged_steps`` /
  ``_build_multi_step`` — which includes the multi-step dispatcher and its
  ``lax.scan`` horizon body — plus any function passed to ``jax.jit`` or
  used as a ``jax.lax.scan`` body), no host transfers: ``.item()``,
  ``np.asarray``/``np.array``, ``jax.device_get``, ``.block_until_ready()``,
  or ``float()``/``int()``/``bool()`` on non-literal values.
* ``global-random`` — no stdlib ``random`` and no ``np.random.*`` module
  calls anywhere in ``src/repro/``; the one sanctioned idiom is an
  explicitly seeded generator (``np.random.default_rng(seed)`` /
  ``Generator`` / ``SeedSequence`` with at least one argument).
* ``prng-discipline`` — outside ``launch/sampling.py`` (home of the
  per-request fold-in idiom): ``jax.random.PRNGKey`` must take a literal
  seed, and one key expression must not feed two sampler calls in the same
  function (reuse correlates the streams).

Per-site suppression: append ``# lint: allow(rule): reason`` on the
offending line (or the line directly above). Suppressed findings are still
reported, flagged, and never gate.
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Tuple

from .findings import Finding

# ---------------------------------------------------------------------------
# Allowlists migrated verbatim from the retired grep guard
# (tests/test_no_gemm_bypass.py). Same semantics: (file name, equation) for
# einsums over activations/recurrent state, (file name, source fragment) for
# gating projections whose outputs select/modulate rather than carry the
# GEMM workload.
# ---------------------------------------------------------------------------
SANCTIONED_EINSUMS = {
    # flash attention scores / values (activation x activation)
    ("layers.py", "bkgqd,bkcd->bkgqc"),
    ("layers.py", "bkgqc,bkcd->bkgqd"),
    # Mamba2 SSD chunked recurrence (activations x recurrent state)
    ("ssm.py", "bihn,bjhn->bijh"),
    ("ssm.py", "bijh,bijh,bjh,bjhp->bihp"),
    ("ssm.py", "bihn,bhpn,bih->bihp"),
    ("ssm.py", "bjh,bjh,bjhp,bjhn->bhpn"),
    ("ssm.py", "bh,bhp,bhn->bhpn"),
    ("ssm.py", "bhn,bhpn->bhp"),
    # mLSTM chunked matrix-memory recurrence
    ("xlstm.py", "bihd,bjhd->bijh"),
    ("xlstm.py", "bijh,bijh,bjhd->bihd"),
    ("xlstm.py", "bihe,bhde,bih->bihd"),
    ("xlstm.py", "bijh,bijh->bih"),
    ("xlstm.py", "bihd,bhd,bih->bih"),
    ("xlstm.py", "bjh,bjhd,bjhe->bhde"),
    ("xlstm.py", "bjh,bjhd->bhd"),
}

SANCTIONED_OPERATOR_GEMMS = {
    ("moe.py", '@ p["router"]'),          # expert-routing logits
    ("xlstm.py", '@ p["w_if"]'),          # mLSTM input/forget gate pre-acts
    ("xlstm.py", "@ r_in.astype"),        # sLSTM recurrent gate pre-acts
}

# jit-step builder functions whose nested defs are the host-sync scope
# (make_multi_step — the fused-horizon dispatcher — matches make_\w*_step)
_STEP_BUILDER_RE = re.compile(
    r"^(make_\w*_step|_build_steps|_build_paged_steps|_build_multi_step)$")
_HOST_SYNC_FILES = ("launch/steps.py", "launch/engine.py")

_SAMPLER_FNS = {
    "normal", "uniform", "categorical", "bernoulli", "gumbel", "randint",
    "truncated_normal", "exponential", "laplace", "beta", "gamma", "choice",
    "permutation",
}

_SEEDED_NP_RANDOM = {"default_rng", "Generator", "SeedSequence"}

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([\w-]+)\)(?::\s*(.*))?")


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a call target ('jnp.matmul', 'dot', ...)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _snippet(src_lines: List[str], node: ast.AST) -> str:
    line = src_lines[node.lineno - 1].strip() if node.lineno <= len(src_lines) else ""
    return line[:160]


class _FileCtx:
    def __init__(self, root: pathlib.Path, path: pathlib.Path):
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        self.name = path.name
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))

    def suppression(self, line: int, rule: str) -> Optional[str]:
        """Reason string if `# lint: allow(rule)` covers this line, else None."""
        for ln in (line, line - 1):
            if 1 <= ln <= len(self.lines):
                m = _ALLOW_RE.search(self.lines[ln - 1])
                if m and m.group(1) == rule:
                    return (m.group(2) or "").strip() or "allowed"
        return None

    def finding(self, rule: str, node: ast.AST, message: str,
                site: Optional[str] = None) -> Finding:
        reason = self.suppression(node.lineno, rule)
        return Finding(
            tool="lint", rule=rule, severity="error", path=self.rel,
            line=node.lineno, site=site or _snippet(self.lines, node),
            message=message, suppressed=reason is not None,
            suppress_reason=reason or "")


# ---------------------------------------------------------------------------
# gemm-bypass + dot-layer (models/)
# ---------------------------------------------------------------------------

def _lint_models(ctx: _FileCtx, used_sanctions: set) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
            line = ctx.lines[node.lineno - 1]
            hit = next((frag for fn, frag in SANCTIONED_OPERATOR_GEMMS
                        if fn == ctx.name and frag in line), None)
            if hit is not None:
                used_sanctions.add((ctx.name, hit))
                continue
            yield ctx.finding(
                "gemm-bypass", node,
                "`@` GEMM bypasses GemmPolicy/bind — route through "
                "core.gemm.dot, or sanction a genuine gating projection in "
                "lint.SANCTIONED_OPERATOR_GEMMS")
            continue
        if not isinstance(node, ast.Call):
            continue
        target = _dotted(node.func)
        if target == "jnp.matmul":
            yield ctx.finding(
                "gemm-bypass", node,
                "jnp.matmul bypasses GemmPolicy/bind — route through "
                "core.gemm.dot(a, b, policy, layer=...)")
        elif target in ("jnp.dot", "lax.dot_general", "lax.dot"):
            line = ctx.lines[node.lineno - 1]
            hit = next((frag for fn, frag in SANCTIONED_OPERATOR_GEMMS
                        if fn == ctx.name and frag in line), None)
            if hit is not None:
                used_sanctions.add((ctx.name, hit))
                continue
            yield ctx.finding(
                "gemm-bypass", node,
                f"{target} bypasses GemmPolicy/bind — route through "
                "core.gemm.dot")
        elif target == "jnp.einsum":
            eq = None
            if node.args and isinstance(node.args[0], ast.Constant) \
                    and isinstance(node.args[0].value, str):
                eq = node.args[0].value
            if eq is not None and (ctx.name, eq) in SANCTIONED_EINSUMS:
                used_sanctions.add((ctx.name, eq))
                continue
            yield ctx.finding(
                "gemm-bypass", node,
                f"unsanctioned jnp.einsum({eq!r}) — parameter-leaf GEMMs "
                "must use core.gemm.dot; genuinely activation-only "
                "contractions go in lint.SANCTIONED_EINSUMS with "
                "justification",
                site=f"einsum:{eq}")
        elif target in ("dot", "gemm.dot") and not any(
                kw.arg == "layer" for kw in node.keywords):
            yield ctx.finding(
                "dot-layer", node,
                "dot(...) without layer= — per-layer GemmPolicy overrides "
                "cannot target an unnamed call site")


# ---------------------------------------------------------------------------
# host-sync-in-step (launch/steps.py, launch/engine.py)
# ---------------------------------------------------------------------------

def _jit_wrapped_names(tree: ast.Module) -> set:
    """Names of functions passed to jax.jit anywhere in the module, plus
    functions used as ``jax.lax.scan`` bodies — a scan body traced from
    inside a jit step (the multi-step horizon) is jit-step scope even when
    it is defined outside a recognized builder."""
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func) in (
                "jax.jit", "jit", "jax.lax.scan", "lax.scan"):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name):
                    names.add(arg.id)
    return names


def _lint_host_sync(ctx: _FileCtx) -> Iterable[Finding]:
    jit_names = _jit_wrapped_names(ctx.tree)

    def step_defs(node, inside_builder):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                is_step = (inside_builder or child.name in jit_names)
                is_builder = bool(_STEP_BUILDER_RE.match(child.name))
                if is_step:
                    yield child
                yield from step_defs(child, inside_builder or is_builder)
            else:
                yield from step_defs(child, inside_builder)

    seen_sites = set()                       # a nested step def is walked by
    for fn in step_defs(ctx.tree, False):    # its parent too: report once
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if (node.lineno, node.col_offset) in seen_sites:
                continue
            target = _dotted(node.func)
            attr = node.func.attr if isinstance(node.func, ast.Attribute) else ""
            msg = None
            if attr == "item":
                msg = ".item() forces a device sync inside a jit step"
            elif target in ("np.asarray", "np.array", "numpy.asarray",
                            "numpy.array"):
                msg = f"{target} pulls the array to host inside a jit step"
            elif target in ("jax.device_get", "device_get"):
                msg = "jax.device_get inside a jit step"
            elif attr == "block_until_ready":
                msg = ".block_until_ready() inside a jit step"
            elif target in ("float", "int", "bool") and node.args and not \
                    isinstance(node.args[0], ast.Constant):
                msg = (f"{target}() on a traced value concretizes it "
                       "(host sync) inside a jit step")
            if msg:
                seen_sites.add((node.lineno, node.col_offset))
                yield ctx.finding(
                    "host-sync-in-step", node,
                    f"{msg} — keep jit-step bodies device-only "
                    f"(step fn '{fn.name}')")


# ---------------------------------------------------------------------------
# global-random (all of src/repro/)
# ---------------------------------------------------------------------------

def _lint_global_random(ctx: _FileCtx) -> Iterable[Finding]:
    imports_stdlib_random = any(
        (isinstance(n, ast.Import) and any(a.name == "random" for a in n.names))
        or (isinstance(n, ast.ImportFrom) and n.module == "random")
        for n in ast.walk(ctx.tree))
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        target = _dotted(node.func)
        if imports_stdlib_random and target.startswith("random."):
            yield ctx.finding(
                "global-random", node,
                f"stdlib {target} draws from hidden global state — "
                "determinism requires an explicit seeded generator")
        elif target.startswith("np.random.") or target.startswith("numpy.random."):
            fn = target.rsplit(".", 1)[1]
            if fn in _SEEDED_NP_RANDOM and node.args:
                continue  # seeded generator construction: the sanctioned idiom
            if fn in _SEEDED_NP_RANDOM:
                yield ctx.finding(
                    "global-random", node,
                    f"np.random.{fn}() without a seed is entropy-seeded — "
                    "pass an explicit seed")
            else:
                yield ctx.finding(
                    "global-random", node,
                    f"{target} uses the global numpy RNG — use a seeded "
                    "np.random.default_rng(seed) instead")


# ---------------------------------------------------------------------------
# prng-discipline (src/repro/ minus launch/sampling.py)
# ---------------------------------------------------------------------------

def _lint_prng(ctx: _FileCtx) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and _dotted(node.func) in (
                "jax.random.PRNGKey", "random.PRNGKey", "jrandom.PRNGKey"):
            seed = node.args[0] if node.args else None
            literal = isinstance(seed, ast.Constant) or (
                isinstance(seed, ast.UnaryOp)
                and isinstance(seed.operand, ast.Constant))
            if not literal:
                yield ctx.finding(
                    "prng-discipline", node,
                    "PRNGKey with a non-literal seed — derive per-use keys "
                    "from a fixed root via fold_in/split "
                    "(launch/sampling.py idiom) so runs stay replayable")

    # key reuse: one key expression feeding >= 2 sampler calls in a function
    for fn in [n for n in ast.walk(ctx.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        seen: Dict[str, ast.Call] = {}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            target = _dotted(node.func)
            if not (target.startswith("jax.random.")
                    or target.startswith("jrandom.")):
                continue
            if target.rsplit(".", 1)[1] not in _SAMPLER_FNS or not node.args:
                continue
            key_src = ast.dump(node.args[0])
            if key_src in seen:
                yield ctx.finding(
                    "prng-discipline", node,
                    "PRNG key reused by a second sampler call in "
                    f"'{fn.name}' — split/fold_in a fresh key per draw "
                    "(reuse correlates the streams)")
            else:
                seen[key_src] = node


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lint_file(root: pathlib.Path, path: pathlib.Path,
              used_sanctions: Optional[set] = None) -> List[Finding]:
    ctx = _FileCtx(root, path)
    rel = ctx.rel
    used = used_sanctions if used_sanctions is not None else set()
    out: List[Finding] = []
    if "/models/" in f"/{rel}":
        out.extend(_lint_models(ctx, used))
    if any(rel.endswith(f) for f in _HOST_SYNC_FILES):
        out.extend(_lint_host_sync(ctx))
    out.extend(_lint_global_random(ctx))
    if not rel.endswith("launch/sampling.py"):
        out.extend(_lint_prng(ctx))
    return out


def lint_tree(root: pathlib.Path,
              subdir: str = "src/repro") -> Tuple[List[Finding], set]:
    """Lint every .py under root/subdir. Returns (findings, used_sanctions)."""
    root = pathlib.Path(root)
    used: set = set()
    findings: List[Finding] = []
    files = sorted((root / subdir).rglob("*.py"))
    assert files, f"no sources under {root / subdir}"
    for path in files:
        findings.extend(lint_file(root, path, used))
    return findings, used


def stale_sanctions(used: set) -> set:
    """Allowlist entries no longer matched by any source — prune with the code."""
    return (SANCTIONED_EINSUMS | SANCTIONED_OPERATOR_GEMMS) - used
