"""Paper §V application reproductions (Table VI), routed through GemmPolicy.

dct.py  — 8x8 integer DCT image compression (fixed T8 weights, both sides).
edge.py — kernel-based edge detection via im2col GEMM (fixed conv kernel).
bdcn.py — compact BDCN-style CNN with the paper's hybrid policy expressed as
          per-layer GemmPolicy overrides (approx early blocks, exact late).

Every app's ``run(..., policy=...)`` accepts a backend name or GemmPolicy;
fixed weights are prepared once (``core.gemm.prepare_weights_cached``) so the
weight-stationary backends amortize their precompute across all blocks/rows.
"""
from . import bdcn, dct, edge, images  # noqa: F401
