from . import bdcn, dct, edge, images  # noqa: F401
