"""CNN-based edge detection: compact BDCN-style bi-directional cascade (paper
§V-B, He et al. [17]) with the paper's hybrid policy — the first two blocks run
on approximate PEs, later blocks exact.

The paper uses a pretrained torch BDCN we cannot load offline; this is a compact
JAX re-implementation with fixed seeded weights whose first-layer filters are
edge-selective (Sobel/Laplacian banks), evaluated with the paper's methodology:
PSNR/SSIM of the hybrid-approximate network's edge map against the exact-
arithmetic edge map of the *same* network.

The hybrid is expressed as a ``GemmPolicy`` with per-layer overrides
(``hybrid_policy``): blocks ``block00``/``block01`` take the approximate
backend, later blocks resolve to exact integer GEMM. Each layer's quantized
weight matrix is fixed, so it is prepared once per (layer, k) and the
weight-stationary backends reuse the precompute across all H*W im2col rows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import errors, gemm, quant
from . import images

DEFAULT_BACKEND = "approx_lut"

_SOBELS = [
    np.array([[1, 0, -1], [2, 0, -2], [1, 0, -1]]),
    np.array([[1, 2, 1], [0, 0, 0], [-1, -2, -1]]),
    np.array([[0, 1, 2], [-1, 0, 1], [-2, -1, 0]]),
    np.array([[2, 1, 0], [1, 0, -1], [0, -1, -2]]),
    np.array([[0, 1, 0], [1, -4, 1], [0, 1, 0]]),
    np.array([[1, 1, 1], [1, -8, 1], [1, 1, 1]]),
]


def layer_name(li: int) -> str:
    """Zero-padded so prefix-matching overrides can't alias across blocks."""
    return f"block{li:02d}"


def hybrid_policy(k: int, backend: str = DEFAULT_BACKEND,
                  n_approx_blocks: int = 2,
                  n_blocks: int = 4) -> gemm.GemmPolicy:
    """The paper's hybrid as a GemmPolicy: approximate early blocks, exact
    later blocks (k=0 degenerates to exact everywhere)."""
    pol = gemm.as_policy(backend, k=k)    # validates the backend name
    if k == 0:
        return gemm.GemmPolicy(backend="exact", k=0)
    overrides = {layer_name(li): "exact"
                 for li in range(n_approx_blocks, n_blocks)}
    return dataclasses.replace(pol, overrides=overrides or None)


def make_weights(channels: List[int], seed: int = 0) -> List[np.ndarray]:
    """Conv stack weights (C_out, C_in, 3, 3), first layer edge-selective."""
    rng = np.random.default_rng(seed)
    ws = []
    c_prev = 1
    for li, c in enumerate(channels):
        w = rng.normal(0, (9 * c_prev) ** -0.5, size=(c, c_prev, 3, 3))
        if li == 0:
            for i in range(c):
                w[i, 0] = _SOBELS[i % len(_SOBELS)] * 0.25
        ws.append(w.astype(np.float32))
        c_prev = c
    return ws


def _im2col_nchw(x: np.ndarray) -> np.ndarray:
    from numpy.lib.stride_tricks import sliding_window_view
    c, h, w = x.shape
    xp = np.pad(x, ((0, 0), (1, 1), (1, 1)))
    v = sliding_window_view(xp, (3, 3), axis=(1, 2))    # (C, H, W, 3, 3)
    return v.transpose(1, 2, 0, 3, 4).reshape(h * w, c * 9)


def conv_layer(x: np.ndarray, w: np.ndarray, policy: gemm.GemmPolicy,
               layer: str = "") -> np.ndarray:
    """x: (C_in, H, W) float -> (C_out, H, W); int8-quantized GEMM under the
    layer's backend; ReLU applied."""
    c_out = w.shape[0]
    _, h, wd = x.shape
    cols = _im2col_nchw(x)                              # (H*W, C_in*9)
    wmat = w.reshape(c_out, -1).T                       # (C_in*9, C_out)
    xq = quant.quantize(np.asarray(cols))
    wq = quant.quantize(np.asarray(wmat), axis=0)
    prep = gemm.prepare_weights_cached(wq.values, policy, layer=layer)
    acc = np.asarray(gemm.dot(xq.values, prep, policy, layer=layer))
    out = acc.astype(np.float64) * np.asarray(xq.scale) * np.asarray(wq.scale)
    out = np.maximum(out, 0.0)                          # ReLU
    return out.T.reshape(c_out, h, wd).astype(np.float32)


def bdcn_forward(img: np.ndarray, ws: List[np.ndarray], k: int = None,
                 n_approx_blocks: int = 2, policy=None) -> np.ndarray:
    """Bi-directional cascade: shallow-to-deep and deep-to-shallow edge maps
    fused. With the default policy, blocks < n_approx_blocks use approximate
    arithmetic (the paper's hybrid); pass a ``GemmPolicy`` to override."""
    if policy is None or isinstance(policy, str):
        pol = hybrid_policy(0 if k is None else k,
                            backend=policy or DEFAULT_BACKEND,
                            n_approx_blocks=n_approx_blocks,
                            n_blocks=len(ws))
    else:
        pol = gemm.as_policy(policy, k=k)
    x = (img.astype(np.float32) - 128.0) / 128.0
    x = x[None]                                          # (1, H, W)
    side_maps = []
    for li, w in enumerate(ws):
        x = conv_layer(x, w, pol, layer=layer_name(li))
        side_maps.append(np.abs(x).mean(axis=0))         # side output per block
    # bi-directional fusion: forward cascade + backward cascade
    fwd = np.zeros_like(side_maps[0])
    for m in side_maps:
        fwd = 0.5 * fwd + m
    bwd = np.zeros_like(side_maps[0])
    for m in reversed(side_maps):
        bwd = 0.5 * bwd + m
    fused = fwd + bwd
    fused = 255.0 * fused / max(fused.max(), 1e-9)
    return np.clip(fused, 0, 255)


def run(size: int = 64, ks=(2, 4, 6, 8), seed: int = 0,
        channels=(8, 16, 16, 16), policy=None,
        n_approx_blocks: int = 2) -> Dict[int, Dict]:
    """``policy`` may be None / a backend name (hybrid per the paper: that
    backend on the first ``n_approx_blocks`` blocks, exact after) or a full
    ``GemmPolicy`` (used as-is, with k swept)."""
    img = images.test_image(size, seed)
    ws = make_weights(list(channels), seed)
    exact = bdcn_forward(img, ws, 0, n_approx_blocks, policy=policy)
    out = {}
    for k in ks:
        approx = bdcn_forward(img, ws, k, n_approx_blocks, policy=policy)
        out[k] = {"psnr": errors.psnr(exact, approx),
                  "ssim": errors.ssim(exact, approx)}
    return out
