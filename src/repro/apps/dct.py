"""8x8 integer-scaled DCT image compression through the approximate systolic GEMM
(paper §V-A; integer DCT per Meher et al. [18], HEVC T8 matrix).

Pipeline (all multiplies are 8-bit PE GEMMs):
  X (centered int8 block) -> T. X  (>>7, saturate int8) -> . T^T (>>7) = coeffs
  reconstruction uses the exact transpose pipeline; PSNR/SSIM measured against
  the exact-arithmetic output of the same pipeline, as in the paper.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import emulate, errors
from . import images

# HEVC-style 8x8 integer DCT matrix (fits signed 8-bit operands)
T8 = np.array([
    [64, 64, 64, 64, 64, 64, 64, 64],
    [89, 75, 50, 18, -18, -50, -75, -89],
    [83, 36, -36, -83, -83, -36, 36, 83],
    [75, -18, -89, -50, 50, 89, 18, -75],
    [64, -64, -64, 64, 64, -64, -64, 64],
    [50, -89, 18, 75, -75, -18, 89, 50],
    [36, -83, 83, -36, -36, 83, -83, 36],
    [18, -50, 89, -75, 75, -89, 50, -18]], dtype=np.int32)


def _gemm(a: np.ndarray, b: np.ndarray, k: int, *, fused: bool = True) -> np.ndarray:
    """Batched 8x8 approximate GEMM. `fused=True` chains the bit-level PE
    (faithful to the paper's fused-MAC simulation, including accumulator error);
    False uses the faster product-table model."""
    if fused:
        acc = np.zeros(a.shape[:-1] + (b.shape[-1],), np.int32)
        for kk in range(a.shape[-1]):
            acc = np.asarray(emulate.pe_mac(
                a[..., :, kk][..., :, None], b[..., kk, :][..., None, :], acc,
                n_bits=8, k=k, signed=True, acc_bits=24))
        return acc
    table = emulate.product_table(8, k, True, 24)
    return table[a[..., :, :, None] & 255, b[..., None, :, :] & 255].sum(axis=-2)


def _sat8(x: np.ndarray, shift: int) -> np.ndarray:
    return np.clip(x >> shift, -128, 127).astype(np.int32)


def forward_dct_blocks(blocks: np.ndarray, k: int) -> np.ndarray:
    """blocks: (N, 8, 8) uint8 -> (N, 8, 8) int coefficients via approx GEMM."""
    x = blocks.astype(np.int32) - 128
    t = np.broadcast_to(T8, x.shape)
    s1 = _sat8(_gemm(t, x, k), 7)                  # T . X, rescale to int8
    coeff = _gemm(s1, np.broadcast_to(T8.T.copy(), x.shape), k)
    return coeff


def inverse_dct_blocks(coeff: np.ndarray) -> np.ndarray:
    """Exact float inverse of the integer pipeline (shared by approx & exact).

    Forward was C = (T.X >> 7) . T^T  ~=  T.X.T^T / 128, so
    X = 128 * T^{-1} . C . (T^{-1})^T.
    """
    tinv = np.linalg.inv(T8.astype(np.float64))
    x = 128.0 * np.einsum("ij,njk,kl->nil", tinv, coeff.astype(np.float64),
                          tinv.T)
    return x + 128.0


def run(size: int = 256, ks=(0, 2, 4, 6, 8), seed: int = 0) -> Dict[int, Dict]:
    """Returns {k: {psnr, ssim}} of approx-DCT reconstruction vs exact-DCT
    reconstruction (the paper's methodology)."""
    img = images.test_image(size, seed)
    blocks = images.to_blocks(img)
    h = w = size
    recon = {}
    for k in ks:
        coeff = forward_dct_blocks(blocks, k)
        rec = inverse_dct_blocks(coeff)
        recon[k] = images.from_blocks(np.clip(rec, 0, 255), h, w)
    exact = recon.get(0)
    if exact is None:
        coeff = forward_dct_blocks(blocks, 0)
        exact = images.from_blocks(np.clip(inverse_dct_blocks(coeff), 0, 255), h, w)
    out = {}
    for k in ks:
        if k == 0:
            continue
        out[k] = {"psnr": errors.psnr(exact, recon[k]),
                  "ssim": errors.ssim(exact, recon[k])}
    return out
