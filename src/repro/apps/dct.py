"""8x8 integer-scaled DCT image compression through the approximate systolic GEMM
(paper §V-A; integer DCT per Meher et al. [18], HEVC T8 matrix).

Pipeline (all multiplies are 8-bit PE GEMMs routed through ``GemmPolicy``):
  X (centered int8 block) -> T. X  (>>7, saturate int8) -> . T^T (>>7) = coeffs
  reconstruction uses the exact transpose pipeline; PSNR/SSIM measured against
  the exact-arithmetic output of the same pipeline, as in the paper.

The DCT matrix is a fixed weight — the ideal weight-stationary case: its
rank-r delta factor (``approx_delta``) / one-hot table (``approx_onehot``) is
prepared once per k and reused by every 8x8 block of the image. ``T8``
multiplies from the *left* in the first stage; the approximate product table
is not symmetric, so the left/right operand roles are preserved end to end
(``gemm.prepare_weights(..., side="left")``).

Backends: the default ``approx_oracle`` chains the bit-level fused-MAC PE
(faithful to the paper's simulation including accumulator error); pass
``policy="approx_lut"`` for the product-table model or ``"approx_delta"`` for
the MXU-resident weight-stationary path (both bit-identical to each other).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import errors, gemm
from . import images

# HEVC-style 8x8 integer DCT matrix (fits signed 8-bit operands)
T8 = np.array([
    [64, 64, 64, 64, 64, 64, 64, 64],
    [89, 75, 50, 18, -18, -50, -75, -89],
    [83, 36, -36, -83, -83, -36, 36, 83],
    [75, -18, -89, -50, 50, 89, 18, -75],
    [64, -64, -64, 64, 64, -64, -64, 64],
    [50, -89, 18, 75, -75, -18, 89, 50],
    [36, -83, 83, -36, -36, 83, -83, 36],
    [18, -50, 89, -75, 75, -89, 50, -18]], dtype=np.int32)

# The paper's Table VI simulates the fused-MAC PE chain (incl. accumulator
# error), which is backend "approx_oracle" in the GemmPolicy registry.
DEFAULT_BACKEND = "approx_oracle"


def _sat8(x: np.ndarray, shift: int) -> np.ndarray:
    return np.clip(x >> shift, -128, 127).astype(np.int32)


def forward_dct_blocks(blocks: np.ndarray, k: int = None,
                       policy=None) -> np.ndarray:
    """blocks: (N, 8, 8) uint8 -> (N, 8, 8) int coefficients under the policy.

    ``policy`` may be None (paper-default backend at factor ``k``), a backend
    name, or a ``GemmPolicy``; ``k`` (when given) overrides the policy's
    approximation factor.
    """
    pol = gemm.as_policy(policy, backend=DEFAULT_BACKEND, k=k)
    x = blocks.astype(np.int32) - 128
    # T8 is the fixed weight of both stages: left operand of T.X, right
    # operand (transposed) of (T.X >> 7).T^T — prepared once per call batch.
    t_fwd = gemm.prepare_weights_cached(T8, pol, layer="dct.fwd", side="left")
    t_tr = gemm.prepare_weights_cached(T8.T, pol, layer="dct.fwd",
                                       side="right")
    s1 = _sat8(np.asarray(gemm.dot(t_fwd, x, pol, layer="dct.fwd")), 7)
    coeff = np.asarray(gemm.dot(s1, t_tr, pol, layer="dct.fwd"))
    return coeff


def inverse_dct_blocks(coeff: np.ndarray) -> np.ndarray:
    """Exact float inverse of the integer pipeline (shared by approx & exact).

    Forward was C = (T.X >> 7) . T^T  ~=  T.X.T^T / 128, so
    X = 128 * T^{-1} . C . (T^{-1})^T.
    """
    tinv = np.linalg.inv(T8.astype(np.float64))
    x = 128.0 * np.einsum("ij,njk,kl->nil", tinv, coeff.astype(np.float64),
                          tinv.T)
    return x + 128.0


def run(size: int = 256, ks=(0, 2, 4, 6, 8), seed: int = 0,
        policy=None) -> Dict[int, Dict]:
    """Returns {k: {psnr, ssim}} of approx-DCT reconstruction vs exact-DCT
    reconstruction (the paper's methodology) under the chosen backend."""
    pol = gemm.as_policy(policy, backend=DEFAULT_BACKEND)
    img = images.test_image(size, seed)
    blocks = images.to_blocks(img)
    h = w = size
    recon = {}
    for k in ks:
        coeff = forward_dct_blocks(blocks, k, policy=pol)
        rec = inverse_dct_blocks(coeff)
        recon[k] = images.from_blocks(np.clip(rec, 0, 255), h, w)
    exact = recon.get(0)
    if exact is None:
        coeff = forward_dct_blocks(blocks, 0, policy=pol)
        exact = images.from_blocks(np.clip(inverse_dct_blocks(coeff), 0, 255), h, w)
    out = {}
    for k in ks:
        if k == 0:
            continue
        out[k] = {"psnr": errors.psnr(exact, recon[k]),
                  "ssim": errors.ssim(exact, recon[k])}
    return out
