"""Kernel-based edge detection through the approximate systolic GEMM (paper §V-B).

The Laplacian convolution is lowered to im2col GEMM — (H*W, 9) x (9, 1) — and
executed with the approximate PE product-table model; output quality is measured
against the exact-arithmetic output of the identical pipeline.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import emulate, errors
from . import images

LAPLACIAN = np.array([[0, 1, 0], [1, -4, 1], [0, 1, 0]], dtype=np.int32)
LAPLACIAN8 = np.array([[1, 1, 1], [1, -8, 1], [1, 1, 1]], dtype=np.int32)


def im2col(img: np.ndarray, kh: int = 3, kw: int = 3) -> np.ndarray:
    from numpy.lib.stride_tricks import sliding_window_view
    v = sliding_window_view(img, (kh, kw))           # (H-2, W-2, 3, 3)
    return v.reshape(-1, kh * kw)


def conv_gemm(img: np.ndarray, kernel: np.ndarray, k: int) -> np.ndarray:
    """Approximate-GEMM convolution. img uint8 -> int32 response map."""
    h, w = img.shape
    cols = im2col(img.astype(np.int32) - 128)        # center into int8 range
    kflat = kernel.reshape(-1, 1)
    table = emulate.product_table(8, k, True, 24)
    out = table[cols & 255, kflat[None, :, 0] & 255].sum(axis=1)
    return out.reshape(h - 2, w - 2)


def edge_map(resp: np.ndarray) -> np.ndarray:
    mag = np.abs(resp).astype(np.float64)
    mag = 255.0 * mag / max(mag.max(), 1.0)
    return np.clip(mag, 0, 255)


def run(size: int = 256, ks=(2, 4, 6, 8), seed: int = 0,
        kernel: np.ndarray = LAPLACIAN) -> Dict[int, Dict]:
    img = images.test_image(size, seed)
    exact = edge_map(conv_gemm(img, kernel, 0))
    out = {}
    for k in ks:
        approx = edge_map(conv_gemm(img, kernel, k))
        out[k] = {"psnr": errors.psnr(exact, approx),
                  "ssim": errors.ssim(exact, approx)}
    return out
