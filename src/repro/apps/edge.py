"""Kernel-based edge detection through the approximate systolic GEMM (paper §V-B).

The Laplacian convolution is lowered to im2col GEMM — (H*W, 9) x (9, 1) — and
routed through ``GemmPolicy``; output quality is measured against the
exact-arithmetic output of the identical pipeline.

The convolution kernel is a fixed weight: it is prepared once per k
(``gemm.prepare_weights``) so the weight-stationary backends (``approx_delta``
rank-r factor, ``approx_onehot`` T_B) amortize their precompute across every
im2col row. The default ``approx_lut`` backend reproduces the paper's
product-table model bit-for-bit.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import errors, gemm
from . import images

LAPLACIAN = np.array([[0, 1, 0], [1, -4, 1], [0, 1, 0]], dtype=np.int32)
LAPLACIAN8 = np.array([[1, 1, 1], [1, -8, 1], [1, 1, 1]], dtype=np.int32)

DEFAULT_BACKEND = "approx_lut"


def im2col(img: np.ndarray, kh: int = 3, kw: int = 3) -> np.ndarray:
    from numpy.lib.stride_tricks import sliding_window_view
    v = sliding_window_view(img, (kh, kw))           # (H-2, W-2, 3, 3)
    return v.reshape(-1, kh * kw)


def conv_gemm(img: np.ndarray, kernel: np.ndarray, k: int,
              policy=None) -> np.ndarray:
    """Approximate-GEMM convolution under the policy. img uint8 -> int32
    response map."""
    pol = gemm.as_policy(policy, backend=DEFAULT_BACKEND, k=k)
    h, w = img.shape
    cols = im2col(img.astype(np.int32) - 128)        # center into int8 range
    kflat = kernel.reshape(-1, 1)
    prep = gemm.prepare_weights_cached(kflat, pol, layer="edge.conv")
    out = np.asarray(gemm.dot(cols, prep, pol, layer="edge.conv"))
    return out[:, 0].reshape(h - 2, w - 2)


def edge_map(resp: np.ndarray) -> np.ndarray:
    mag = np.abs(resp).astype(np.float64)
    mag = 255.0 * mag / max(mag.max(), 1.0)
    return np.clip(mag, 0, 255)


def run(size: int = 256, ks=(2, 4, 6, 8), seed: int = 0,
        kernel: np.ndarray = LAPLACIAN, policy=None) -> Dict[int, Dict]:
    pol = gemm.as_policy(policy, backend=DEFAULT_BACKEND)
    img = images.test_image(size, seed)
    exact = edge_map(conv_gemm(img, kernel, 0, policy=pol))
    out = {}
    for k in ks:
        approx = edge_map(conv_gemm(img, kernel, k, policy=pol))
        out[k] = {"psnr": errors.psnr(exact, approx),
                  "ssim": errors.ssim(exact, approx)}
    return out
