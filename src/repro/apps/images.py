"""Synthetic test images (offline container: no image files). Deterministic
photo-like composites — smooth gradients, shapes, texture — so DCT/edge results
are reproducible."""
from __future__ import annotations

import numpy as np


def test_image(size: int = 256, seed: int = 0) -> np.ndarray:
    """uint8 grayscale (size, size) with edges, gradients and texture."""
    rng = np.random.default_rng(seed)
    y, x = np.mgrid[0:size, 0:size].astype(np.float64) / size
    img = 96 + 80 * x + 40 * np.sin(6.28 * 2 * y)
    # shapes (hard edges)
    cy, cx, r = 0.35, 0.4, 0.18
    img = np.where((y - cy) ** 2 + (x - cx) ** 2 < r ** 2, 210.0, img)
    img = np.where((np.abs(y - 0.7) < 0.12) & (np.abs(x - 0.65) < 0.2), 40.0, img)
    tri = (x + y > 1.35) & (x - y < 0.2)
    img = np.where(tri, 160.0, img)
    # texture + noise
    img += 8 * np.sin(6.28 * 16 * x) * np.sin(6.28 * 16 * y)
    img += rng.normal(0, 3, (size, size))
    return np.clip(img, 0, 255).astype(np.uint8)


def to_blocks(img: np.ndarray, n: int = 8) -> np.ndarray:
    h, w = img.shape
    hb, wb = h // n, w // n
    return (img[: hb * n, : wb * n]
            .reshape(hb, n, wb, n).transpose(0, 2, 1, 3).reshape(-1, n, n))


def from_blocks(blocks: np.ndarray, h: int, w: int, n: int = 8) -> np.ndarray:
    hb, wb = h // n, w // n
    return (blocks.reshape(hb, wb, n, n).transpose(0, 2, 1, 3)
            .reshape(hb * n, wb * n))
