"""Sharded checkpointing: npz payload shards + JSON manifest.

Design (works with any device count — elastic reshard on restore):
* Every leaf is saved in *global* (unsharded) layout, chunked into `shard_mb`
  pieces so hosts stream without 2x peak memory; the manifest stores the tree
  structure, dtypes, shapes and a content checksum.
* `save_async` runs serialization on a background thread (training continues;
  `wait()` joins before the next save — one checkpoint in flight).
* Restore reads the manifest, reassembles leaves, and `jax.device_put`s them to
  the *current* mesh's shardings — a different pod count or mesh shape than the
  writer's is fine (that is the elastic-rescaling path).
* Retention: keep the newest `keep` checkpoints, atomic rename on completion so
  a crash mid-save never corrupts the latest good step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree: PyTree):
    out = []

    def go(path, _leaf):
        keys = []
        for p in path:
            keys.append(str(getattr(p, "key", getattr(p, "idx", p))))
        out.append("/".join(keys))
    jax.tree_util.tree_map_with_path(go, tree)
    return out


def save(step: int, tree: PyTree, directory: str, *, keep: int = 3) -> str:
    """Synchronous save. Returns the checkpoint path."""
    tmp = os.path.join(directory, f"step_{step:09d}.tmp")
    final = os.path.join(directory, f"step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)
    leaves, _ = _flatten(tree)
    paths = _paths(tree)
    manifest = {"step": step, "leaves": []}
    arrays = {}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        logical = str(arr.dtype)
        if logical == "bfloat16":            # npz has no bf16: store a u16 view
            arr = arr.view(np.uint16)
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"].append({
            "path": p, "key": key, "shape": list(arr.shape),
            "dtype": logical,
            "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
        })
    np.savez(os.path.join(tmp, "payload.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep)
    return final


class AsyncCheckpointer:
    """One in-flight background save; `wait()` before the next or at exit."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: PyTree):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before mutation

        def work():
            save(step, host_tree, self.directory, keep=self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, tree_like: PyTree, *, step: Optional[int] = None,
            shardings: Optional[PyTree] = None, verify: bool = True) -> PyTree:
    """Restore into the structure of `tree_like`; device_put to `shardings`
    (current mesh) if given — elastic reshard happens here."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    payload = np.load(os.path.join(path, "payload.npz"))
    by_path = {e["path"]: e for e in manifest["leaves"]}
    want_paths = _paths(tree_like)
    leaves, treedef = _flatten(tree_like)
    out = []
    for p, leaf in zip(want_paths, leaves):
        e = by_path[p]
        arr = payload[e["key"]]
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
            if crc != e["crc"]:
                raise IOError(f"checksum mismatch for {p} in step {step}")
        if e["dtype"] == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {p}: ckpt {arr.shape} vs "
                             f"model {np.shape(leaf)}")
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


def _retain(directory: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"), ignore_errors=True)
