from .base import ModelConfig, ShapeSpec, lm_shapes  # noqa: F401
from .registry import ARCHS, get, reduced  # noqa: F401
