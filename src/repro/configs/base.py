"""Config system: architecture + input-shape cells (--arch / --shape selectable)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str              # train_4k | prefill_32k | decode_32k | long_500k
    kind: str              # train | prefill | decode
    seq_len: int
    global_batch: int
    skip: bool = False     # per-arch skip (encoder-only decode, quadratic 500k)
    skip_reason: str = ""


def lm_shapes(*, decode_ok: bool = True, long_ok: bool = False,
              long_reason: str = "full attention is quadratic at 500k",
              decode_reason: str = "encoder-only arch has no decode step"):
    return (
        ShapeSpec("train_4k", "train", 4096, 256),
        ShapeSpec("prefill_32k", "prefill", 32768, 32),
        ShapeSpec("decode_32k", "decode", 32768, 128,
                  skip=not decode_ok, skip_reason=decode_reason),
        ShapeSpec("long_500k", "decode", 524288, 1,
                  skip=(not decode_ok) or (not long_ok),
                  skip_reason=decode_reason if not decode_ok else long_reason),
    )


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    causal: bool = True             # False for encoder-only
    # sliding-window pattern: every `global_every`-th layer is global; others use
    # `window_size` (0 = all layers full attention)
    window_size: int = 0
    global_every: int = 0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    n_active_experts: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM / recurrent
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0             # zamba2: one shared attn block per N mamba blocks
    slstm_every: int = 0            # xlstm: every N-th block is sLSTM
    # modality frontend stub (audio/vlm): inputs are precomputed embeddings
    embed_inputs: bool = False
    prefix_len_frac: float = 0.0    # vlm: fraction of seq that is patch embeddings
    tie_embeddings: bool = False
    act: str = "silu"
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    shapes: Tuple[ShapeSpec, ...] = ()
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name} has no shape {name}")

    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm",):
            per = _xlstm_block_params(self)
            blocks = self.n_layers * per
        elif self.family == "hybrid":
            blocks = _zamba_params(self)
        else:
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            if self.is_moe:
                ffn = self.n_experts * 3 * d * self.moe_d_ff \
                    + self.n_shared_experts * 3 * d * self.moe_d_ff \
                    + d * self.n_experts
            else:
                ffn = 3 * d * self.d_ff
            blocks = self.n_layers * (attn + ffn + 2 * d)
        return emb + blocks + d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.n_active_experts) \
            * 3 * d * self.moe_d_ff
        return total - inactive


def _xlstm_block_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    # mLSTM: up/gate/down projections + qkv + gates
    return 2 * d * di + di * d + 3 * di * di // 4 + 3 * di


def _zamba_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n_attn = cfg.n_layers // max(1, cfg.attn_every)
    mamba = cfg.n_layers * (2 * d * di + di * d + di * (2 * cfg.ssm_state) + di)
    attn = 4 * d * d + 3 * d * cfg.d_ff  # one shared block, counted once
    return mamba + attn + n_attn * 2 * d
