"""gemma2-27b — local+global alternating attention with logit softcaps
[arXiv:2408.00118; hf]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab_size=256000, head_dim=128,
    window_size=4096, global_every=2,   # alternating local / global
    attn_softcap=50.0, final_softcap=30.0, tie_embeddings=True,
    # half the layers are full-attention global -> 500k decode cache dominated by
    # them; treated as full-attention for the long_500k skip rule
    shapes=lm_shapes(long_ok=False,
                     long_reason="23/46 layers are global full attention"),
    source="arXiv:2408.00118",
)
