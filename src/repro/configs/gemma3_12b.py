"""gemma3-12b — dense GQA, 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab_size=262144, head_dim=256, rope_theta=1e6,
    window_size=1024, global_every=6,   # 5 local : 1 global
    tie_embeddings=True,
    # 5/6 of layers have O(W) caches; global layers hold a sharded 500k KV and
    # decode is O(S) per token -> runnable (DESIGN.md §4)
    shapes=lm_shapes(long_ok=True),
    source="hf:google/gemma-3-1b-pt",
)
