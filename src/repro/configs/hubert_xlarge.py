"""hubert-xlarge — encoder-only audio transformer (w2v2 arch) [arXiv:2106.07447]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504, causal=False, embed_inputs=True, act="gelu",
    shapes=lm_shapes(decode_ok=False),   # encoder-only: no decode shapes
    source="arXiv:2106.07447",
)
