"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=163840, head_dim=128,
    n_experts=64, n_active_experts=6, moe_d_ff=1408, n_shared_experts=2,
    shapes=lm_shapes(long_ok=False),
    source="hf:moonshotai/Moonlight-16B-A3B",
)
