"""pixtral-12b — pixtral-ViT (stubbed frontend) + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=131072, head_dim=128, rope_theta=1e6,
    prefix_len_frac=0.25,   # leading quarter of the sequence is patch embeddings
    shapes=lm_shapes(long_ok=False),
    source="hf:mistralai/Pixtral-12B-2409",
)
