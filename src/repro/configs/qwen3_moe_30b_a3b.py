"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab_size=151936, head_dim=128, rope_theta=1e6,
    n_experts=128, n_active_experts=8, moe_d_ff=768,
    shapes=lm_shapes(long_ok=False),
    source="hf:Qwen/Qwen3-30B-A3B",
)
