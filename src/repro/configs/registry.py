"""Architecture registry: --arch <id> resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict

from .base import ModelConfig
from . import (gemma2_27b, gemma3_12b, hubert_xlarge, moonshot_v1_16b_a3b,
               pixtral_12b, qwen2_5_14b, qwen3_moe_30b_a3b, smollm_360m,
               xlstm_350m, zamba2_1_2b)

_MODULES = (qwen2_5_14b, smollm_360m, gemma3_12b, gemma2_27b, xlstm_350m,
            moonshot_v1_16b_a3b, qwen3_moe_30b_a3b, zamba2_1_2b, hubert_xlarge,
            pixtral_12b)

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests (few layers, tiny dims)."""
    upd = dict(
        n_layers=max(2, (cfg.attn_every or cfg.slstm_every or cfg.global_every or 2)),
        d_model=64,
        n_heads=max(2, min(4, cfg.n_heads)),
        n_kv_heads=max(1, min(2, cfg.n_kv_heads)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window_size=8 if cfg.window_size else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_expand=cfg.ssm_expand,
    )
    if cfg.is_moe:
        upd.update(n_experts=4, n_active_experts=2, moe_d_ff=32,
                   n_shared_experts=min(1, cfg.n_shared_experts))
    if cfg.attn_every:
        upd.update(attn_every=2, n_layers=4)
    if cfg.slstm_every:
        upd.update(slstm_every=2, n_layers=4)
    if cfg.global_every:
        upd.update(global_every=2, n_layers=4)
    return dataclasses.replace(cfg, **upd)
