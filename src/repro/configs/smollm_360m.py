"""smollm-360m — llama-arch small dense GQA [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab_size=49152, head_dim=64, tie_embeddings=True,
    shapes=lm_shapes(long_ok=False),
    source="hf:HuggingFaceTB/SmolLM-135M",
)
