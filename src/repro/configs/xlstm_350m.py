"""xlstm-350m — sLSTM + mLSTM blocks (linear-time recurrent) [arXiv:2405.04517]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, ssm_expand=2, slstm_every=6,  # every 6th block sLSTM
    shapes=lm_shapes(long_ok=True, long_reason=""),  # linear-time: runnable
    source="arXiv:2405.04517",
)
