"""zamba2-1.2b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf]."""
from .base import ModelConfig, lm_shapes

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, ssm_state=64, ssm_conv=4, ssm_expand=2,
    attn_every=6,   # one shared attention block per 6 mamba blocks
    shapes=lm_shapes(long_ok=True, long_reason=""),  # SSM state: runnable
    source="arXiv:2411.15242",
)
