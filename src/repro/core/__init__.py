"""Core: the paper's contribution — exact/approximate systolic-array GEMM.

Submodules: pe (Table I cells), emulate (bit-level fused MAC + GEMM oracle),
lut (fast functional model + one-hot MXU trick), error_delta (exact-plus-delta
low-rank decomposition of the approximate product), systolic (cycle-accurate
SA), errors (NMED/MRED/PSNR/SSIM), energy (analytical model from paper tables),
quant (int8 symmetric quantization), gemm (backend registry / the unified
`dot` entry point + `bind` for weight-stationary bound parameter pytrees).
"""
from . import emulate, energy, error_delta, errors, gemm, lut, pe, quant, systolic  # noqa: F401
from .gemm import EXACT, BoundParams, GemmPolicy, bind, dot  # noqa: F401
