"""Core: the paper's contribution — exact/approximate systolic-array GEMM.

Submodules: pe (Table I cells), emulate (bit-level fused MAC + GEMM oracle),
lut (fast functional model + one-hot MXU trick), error_delta (exact-plus-delta
low-rank decomposition of the approximate product), systolic (cycle-accurate
SA), errors (NMED/MRED/PSNR/SSIM), energy (analytical model from paper tables),
quant (int8 symmetric quantization), gemm (backend registry / sa_dot).
"""
from . import emulate, energy, error_delta, errors, gemm, lut, pe, quant, systolic  # noqa: F401
from .gemm import EXACT, GemmPolicy, int_matmul, sa_dot  # noqa: F401
