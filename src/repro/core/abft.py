"""Algorithm-based fault tolerance (ABFT) for the GEMM path.

The paper's energy savings come from aggressively simplified PE cells —
exactly the regime (voltage/precision-scaled systolic hardware) where soft
errors and stuck-at faults appear. A deployment must then distinguish
*intended* approximation error from *actual* faults. This module provides the
detection substrate `gemm.dot` uses when ``GemmPolicy.guard`` is
``'detect'`` or ``'recompute'`` (see docs/serving.md "Reliability"):

* **Weight-integrity checksum vectors** (the canonical systolic-array ABFT):
  ``prepare_weights`` attaches row/column sums of the quantized weight matrix
  (plus a bit-level fingerprint of the derived backend tables — delta
  factors, one-hot ``T_B``, dequant scale) to every ``PreparedOperand``.
  ``dot`` re-reduces the runtime operand and compares **exactly** (integer
  arithmetic, threshold 0): any bit flip in a bound weight leaf that changes
  the value the kernels consume is flagged, for every backend, with zero
  false positives.
* **Output checksums**: ``sum_j C_ij`` is compared against ``(A @ Be)_i``
  (and ``sum_i C_ij`` against ``(e^T A @ B)_j``) computed by exact matvecs.
  For exact integer backends the comparison threshold is 0. For approximate
  backends the threshold is the *sound approximation envelope* derived from
  the quantization/approximation bounds: each approximate product deviates
  from exact by at most ``max |E_k|`` (the error table's max, exact per
  (n_bits, k)), so a row checksum over N outputs of K-deep dots deviates by
  at most ``N*K*max|E_k|``. Intended approximation error therefore **never**
  false-positives; a fault is flagged when it pushes a checksum outside the
  envelope.
* **Table integrity**: the device-resident product/factor tables (uploaded
  once, shared by all calls — the model for on-chip LUT SRAM) are compared
  bit-for-bit against a freshly built host golden copy.
* **Memory fingerprints** (`tree_fingerprint`/`verify_fingerprint`): bitcast
  sums per pytree leaf, used by the serve engine to scrub bound params and
  the paged KV pool between steps.

Checksum arithmetic note: int32 sums may wrap, but wrapping is exact mod
2^32 on both sides of each comparison; a clean run's true deviation is below
the (< 2^31) threshold, so the signed wrapped difference equals the true
difference and false positives remain impossible. A fault aliasing to within
the envelope mod 2^32 is the only theoretical escape.

Detection is reported through a **fault ledger**: inside traced code (the
jitted serve-engine steps) a mismatch cannot raise, so it is recorded via
``jax.debug.callback``; the engine drains the ledger after its per-step
device sync and runs its quarantine/restore/replay protocol. Eager callers
(apps, tests) get a synchronous ``AbftFaultError``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

GUARDS = ("none", "detect", "recompute")

# Cap thresholds below int31 so signed wrapped differences stay ordered.
_THRESHOLD_CAP = 1 << 30


class AbftFaultError(RuntimeError):
    """A guarded GEMM (or an engine scrub) detected a fault.

    ``faults`` holds the `Fault` records that triggered the error; the
    message summarizes the first few.
    """

    def __init__(self, faults: Sequence["Fault"]):
        self.faults = list(faults)
        head = "; ".join(str(f) for f in self.faults[:4])
        more = f" (+{len(self.faults) - 4} more)" if len(self.faults) > 4 else ""
        super().__init__(f"ABFT fault detected: {head}{more}")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One detected integrity violation.

    ``substep`` attributes a fault recorded inside a fused multi-step
    horizon (`launch.steps.make_multi_step`) to the scan sub-step that
    produced it; ``None`` for per-step detections.
    """
    layer: str
    kind: str            # "weight" | "table" | "output" | "memory" | "aux"
    deviation: float
    threshold: float
    substep: Optional[int] = None

    def __str__(self) -> str:
        sub = f" substep={self.substep}" if self.substep is not None else ""
        return (f"[{self.kind}] layer={self.layer!r} deviation={self.deviation}"
                f" > threshold={self.threshold}{sub}")


# --------------------------------------------------------------------------
# Fault ledger: the traced-code escape hatch
# --------------------------------------------------------------------------

_LEDGER: List[Fault] = []

# Stack of active sub-step tags (traced or concrete) — see `substep`.
_SUBSTEP: List[Any] = []


@contextlib.contextmanager
def substep(idx):
    """Tag every fault recorded in this scope with a horizon sub-step index.

    ``idx`` may be a *traced* value (the multi-step dispatcher's scan
    iteration index): it rides into the fault ledger through the same
    ``jax.debug.callback`` as the deviation, so a fault detected inside a
    fused ``n``-step dispatch is attributed to the exact sub-step that
    produced it (`Fault.substep`).
    """
    _SUBSTEP.append(idx)
    try:
        yield
    finally:
        _SUBSTEP.pop()


def _record_cb(dev, sub=None, *, layer: str, kind: str,
               threshold: float) -> None:
    d = float(dev)
    if d > threshold:
        _LEDGER.append(Fault(layer, kind, d, threshold,
                             substep=None if sub is None else int(sub)))


def record(dev, *, layer: str, kind: str, threshold: float = 0.0) -> None:
    """Record a deviation (fault iff dev > threshold).

    Traced values are routed through ``jax.debug.callback`` (the host-side
    append happens when the step actually executes); concrete values append
    immediately. An active `substep` tag is forwarded alongside the
    deviation.
    """
    sub = _SUBSTEP[-1] if _SUBSTEP else None
    if isinstance(dev, jax.core.Tracer) or isinstance(sub, jax.core.Tracer):
        cb = functools.partial(_record_cb, layer=layer, kind=kind,
                               threshold=threshold)
        if sub is None:
            jax.debug.callback(cb, dev)
        else:
            jax.debug.callback(cb, dev, sub)
    else:
        _record_cb(dev, sub, layer=layer, kind=kind, threshold=threshold)


def drain_faults() -> List[Fault]:
    """Flush pending device callbacks and return (and clear) the ledger."""
    jax.effects_barrier()
    out = list(_LEDGER)
    _LEDGER.clear()
    return out


def clear_faults() -> None:
    jax.effects_barrier()
    _LEDGER.clear()


# --------------------------------------------------------------------------
# Thresholds from the approximation's error bounds
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def max_error_distance(n_bits: int = 8, k: int = 4, acc_bits: int = 24,
                       signed: bool = True) -> int:
    """Exact max |approx - exact| per product (the error table's max)."""
    if k <= 0:
        return 0
    from . import error_delta
    return int(np.abs(error_delta.error_table(n_bits, k, signed,
                                              acc_bits)).max())


def _per_product_bound(policy, backend: str) -> int:
    med = max_error_distance(policy.n_bits, policy.k, policy.acc_bits)
    if backend in ("mxu_int8", "exact"):
        return 0
    if backend == "approx_oracle":
        # the fused MAC chain also runs the *accumulator's* bits through the
        # approximate columns (< k), which the per-product table cannot see:
        # the approximate region's value error is < 2^k and each of the
        # ~n_bits absorbed rows can lose/gain one carry into column k, so
        # bound the extra per-MAC deviation by (n_bits + 3) * 2^k
        med = max(med, (policy.n_bits + 3) << policy.k)
    if (backend == "approx_delta"
            and (policy.delta_rank is not None or policy.delta_tol is not None)):
        # truncated correction: bounded extra error on top of the table's
        from . import error_delta
        fac = error_delta.delta_factors(policy.n_bits, policy.k, True,
                                        policy.acc_bits,
                                        rank=policy.delta_rank,
                                        tol=policy.delta_tol)
        med += int(np.ceil(fac.max_err)) + 1
    return med


def int_thresholds(policy, backend: str, a_shape, b_shape) -> Tuple[int, int]:
    """(row, col) output-checksum thresholds for an (M,K)x(K,N) int GEMM.

    Row checksums sum N outputs, col checksums sum M outputs; each output is
    a K-deep dot whose per-product approximation error is bounded by
    ``max_error_distance`` — the sound envelope intended approximation can
    reach and a detectable fault must exceed.
    """
    m, kd = a_shape[-2], a_shape[-1]
    n = b_shape[-1]
    med = _per_product_bound(policy, backend)
    return (min(kd * n * med, _THRESHOLD_CAP), min(kd * m * med, _THRESHOLD_CAP))


# --------------------------------------------------------------------------
# Prepared-operand checksum metadata
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AbftMeta:
    """Clean-weight checksums attached to a ``PreparedOperand`` at bind time.

    ``row``/``col`` are the last-axis / second-to-last-axis sums of the
    quantized integer values (leading stack dims preserved so bound stacks
    still ride ``lax.scan``/``vmap``); ``aux`` is a bitcast fingerprint of
    every *derived* leaf of the prepared operand (delta factors, one-hot
    table, dequant scale) reduced to the stack dims.
    """
    row: jnp.ndarray     # (..., K) int32 — sum over the last axis
    col: jnp.ndarray     # (..., N) int32 — sum over the second-to-last axis
    aux: jnp.ndarray     # (...,) uint32 — fingerprint of derived leaves


jax.tree_util.register_pytree_node(
    AbftMeta,
    lambda m: ((m.row, m.col, m.aux), None),
    lambda _, ch: AbftMeta(*ch))


def _bitsum(leaf, lead_ndim: int) -> jnp.ndarray:
    """uint32 wraparound sum of a leaf's bit patterns over its trailing axes."""
    x = jnp.asarray(leaf)
    if jnp.issubdtype(x.dtype, jnp.floating):
        if x.dtype != jnp.float32:
            x = x.astype(jnp.float32)
        bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    elif x.dtype == jnp.bool_:
        bits = x.astype(jnp.uint32)
    else:
        bits = x.astype(jnp.uint32)
    axes = tuple(range(lead_ndim, bits.ndim))
    return jnp.sum(bits, axis=axes, dtype=jnp.uint32)


def aux_fingerprint(children, lead_shape: Tuple[int, ...]) -> jnp.ndarray:
    """Combined bitcast fingerprint of the derived leaves of a prepared
    operand, shaped like the operand's leading stack dims."""
    total = jnp.zeros(lead_shape, jnp.uint32)
    for leaf in jax.tree_util.tree_leaves(children):
        total = total + _bitsum(leaf, len(lead_shape))
    return total


def meta_for(values: jnp.ndarray, derived) -> AbftMeta:
    """Build the checksum metadata for a freshly prepared (clean) operand."""
    lead = values.shape[:-2]
    return AbftMeta(
        row=jnp.sum(values, axis=-1, dtype=jnp.int32),
        col=jnp.sum(values, axis=-2, dtype=jnp.int32),
        aux=aux_fingerprint(derived, lead))


def prep_derived(prep) -> Tuple:
    """The derived (non-``values``) numeric leaves of a PreparedOperand."""
    return (prep.delta, prep.t_b, prep.scale)


# --------------------------------------------------------------------------
# The guards
# --------------------------------------------------------------------------

def _maxabs_i32(x) -> jnp.ndarray:
    x = x.astype(jnp.int32)
    # |INT32_MIN| overflows back to INT32_MIN (negative): a sign-bit upset
    # whose wrapped deviation is exactly -2^31 would otherwise compare as
    # *smaller* than any threshold — clamp it to INT32_MAX (> the 2^30
    # threshold cap) so it always reads as a huge deviation
    return jnp.max(jnp.where(x == jnp.iinfo(jnp.int32).min,
                             jnp.iinfo(jnp.int32).max, jnp.abs(x)))


def guard_weight_meta(prep, *, layer: str, guard: str) -> None:
    """Exact integrity check of a prepared operand against its clean sums."""
    meta = getattr(prep, "abft", None)
    if meta is None or guard == "none":
        return
    vals = prep.values
    dev = jnp.maximum(
        _maxabs_i32(jnp.sum(vals, axis=-1, dtype=jnp.int32) - meta.row),
        _maxabs_i32(jnp.sum(vals, axis=-2, dtype=jnp.int32) - meta.col))
    aux = aux_fingerprint(prep_derived(prep), vals.shape[:-2])
    aux_dev = jnp.max((aux - meta.aux).astype(jnp.int32) != 0).astype(jnp.int32)
    total = jnp.maximum(dev, aux_dev).astype(jnp.float32)
    if isinstance(total, jax.core.Tracer):
        record(total, layer=layer, kind="weight", threshold=0.0)
    elif float(total) > 0:
        raise AbftFaultError([Fault(layer, "weight", float(total), 0.0)])


def guard_int_matmul(acc, a, b, *, policy, backend: str, layer: str,
                     meta: Optional[AbftMeta] = None, meta_side: str = "right",
                     recompute_fn=None):
    """Output-checksum guard for a 2-D integer GEMM ``acc = a @_approx b``.

    ``meta`` (when the fixed operand was prepared) supplies the *clean*
    checksum vector for the expected-value matvec, so a corrupted weight
    perturbs the comparison even though the corrupted operand also feeds the
    expected side. Returns ``acc`` (identity under ``detect``; under
    ``recompute`` a flagged tile is re-executed once via ``recompute_fn``
    and re-checked).
    """
    guard = policy.guard
    if guard == "none":
        return acc
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    thr_row, thr_col = int_thresholds(policy, backend, a32.shape, b32.shape)
    b_row = meta.row if (meta is not None and meta_side == "right") \
        else jnp.sum(b32, axis=-1, dtype=jnp.int32)
    a_col = meta.col if (meta is not None and meta_side == "left") \
        else jnp.sum(a32, axis=-2, dtype=jnp.int32)

    def deviations(out):
        dev_r = _maxabs_i32(jnp.sum(out, axis=-1, dtype=jnp.int32)
                            - jnp.matmul(a32, b_row))
        dev_c = _maxabs_i32(jnp.sum(out, axis=-2, dtype=jnp.int32)
                            - jnp.matmul(a_col, b32))
        return dev_r, dev_c

    dev_r, dev_c = deviations(acc)
    bad = (dev_r > thr_row) | (dev_c > thr_col)
    traced = isinstance(bad, jax.core.Tracer)
    if guard == "recompute" and recompute_fn is not None:
        if traced:
            acc = jax.lax.cond(bad, recompute_fn, lambda: acc)
            dev_r, dev_c = deviations(acc)
        elif bool(bad):
            acc = recompute_fn()
            dev_r, dev_c = deviations(acc)
    dev_r, dev_c = dev_r.astype(jnp.float32), dev_c.astype(jnp.float32)
    if traced:
        record(dev_r, layer=layer, kind="output", threshold=float(thr_row))
        record(dev_c, layer=layer, kind="output", threshold=float(thr_col))
        return acc
    faults = [Fault(layer, "output", float(d), float(t))
              for d, t in ((dev_r, thr_row), (dev_c, thr_col))
              if float(d) > t]
    if faults:
        raise AbftFaultError(faults)
    return acc


def float_threshold(a, b, out_dtype=None) -> jnp.ndarray:
    """Sound checksum tolerance for an exact float matmul.

    Re-association between the checksum matvec and the row/col sums of the
    product perturbs each partial by at most a few ulps per accumulation —
    in the *computation* precision: a bf16 model pays bf16 rounding per
    output element, so the tolerance must use the widest eps among the
    operand/output dtypes, not float32's. The bound below is far looser
    than observed clean drift while an exponent or sign-bit fault exceeds
    it by orders of magnitude.
    """
    kd = a.shape[-1]
    # the column checksum flattens every leading dim of `a` into one long
    # accumulation, so the drift budget must count *all* rows, not just the
    # trailing matrix dimension
    rows = int(np.prod(a.shape[:-1])) if a.ndim > 1 else 1
    eps = max(float(jnp.finfo(dt).eps)
              for dt in (a.dtype, b.dtype, out_dtype or a.dtype)
              if jnp.issubdtype(dt, jnp.inexact))
    amax = jnp.max(jnp.abs(a.astype(jnp.float32)))
    bmax = jnp.max(jnp.abs(b.astype(jnp.float32)))
    return (64.0 * jnp.float32(eps) * jnp.float32(kd * max(rows,
                                                           b.shape[-1]))
            * jnp.maximum(amax * bmax, jnp.float32(1e-30)))


def guard_float_matmul(out, a, b, *, policy, layer: str):
    """Output-checksum guard for the exact float path (2-D right operand)."""
    if policy.guard == "none" or b.ndim != 2:
        return out
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    o32 = out.astype(jnp.float32)
    thr = float_threshold(a, b, out.dtype)
    dev_r = jnp.max(jnp.abs(jnp.sum(o32, axis=-1) - a32 @ jnp.sum(b32, -1)))
    a_col = jnp.sum(a32.reshape(-1, a32.shape[-1]), axis=0)
    dev_c = jnp.max(jnp.abs(jnp.sum(o32.reshape(-1, o32.shape[-1]), axis=0)
                            - a_col @ b32))
    dev = jnp.maximum(dev_r, dev_c)
    rel = dev / thr
    if isinstance(rel, jax.core.Tracer):
        record(rel, layer=layer, kind="output", threshold=1.0)
        return out
    if float(dev) > float(thr):
        raise AbftFaultError([Fault(layer, "output", float(dev), float(thr))])
    return out


# --------------------------------------------------------------------------
# Device-table integrity
# --------------------------------------------------------------------------

def _table_mismatch(golden: np.ndarray, device) -> bool:
    return not np.array_equal(golden, np.asarray(device))


def verify_tables(policy, backend: str, *, layer: str = "") -> None:
    """Compare the device-resident tables a backend consumes against freshly
    built host golden copies. Raises ``AbftFaultError`` on mismatch (host
    context: at trace time under jit, per call in eager code).

    The device caches model on-chip table SRAM (uploaded once, reused by
    every call); the host build is the trusted reference. ``approx_oracle``
    re-derives every product from the bit-level PE emulation and has no
    table to corrupt.
    """
    if policy.guard == "none" or backend in ("exact", "mxu_int8",
                                             "approx_oracle"):
        return
    from . import emulate, error_delta
    n_bits, k, acc = policy.n_bits, policy.k, policy.acc_bits
    golden = emulate.product_table(n_bits, k, True, acc)
    with jax.ensure_compile_time_eval():
        faults = []
        if backend in ("approx_lut", "approx_onehot"):
            dev = emulate.product_table_jnp(n_bits, k, True, acc,
                                            flat=(backend == "approx_lut"))
            ref = golden.reshape(-1) if backend == "approx_lut" else golden
            if _table_mismatch(ref, dev):
                faults.append(Fault(layer, "table", 1.0, 0.0))
        elif backend == "approx_delta":
            fac = error_delta.delta_factors(n_bits, k, True, acc,
                                            rank=policy.delta_rank,
                                            tol=policy.delta_tol)
            f_dev, g_dev = error_delta.factor_tables_jnp(
                n_bits, k, True, acc, rank=policy.delta_rank,
                tol=policy.delta_tol)
            if fac.rank:
                span = 1 << n_bits
                ok = (np.array_equal(np.ascontiguousarray(fac.f).reshape(-1),
                                     np.asarray(f_dev))
                      and np.array_equal(
                          np.ascontiguousarray(fac.g).reshape(-1),
                          np.asarray(g_dev)))
                if not ok:
                    faults.append(Fault(layer, "table", 1.0, 0.0))
    if faults:
        raise AbftFaultError(faults)


# --------------------------------------------------------------------------
# Memory fingerprints (engine scrub)
# --------------------------------------------------------------------------

def tree_fingerprint(tree) -> Dict[str, int]:
    """Bitcast-sum fingerprint per array leaf, keyed by the pytree path.

    Bitwise-sensitive: any single bit flip in a leaf changes its uint32
    wraparound sum (a *pair* of compensating flips could alias — the engine
    scrub targets single-event upsets). One device reduction + host sync per
    leaf; the serve engine runs this over bound params and the paged KV pool
    between steps when the policy is guarded.
    """
    out: Dict[str, int] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        if not hasattr(leaf, "dtype") or not hasattr(leaf, "ndim"):
            continue
        key = jax.tree_util.keystr(path)
        out[key] = int(_bitsum(leaf, 0))
    return out


def verify_fingerprint(tree, ref: Dict[str, int]) -> List[str]:
    """Paths whose current fingerprint differs from ``ref`` (new/missing
    leaves count as mismatches — structure changes are not expected between
    scrubs)."""
    cur = tree_fingerprint(tree)
    bad = [p for p, v in cur.items() if ref.get(p) != v]
    bad += [p for p in ref if p not in cur]
    return bad
