"""Bit-level emulation of the paper's exact/approximate fused-MAC PE.

The PE computes ``a*b + c`` (N-bit operands, ``acc_bits``-bit accumulator) via a
carry-save array of PPC/NPPC cells; columns ``< k`` use the approximate cells of
Table I, the rest are exact. This module emulates that array *bit-exactly* in a
fully vectorized way:

The carry-save state is packed into integer words ``S`` and ``C`` (uint32): bit ``w``
of ``S``/``C`` is the sum/carry bit of column ``w``. One partial-product row is then
absorbed into (S, C) with ~10 word-wide bitwise ops, processing every column of every
batch element at once. The Baugh-Wooley decomposition supplies the NPPC positions
(the ``2N-2`` sign-row cells) and the two's-complement correction constant.

Cell-count check (validates the paper's quote of 50 PPC + 14 NPPC for N=8):
PPC = (N-1)^2 + 1 = N^2 - 2N + 2 (the paper's prose "N^2-2N-2" is a sign typo; its own
"50 PPC" quote matches +2), NPPC = 2N - 2.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32


class PEConfig(NamedTuple):
    n_bits: int = 8        # operand width N
    k: int = 0             # approximation factor: columns < k use approximate cells
    signed: bool = True    # Baugh-Wooley signed vs plain unsigned array
    acc_bits: int = 24     # fused accumulator width (two's complement when signed)


def ppc_count(n_bits: int) -> int:
    return (n_bits - 1) ** 2 + 1


def nppc_count(n_bits: int) -> int:
    return 2 * n_bits - 2


def _rows_and_masks(cfg: PEConfig):
    """Static per-row metadata: for row i, which columns hold PPC vs NPPC cells.

    Returns (row_specs, const_word). row_specs[i] = (ppc_cols, nppc_cols) as python
    lists of (col, a_bit, b_bit). const_word is the Baugh-Wooley correction constant
    (already reduced modulo 2**acc_bits).
    """
    n, acc = cfg.n_bits, cfg.acc_bits
    rows = []
    if not cfg.signed:
        for i in range(n):
            rows.append(([(i + j, j, i) for j in range(n)], []))
        const = 0
    else:
        for i in range(n - 1):
            ppc = [(i + j, j, i) for j in range(n - 1)]
            nppc = [(i + n - 1, n - 1, i)]          # ~(a_{N-1} b_i)
            rows.append((ppc, nppc))
        # row N-1: ~(a_j b_{N-1}) for j<N-1, plus a_{N-1}b_{N-1} at 2N-2
        rows.append((
            [(2 * n - 2, n - 1, n - 1)],
            [(j + n - 1, j, n - 1) for j in range(n - 1)],
        ))
        # constant: +2^N - 2^{2N-1}  (mod 2^acc)
        const = (2 ** n - 2 ** (2 * n - 1)) % (2 ** acc)
    return rows, const


def _absorb_row(s, c, e, m_ppc, m_nppc, ak, acc_mask):
    """Absorb one addend row into the carry-save state (word-parallel cells).

    s, c: current sum/carry words. e: effective addend bits (p at PPC positions,
    ~p at NPPC positions, 0 where no cell). m_ppc/m_nppc: position masks. ak: mask of
    approximate columns (already intersected with cell positions).
    """
    ex = ~ak & acc_mask
    # exact full adder at every position (cell-less positions degenerate to HA on s,c)
    x = s ^ e
    s_exact = x ^ c
    c_exact = (s & e) | (c & x)
    # approximate PPC: S = (S|C)&~p ; C = p
    sc = s | c
    s_ap = sc & ~e
    c_ap = e
    # approximate NPPC (e already holds q=~p): C = (S|C)&q ; S = ~C
    c_an = sc & e
    s_an = ~c_an
    ap = ak & m_ppc
    an = ak & m_nppc
    s_new = (s_exact & ex) | (s_ap & ap) | (s_an & an)
    c_new = (c_exact & ex) | (c_ap & ap) | (c_an & an)
    return s_new & acc_mask, ((c_new << 1) & acc_mask)


@functools.partial(jax.jit, static_argnums=(3,))
def _pe_mac_impl(a_u, b_u, c_u, cfg: PEConfig):
    n, acc = cfg.n_bits, cfg.acc_bits
    acc_mask = U32((1 << acc) - 1)
    rows, const = _rows_and_masks(cfg)

    s = (c_u + U32(const)) & acc_mask   # accumulator + BW constant seed the array
    c = jnp.zeros_like(s)

    for ppc, nppc in rows:
        e = jnp.zeros_like(s)
        m_ppc = 0
        m_nppc = 0
        for col, abit, bbit in ppc:
            p = ((a_u >> abit) & 1) & ((b_u >> bbit) & 1)
            e = e | (p << col)
            m_ppc |= (1 << col)
        for col, abit, bbit in nppc:
            q = (((a_u >> abit) & 1) & ((b_u >> bbit) & 1)) ^ 1
            e = e | (q << col)
            m_nppc |= (1 << col)
        m_ppc_w = U32(m_ppc)
        m_nppc_w = U32(m_nppc)
        k_mask = U32(((1 << cfg.k) - 1) if cfg.k > 0 else 0)
        ak = k_mask & (m_ppc_w | m_nppc_w)
        s, c = _absorb_row(s, c, e, m_ppc_w, m_nppc_w, ak, acc_mask)

    out = (s + c) & acc_mask            # final carry-propagate add (exact CPA stage)
    return out


def _to_unsigned(x, n_bits):
    return jnp.asarray(x, jnp.int32).astype(U32) & U32((1 << n_bits) - 1)


def _from_unsigned(x, acc_bits, signed):
    x = x.astype(jnp.int64) if acc_bits >= 32 else x.astype(jnp.int32)
    if signed:
        half = 1 << (acc_bits - 1)
        full = 1 << acc_bits
        x = jnp.where(x >= half, x - full, x)
    return x.astype(jnp.int32)


def pe_mac(a, b, c=0, *, n_bits: int = 8, k: int = 0, signed: bool = True,
           acc_bits: int = 24):
    """Emulate the PE's fused ``a*b + c``. Broadcasts over any batch shape.

    a, b: integer arrays (interpreted mod 2^n_bits, two's complement if signed).
    c: accumulator input (mod 2^acc_bits). Returns int32 (sign-extended if signed).
    k=0 gives the exact PE; k>0 approximates columns < k per Table I.
    """
    cfg = PEConfig(n_bits, k, signed, acc_bits)
    a_u = _to_unsigned(a, n_bits)
    b_u = _to_unsigned(b, n_bits)
    shape = jnp.broadcast_shapes(jnp.shape(a_u), jnp.shape(b_u), jnp.shape(c))
    a_u = jnp.broadcast_to(a_u, shape)
    b_u = jnp.broadcast_to(b_u, shape)
    c_u = jnp.broadcast_to(jnp.asarray(c, jnp.int32).astype(U32), shape) & U32((1 << acc_bits) - 1)
    out = _pe_mac_impl(a_u, b_u, c_u, cfg)
    return _from_unsigned(out, acc_bits, signed)


def matmul_oracle(a_mat, b_mat, *, n_bits: int = 8, k: int = 0, signed: bool = True,
                  acc_bits: int = 24):
    """GEMM through a chain of fused-MAC PEs — the systolic array's dataflow.

    a_mat: (M, K) int, b_mat: (K, N) int. Accumulation order is k=0..K-1 through the
    same approximate PE, exactly as partial sums flow through the array. Returns
    (M, N) int32.
    """
    a_mat = jnp.asarray(a_mat, jnp.int32)
    b_mat = jnp.asarray(b_mat, jnp.int32)
    m_dim, k_dim = a_mat.shape
    k2, n_dim = b_mat.shape
    assert k_dim == k2, (a_mat.shape, b_mat.shape)

    def step(acc, inputs):
        a_col, b_row = inputs  # (M,), (N,)
        a_bc = a_col[:, None]
        b_bc = b_row[None, :]
        acc = pe_mac(a_bc, b_bc, acc, n_bits=n_bits, k=k, signed=signed,
                     acc_bits=acc_bits)
        return acc, None

    init = jnp.zeros((m_dim, n_dim), jnp.int32)
    acc, _ = jax.lax.scan(step, init, (a_mat.T, b_mat))
    return acc


@functools.lru_cache(maxsize=32)
def product_table(n_bits: int = 8, k: int = 0, signed: bool = True,
                  acc_bits: int = 24) -> np.ndarray:
    """(2^N, 2^N) int32 table T[a_u, b_u] = pe_mac(a, b, 0) — the approximate product.

    Indexing is by the *unsigned bit pattern* of each operand, so signed operands are
    looked up via ``x & (2^N - 1)``.
    """
    span = 1 << n_bits
    av = np.arange(span, dtype=np.int32)
    grid_a = np.repeat(av, span)
    grid_b = np.tile(av, span)
    # force eager evaluation even when called under an outer jit/scan trace
    # (tables are compile-time constants; lru_cache memoizes them)
    with jax.ensure_compile_time_eval():
        out = pe_mac(grid_a, grid_b, 0, n_bits=n_bits, k=k, signed=signed,
                     acc_bits=acc_bits)
    return np.asarray(out, np.int32).reshape(span, span)


@functools.lru_cache(maxsize=32)
def product_table_jnp(n_bits: int = 8, k: int = 0, signed: bool = True,
                      acc_bits: int = 24, flat: bool = False) -> jnp.ndarray:
    """Device-resident copy of ``product_table``, uploaded once per config.

    Shared by kernels/ops.py, core/lut.py and core/error_delta.py so repeated
    GEMM calls don't re-transfer the 256 KiB table to the device every
    invocation. ``flat=True`` returns the (span*span,) row-major view the
    gather kernels index into.
    """
    table = product_table(n_bits, k, signed, acc_bits)
    with jax.ensure_compile_time_eval():   # lru_cache must not capture tracers
        return jnp.asarray(table.reshape(-1) if flat else table)
