"""Analytical area/power/delay/energy model from the paper's synthesis tables.

Energy cannot be measured on CPU/TPU, so this module encodes the paper's Cadence
Genus 90-nm UMC results (Tables II, III, IV) and recomputes every derived claim
(cell/PE/SA-level savings) from the raw entries. It then extrapolates energy per
GEMM for arbitrary problem sizes and SA dimensions, which the benchmark harness
uses to report estimated energy per workload per backend.

Units: area um^2, power uW (cells/PEs) or mW (SAs), delay ps (cells) or ns
(PEs/SAs), PDP aJ (cells) or pJ (SAs), PADP um^2*fJ (PEs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

from .emulate import nppc_count, ppc_count


@dataclasses.dataclass(frozen=True)
class HwPoint:
    area: float
    power: float
    delay: float

    @property
    def pdp(self) -> float:
        return self.power * self.delay

    @property
    def padp(self) -> float:
        return self.area * self.power * self.delay


# ---- Table II: cells (area um^2, power uW, delay ps) -----------------------
CELLS: Dict[str, Dict[str, HwPoint]] = {
    "ppc": {
        "exact_ref6": HwPoint(25.81, 1.03, 262),
        "proposed_exact": HwPoint(24.98, 0.99, 255),
        "approx_ref6": HwPoint(13.32, 0.64, 187),
        "approx_ref5": HwPoint(14.13, 0.58, 157),
        "proposed_approx": HwPoint(10.19, 0.44, 110),
    },
    "nppc": {
        "exact_ref6": HwPoint(24.92, 0.99, 238),
        "proposed_exact": HwPoint(23.47, 0.99, 216),
        "approx_ref6": HwPoint(12.54, 0.61, 156),
        "approx_ref5": HwPoint(13.22, 0.60, 148),
        "proposed_approx": HwPoint(9.40, 0.37, 147),
    },
}

# ---- Table III: 8-bit signed PEs (area um^2, power uW, delay ns) ------------
PE_SIGNED_8B: Dict[str, HwPoint] = {
    "exact_ref6": HwPoint(1708.0, 183.4, 3.71),
    "exact_ref5": HwPoint(1716.0, 190.3, 3.22),
    "proposed_exact": HwPoint(1620.3, 170.6, 3.18),
    "ha_fsa": HwPoint(2012.0, 465.0, 2.3),
    "gemmini": HwPoint(1968.0, 344.0, 2.9),
    "approx_ref6": HwPoint(1546.3, 216.0, 3.51),
    "approx_ref12": HwPoint(1465.2, 207.9, 3.18),
    "approx_ref5": HwPoint(975.5, 177.2, 2.50),
    "proposed_approx": HwPoint(869.5, 155.2, 2.48),
}

# ---- Table IV: 8-bit signed SAs @250MHz (area mm^2, power mW, delay ns, PDP pJ)
SA_8B: Dict[int, Dict[str, HwPoint]] = {
    3: {
        "exact_ref6": HwPoint(0.0191, 6.38, 3.36),
        "proposed_exact": HwPoint(0.0184, 6.01, 3.25),
        "approx_ref12": HwPoint(0.0155, 5.45, 2.97),
        "approx_ref6": HwPoint(0.0142, 4.20, 2.70),
        "approx_ref5": HwPoint(0.0135, 4.60, 2.50),
        "proposed_approx": HwPoint(0.0110, 3.86, 2.42),
    },
    4: {
        "exact_ref6": HwPoint(0.0345, 11.4, 3.56),
        "proposed_exact": HwPoint(0.0333, 11.0, 3.42),
        "approx_ref12": HwPoint(0.0301, 10.4, 3.31),
        "approx_ref6": HwPoint(0.0290, 9.60, 2.90),
        "approx_ref5": HwPoint(0.0285, 9.20, 2.55),
        "proposed_approx": HwPoint(0.0249, 8.06, 2.40),
    },
    8: {
        "exact_ref6": HwPoint(0.1363, 49.8, 3.61),
        "proposed_exact": HwPoint(0.1302, 42.8, 3.51),
        "approx_ref12": HwPoint(0.1151, 35.1, 3.02),
        "approx_ref6": HwPoint(0.1050, 27.8, 2.96),
        "approx_ref5": HwPoint(0.1020, 25.5, 2.80),
        "proposed_approx": HwPoint(0.0895, 20.5, 2.74),
    },
    16: {
        "exact_ref6": HwPoint(0.5841, 265.4, 3.91),
        "proposed_exact": HwPoint(0.5498, 233.3, 3.82),
        "approx_ref12": HwPoint(0.4424, 193.7, 3.88),
        "approx_ref6": HwPoint(0.4200, 166.0, 3.70),
        "approx_ref5": HwPoint(0.4000, 150.0, 3.40),
        "proposed_approx": HwPoint(0.3513, 117.8, 3.28),
    },
}

PAPER_PPC_COUNT_8B = 50   # paper quote; equals (N-1)^2 + 1 for N=8
PAPER_NPPC_COUNT_8B = 14  # = 2N - 2


def pdp_saving(base: HwPoint, new: HwPoint) -> float:
    """Fractional PDP (energy) saving of `new` vs `base`."""
    return 1.0 - new.pdp / base.pdp


def padp_saving(base: HwPoint, new: HwPoint) -> float:
    return 1.0 - new.padp / base.padp


def cell_energy_claims() -> Dict[str, float]:
    """Recompute the paper's headline cell-level savings.

    * proposed exact PPC vs exact [6]: ~6.4% energy improvement
    * proposed approx PPC vs best existing ([5]): 46.8%
    * proposed approx NPPC vs best existing ([5]): 34.4%  (abstract quotes 34.4%)
    """
    c = CELLS
    return {
        "exact_ppc_vs_ref6": pdp_saving(c["ppc"]["exact_ref6"], c["ppc"]["proposed_exact"]),
        "approx_ppc_vs_ref5": pdp_saving(c["ppc"]["approx_ref5"], c["ppc"]["proposed_approx"]),
        "approx_nppc_vs_ref5": pdp_saving(c["nppc"]["approx_ref5"], c["nppc"]["proposed_approx"]),
    }


def pe_energy_claims() -> Dict[str, float]:
    """PE-level: proposed exact vs [6] (24.37% energy), approx vs [5] (22.51%)."""
    p = PE_SIGNED_8B
    return {
        "exact_pe_vs_ref6": pdp_saving(p["exact_ref6"], p["proposed_exact"]),
        "approx_pe_vs_ref5": pdp_saving(p["approx_ref5"], p["proposed_approx"]),
        "exact_pe_padp_vs_gemmini": padp_saving(p["gemmini"], p["proposed_exact"]),
        "approx_pe_padp_vs_ref5": padp_saving(p["approx_ref5"], p["proposed_approx"]),
    }


def sa_energy_claims() -> Dict[str, float]:
    """SA-level: 8x8 exact 16% / approx 68% vs exact [6]; 16x16 62.7% / 24.2%."""
    sa8, sa16 = SA_8B[8], SA_8B[16]
    return {
        "sa8_exact_vs_ref6": pdp_saving(sa8["exact_ref6"], sa8["proposed_exact"]),
        "sa8_approx_vs_exact_ref6": pdp_saving(sa8["exact_ref6"], sa8["proposed_approx"]),
        "sa16_approx_vs_exact_ref6": pdp_saving(sa16["exact_ref6"], sa16["proposed_approx"]),
        "sa16_approx_vs_ref5": pdp_saving(sa16["approx_ref5"], sa16["proposed_approx"]),
    }


def pe_energy_from_cells(design: str, n_bits: int = 8,
                         use_paper_counts: bool = False) -> float:
    """Bottom-up PE energy (aJ) = ppc_count*PDP_ppc + nppc_count*PDP_nppc."""
    if use_paper_counts and n_bits == 8:
        n_ppc, n_nppc = PAPER_PPC_COUNT_8B, PAPER_NPPC_COUNT_8B
    else:
        n_ppc, n_nppc = ppc_count(n_bits), nppc_count(n_bits)
    return (n_ppc * CELLS["ppc"][design].pdp + n_nppc * CELLS["nppc"][design].pdp)


def gemm_energy_estimate(m: int, k: int, n: int, *, design: str = "proposed_approx",
                         sa_dim: int = 8, freq_mhz: float = 250.0) -> Dict[str, float]:
    """Estimated energy (nJ) + latency (us) for an MxKxN int8 GEMM on a sa_dim^2 SA.

    Tiling: output tiles of sa_dim x sa_dim, K streamed. Cycles per tile =
    (3*sa_dim - 2) + (K - 1) wavefront latency [11]; SA power from Table IV.
    """
    sa = SA_8B[sa_dim][design]
    tiles = math.ceil(m / sa_dim) * math.ceil(n / sa_dim)
    cycles_per_tile = (3 * sa_dim - 2) + max(0, k - 1)
    total_cycles = tiles * cycles_per_tile
    secs = total_cycles / (freq_mhz * 1e6)
    energy_nj = sa.power * 1e-3 * secs * 1e9   # mW * s -> nJ
    macs = m * k * n
    return {
        "cycles": float(total_cycles),
        "latency_us": secs * 1e6,
        "energy_nJ": energy_nj,
        "energy_per_mac_fJ": energy_nj * 1e6 / macs,
    }
