"""Exact-plus-error-delta decomposition of the approximate product table.

The paper's approximate PE differs from the exact PE only in the columns
``< k`` of the partial-product array, so the approximate product table is the
exact product plus a structured error term:

    T_k[a, b] = a * b + E_k[a, b],      E_k = product_table(k) - product_table(0)

Because the approximate cells occupy columns ``< k`` only (and for ``k <= N-1``
those columns hold PPC cells fed exclusively by operand bits ``a_j b_i`` with
``i + j < k``), ``E_k[a, b]`` depends only on the **low k bits** of each
operand: the (2^N, 2^N) table is a (2^k, 2^k) tile repeated over the grid.  Its
true rank is therefore at most 2^k and empirically far lower — for N=8 signed:
rank 2 at k=2, 7 at k=4, 21 at k=6, 62 at k=8.

An SVD of ``E`` gives factors ``f (span, r)`` and ``g (r, span)`` with
``E ≈ f @ g``.  At ``r = rank_for_exact(...)`` the float64 reconstruction error
is ~1e-12, so rounding recovers every integer entry exactly, and the
approximate GEMM becomes **two MXU matmuls** instead of O(M·N·K) VPU gathers:

    out = A_s @ B_s                       (exact int8 matmul — the exact PE array)
        + round( F_A @ G_B )              ((M, rK) x (rK, N) float32 correction)

with ``F_A[m, kk*r + j] = f[a_u[m, kk], j]`` and
``G_B[kk*r + j, n] = g[j, b_u[kk, n]]`` — per-element lookups into 256-entry
vectors, trivially VMEM-resident.  Rounding the correction **per K-block** (as
the fused Pallas kernel in ``kernels/delta_gemm.py`` does) keeps the result
bit-identical to the gather path for any K, because each block's true
correction is an integer and the float32 noise per block is ~1e-2 << 0.5.

For truncated ranks (``rank_for_tol``) two residual views are kept:
``residual = E - round(f @ g)`` (int32 — nonzero only where the rank-r
reconstruction rounds to the wrong integer, for sparsity introspection) and
``defect = E - f @ g`` (float32 — the exact reconstruction defect). Callers
restore bit-exactness at any rank by gathering ``defect`` and rounding **once**
over ``correction + defect`` (rounding the two parts separately does not
commute with the summation, so the integer residual alone cannot cancel the
truncation exactly).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .emulate import product_table

# A rank is "exact" when the float64 reconstruction error is below this guard:
# small enough that per-entry rounding is exact and that float32 block-wise
# accumulation (error ~1e-2 at K-block 512, measured) stays well below 0.5.
EXACT_RECON_EPS = 1e-6


class DeltaFactors(NamedTuple):
    """Rank-r factorization of the error table for one (n_bits, k, signed, acc_bits)."""
    n_bits: int
    k: int
    signed: bool
    acc_bits: int
    rank: int
    f: np.ndarray          # (span, rank) float32 — row factor, indexed by a's bit pattern
    g: np.ndarray          # (rank, span) float32 — column factor, indexed by b's bit pattern
    residual: np.ndarray   # (span, span) int32 — E - round(f @ g); all-zero at rank_for_exact
    defect: np.ndarray     # (span, span) float32 — E - f @ g; exact-cancellation table
    max_err: float         # max |f @ g - E| over the table (float64 reconstruction)

    @property
    def exact(self) -> bool:
        return not self.residual.any()


@functools.lru_cache(maxsize=32)
def error_table(n_bits: int = 8, k: int = 4, signed: bool = True,
                acc_bits: int = 24) -> np.ndarray:
    """(2^N, 2^N) int32 table E[a_u, b_u] = T_k[a_u, b_u] - a*b (zero for k=0)."""
    t_k = product_table(n_bits, k, signed, acc_bits).astype(np.int64)
    t_0 = product_table(n_bits, 0, signed, acc_bits).astype(np.int64)
    return (t_k - t_0).astype(np.int32)


@functools.lru_cache(maxsize=32)
def _svd(n_bits: int, k: int, signed: bool, acc_bits: int):
    e = error_table(n_bits, k, signed, acc_bits).astype(np.float64)
    return np.linalg.svd(e)


def _recon_err(n_bits: int, k: int, signed: bool, acc_bits: int, rank: int) -> float:
    e = error_table(n_bits, k, signed, acc_bits).astype(np.float64)
    if rank == 0:
        return float(np.abs(e).max()) if e.size else 0.0
    u, s, vt = _svd(n_bits, k, signed, acc_bits)
    recon = (u[:, :rank] * s[:rank]) @ vt[:rank]
    return float(np.abs(recon - e).max())


@functools.lru_cache(maxsize=64)
def rank_for_exact(n_bits: int = 8, k: int = 4, signed: bool = True,
                   acc_bits: int = 24) -> int:
    """Smallest r whose float64 rank-r reconstruction rounds to E exactly.

    Equals the numerical rank of E (the tiled low-bit structure keeps it far
    below 2^N): the singular spectrum drops to ~0 past the true rank, so the
    reconstruction error falls from O(1) to O(1e-12) in one step.
    """
    _, s, _ = _svd(n_bits, k, signed, acc_bits)
    for r in range(len(s) + 1):
        if _recon_err(n_bits, k, signed, acc_bits, r) <= EXACT_RECON_EPS:
            return r
    raise AssertionError("full-rank SVD failed to reconstruct the error table")


@functools.lru_cache(maxsize=64)
def rank_for_tol(tol: float, n_bits: int = 8, k: int = 4, signed: bool = True,
                 acc_bits: int = 24) -> int:
    """Smallest r with max-abs per-entry reconstruction error <= tol.

    ``tol`` bounds the *additional* per-product error on top of the paper's
    approximation; the exact residual table lets callers cancel it again.
    """
    if tol < 0:
        raise ValueError(f"tol must be >= 0, got {tol}")
    r_exact = rank_for_exact(n_bits, k, signed, acc_bits)
    for r in range(r_exact + 1):
        if _recon_err(n_bits, k, signed, acc_bits, r) <= tol:
            return r
    return r_exact


@functools.lru_cache(maxsize=32)
def delta_factors(n_bits: int = 8, k: int = 4, signed: bool = True,
                  acc_bits: int = 24, rank: Optional[int] = None,
                  tol: Optional[float] = None) -> DeltaFactors:
    """Factor the error table at the requested rank (default: exact rank).

    ``rank`` wins over ``tol``; with neither, ``rank_for_exact`` is used and
    the residual is all-zero (the backend is then bit-identical to the gather
    path). Results are cached per configuration — the SVD runs once per
    (n_bits, k, signed, acc_bits).
    """
    if rank is None:
        rank = (rank_for_exact(n_bits, k, signed, acc_bits) if tol is None
                else rank_for_tol(tol, n_bits, k, signed, acc_bits))
    span = 1 << n_bits
    e = error_table(n_bits, k, signed, acc_bits)
    rank = max(0, min(rank, span))
    if rank == 0:
        f = np.zeros((span, 0), np.float32)
        g = np.zeros((0, span), np.float32)
        recon = np.zeros((span, span), np.float64)
    else:
        u, s, vt = _svd(n_bits, k, signed, acc_bits)
        sq = np.sqrt(s[:rank])
        f = (u[:, :rank] * sq).astype(np.float32)
        g = (sq[:, None] * vt[:rank]).astype(np.float32)
        recon = f.astype(np.float64) @ g.astype(np.float64)
    residual = (e.astype(np.int64) - np.round(recon).astype(np.int64)).astype(np.int32)
    defect = (e.astype(np.float64) - recon).astype(np.float32)
    max_err = float(np.abs(recon - e).max()) if e.size else 0.0
    return DeltaFactors(n_bits, k, signed, acc_bits, rank, f, g, residual,
                        defect, max_err)


@functools.lru_cache(maxsize=32)
def factor_tables_jnp(n_bits: int = 8, k: int = 4, signed: bool = True,
                      acc_bits: int = 24, rank: Optional[int] = None,
                      tol: Optional[float] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Device-resident flattened (f, g) for the Pallas kernel, uploaded once.

    f is flattened row-major (span, r) -> f_flat[v * r + j]; g row-major
    (r, span) -> g_flat[j * span + v].  rank 0 yields (span,)-zeros dummies so
    the kernel signature stays uniform.
    """
    fac = delta_factors(n_bits, k, signed, acc_bits, rank=rank, tol=tol)
    span = 1 << n_bits
    # force eager creation even under an outer jit/scan trace: these are
    # compile-time constants and the lru_cache must never capture a tracer
    with jax.ensure_compile_time_eval():
        if fac.rank == 0:
            z = jnp.zeros((span,), jnp.float32)
            return z, z
        return (jnp.asarray(np.ascontiguousarray(fac.f).reshape(-1)),
                jnp.asarray(np.ascontiguousarray(fac.g).reshape(-1)))


@functools.lru_cache(maxsize=32)
def _device_factors(n_bits: int, k: int, signed: bool, acc_bits: int,
                    rank: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Device-resident (f, g, defect_flat) for the jnp paths, uploaded once."""
    fac = delta_factors(n_bits, k, signed, acc_bits, rank=rank)
    with jax.ensure_compile_time_eval():   # lru_cache must not capture tracers
        return (jnp.asarray(fac.f), jnp.asarray(fac.g),
                jnp.asarray(fac.defect.reshape(-1)))


def _correction(a_u: jnp.ndarray, b_u: jnp.ndarray, fac: DeltaFactors) -> jnp.ndarray:
    """Unrounded rank-r correction: (M, rK) x (rK, N) float32 matmul."""
    m, kd = a_u.shape
    n = b_u.shape[1]
    f_dev, g_dev, _ = _device_factors(fac.n_bits, fac.k, fac.signed,
                                      fac.acc_bits, fac.rank)
    f_a = jnp.take(f_dev, a_u, axis=0)                        # (M, K, r)
    g_b = jnp.take(g_dev, b_u, axis=1)                        # (r, K, N)
    return (f_a.reshape(m, kd * fac.rank)
            @ jnp.transpose(g_b, (1, 0, 2)).reshape(kd * fac.rank, n))


def defect_gather_matmul(a_u: jnp.ndarray, b_u: jnp.ndarray,
                         fac: DeltaFactors) -> jnp.ndarray:
    """sum_kk defect[a,b] via the shared gather loop (cached device table)."""
    from . import lut
    span = 1 << fac.n_bits
    _, _, defect_flat = _device_factors(fac.n_bits, fac.k, fac.signed,
                                        fac.acc_bits, fac.rank)
    return lut.table_gather_matmul(a_u, b_u, defect_flat, span=span)


@dataclasses.dataclass(frozen=True)
class PreparedDelta:
    """Weight-stationary half of the delta decomposition for a fixed operand.

    For a fixed weight matrix the operand-dependent factor of the correction —
    ``G_B[kk, j, n] = g[j, b_u[kk, n]]`` when the weights sit on the right,
    ``F_A[m, kk, j] = f[a_u[m, kk], j]`` when they sit on the left (the DCT
    matrix multiplies from the left; the product table is not symmetric, so
    the operand order cannot be swapped) — is computed **once** and reused for
    every batch of activations: each call then costs one exact int8 matmul
    plus one rank-r float32 contraction and only the *moving* operand's
    gathers.

    Because ``E`` only sees the fixed operand through its low-k bit patterns,
    the factorization is further specialized to the ``d`` *distinct* patterns
    the weights actually reach (``_restricted_factors``): an SVD of the
    restricted table ``E[:, used]`` (or ``E[used, :]``) gives an exact rank
    ``r' <= min(r, d)`` — e.g. the 8x8 DCT matrix needs rank 10 instead of 21
    at k=6, the Laplacian kernel rank 2 — shrinking the per-call gather and
    contraction by the same factor. Restriction applies only at the exact
    rank; explicitly truncated ranks keep the generic factors so the
    ``delta_tol`` semantics (and the defect table that cancels truncation)
    stay identical to the unprepared path. ``prepare_delta(restrict=False)``
    forces the generic factors — ``core.gemm.bind`` uses this for *stacked*
    layer weights so every layer shares one rank and the prepared pytrees can
    ride a ``lax.scan``.

    Registered as a JAX pytree (arrays are children; ``side``/``rank``/the
    factorization spec are static aux data) so prepared operands can be jit
    arguments and ``lax.scan`` xs.
    """
    side: str              # "right": fixed B (K, N); "left": fixed A (M, K)
    rank: int              # effective (possibly weight-restricted) rank
    spec: Tuple            # (n_bits, k, signed, acc_bits, rank_req, tol_req)
    w_u: jnp.ndarray       # fixed operand's unsigned bit patterns, int32
    w_s: jnp.ndarray       # fixed operand's signed (or unsigned) values, int32
    gather_tab: jnp.ndarray  # moving-side factor, (r', span) float32
    factor: jnp.ndarray    # stationary factor: (K, r', N) right / (M, K, r') left

    @property
    def fac(self) -> DeltaFactors:
        n_bits, k, signed, acc_bits, rank_req, tol_req = self.spec
        return delta_factors(n_bits, k, signed, acc_bits, rank=rank_req,
                             tol=tol_req)


jax.tree_util.register_pytree_node(
    PreparedDelta,
    lambda p: ((p.w_u, p.w_s, p.gather_tab, p.factor),
               (p.side, p.rank, p.spec)),
    lambda aux, ch: PreparedDelta(aux[0], aux[1], aux[2], *ch))


def _signed_values(w_u: jnp.ndarray, n_bits: int, signed: bool) -> jnp.ndarray:
    half = (1 << n_bits) >> 1
    return (w_u ^ half) - half if signed else w_u


def _base_matmul(a_s: jnp.ndarray, b_s: jnp.ndarray, signed: bool) -> jnp.ndarray:
    """Exact integer base product. Signed int8 operands take the MXU int8 path
    (int32 accumulate); unsigned values don't fit int8 and use an int32 dot."""
    if signed:
        return jax.lax.dot_general(
            a_s.astype(jnp.int8), b_s.astype(jnp.int8), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
    return jnp.matmul(a_s, b_s)


# Restricted SVDs stay cheap: above this many distinct patterns the generic
# factors are reused (the rank gain vanishes as d approaches the table rank).
RESTRICT_MAX_PATTERNS = 128


@functools.lru_cache(maxsize=256)
def _restricted_factors(n_bits: int, k: int, signed: bool, acc_bits: int,
                        axis: int, patterns: Tuple[int, ...]):
    """Exact-rank factors of E restricted to the fixed operand's patterns.

    ``axis=1`` restricts columns (fixed right operand indexes E by its b
    patterns), ``axis=0`` rows. Returns (f, g, rank) with f (span, r') /
    g (r', d) for axis=1 and f (d, r') / g (r', span) for axis=0 — the
    d-sized side is indexed by position in ``patterns``. The restricted
    reconstruction is rounding-exact by construction (r' <= d suffices)."""
    e = error_table(n_bits, k, signed, acc_bits).astype(np.float64)
    sub = e[:, list(patterns)] if axis == 1 else e[list(patterns), :]
    u, s, vt = np.linalg.svd(sub, full_matrices=False)
    rank = len(s)
    for r in range(len(s) + 1):
        recon = (u[:, :r] * s[:r]) @ vt[:r]
        if np.abs(recon - sub).max() <= EXACT_RECON_EPS:
            rank = r
            break
    sq = np.sqrt(s[:rank])
    f = (u[:, :rank] * sq).astype(np.float32)
    g = (sq[:, None] * vt[:rank]).astype(np.float32)
    return f, g, rank


def _low_patterns(w_u: np.ndarray, n_bits: int, k: int) -> Tuple[int, ...]:
    low_mask = (1 << min(k, n_bits)) - 1
    return tuple(int(v) for v in np.unique(w_u & low_mask))


def _restrict_eligible(fac: DeltaFactors, patterns: Tuple[int, ...]) -> bool:
    """Whether the weight-restricted re-factorization applies — the single
    eligibility rule shared by `prepare_delta` and `restricted_rank`, so the
    adaptive correction-form decision can never diverge from what the
    preparation actually builds."""
    return (fac.rank > 0 and fac.exact
            and len(patterns) <= RESTRICT_MAX_PATTERNS)


def restricted_rank(w, *, side: str = "right", n_bits: int = 8, k: int = 4,
                    signed: bool = True, acc_bits: int = 24,
                    rank: Optional[int] = None,
                    tol: Optional[float] = None) -> int:
    """The correction rank ``prepare_delta(..., restrict=True)`` would use.

    Cheap relative to the full preparation (one cached SVD of the reached
    sub-table, no gathers over the weights) — `core.gemm.prepare_weights`
    uses it to decide, per layer, whether the rank-r' correction matmuls are
    even worth it: when r' exceeds the fixed operand's output width the
    per-element gather path (``approx_lut``) does strictly less work (the
    ROADMAP DCT-k=6 regime), and the two are bit-identical at exact rank.
    """
    fac = delta_factors(n_bits, k, signed, acc_bits, rank=rank, tol=tol)
    if fac.rank == 0:
        return 0
    w_np = np.asarray(jnp.asarray(w, jnp.int32)) & ((1 << n_bits) - 1)
    patterns = _low_patterns(w_np, n_bits, k)
    if not _restrict_eligible(fac, patterns):
        return fac.rank
    axis = 1 if side == "right" else 0
    return _restricted_factors(n_bits, k, signed, acc_bits, axis, patterns)[2]


def prepare_delta(w, *, side: str = "right", n_bits: int = 8, k: int = 4,
                  signed: bool = True, acc_bits: int = 24,
                  rank: Optional[int] = None,
                  tol: Optional[float] = None,
                  restrict: bool = True) -> PreparedDelta:
    """Precompute the fixed operand's correction factor (G_B or F_A) once.

    ``restrict=False`` skips the weight-restricted re-factorization and keeps
    the generic rank-r factors — the effective rank is then a function of the
    policy alone, so prepared operands for different weight matrices share one
    pytree structure (required when stacking per-layer preparations for a
    ``lax.scan``, as ``core.gemm.bind`` does). With ``restrict=False`` the
    fixed operand may also carry leading *stack* dimensions (scan-over-layers
    params, MoE expert stacks): the stationary factor for the whole stack is
    built by one fancy-index gather over the stacked bit patterns, and every
    array of the result keeps the stack dims in front.
    """
    if side not in ("right", "left"):
        raise ValueError(f"side must be 'right' or 'left', got {side!r}")
    fac = delta_factors(n_bits, k, signed, acc_bits, rank=rank, tol=tol)
    spec = (n_bits, k, signed, acc_bits, rank, tol)
    span = 1 << n_bits
    low_mask = (1 << min(k, n_bits)) - 1
    w_u = jnp.asarray(w, jnp.int32) & (span - 1)
    if w_u.ndim < 2:
        raise ValueError(f"prepared operand must be >= 2D, got {w_u.shape}")
    if w_u.ndim > 2 and restrict:
        raise ValueError(
            f"stacked preparation (shape {w_u.shape}) requires restrict=False")
    w_s = _signed_values(w_u, n_bits, signed)
    w_np = np.asarray(w_u)
    patterns = _low_patterns(w_np, n_bits, k) if (restrict and fac.rank) else ()
    restrict = restrict and _restrict_eligible(fac, patterns)
    if restrict:
        # E depends on the fixed operand only through its low-k bit patterns;
        # factor the reached sub-table at its own (smaller) exact rank.
        axis = 1 if side == "right" else 0
        f_np, g_np, r_eff = _restricted_factors(n_bits, k, signed, acc_bits,
                                                axis, patterns)
        pos = np.searchsorted(np.asarray(patterns), w_np & low_mask)
        if side == "right":
            gather_tab = jnp.asarray(f_np.T.copy())            # (r', span)
            g_b = g_np[:, pos]                                 # (r', K, N)
            factor = jnp.asarray(np.transpose(g_b, (1, 0, 2)).copy())
        else:
            gather_tab = jnp.asarray(g_np)                     # (r', span)
            factor = jnp.asarray(f_np[pos])                    # (M, K, r')
    else:
        r_eff = fac.rank
        if r_eff == 0:
            gather_tab = jnp.zeros((0, span), jnp.float32)
            shape = (w_np.shape[:-1] + (0,) + w_np.shape[-1:]
                     if side == "right" else w_np.shape + (0,))
            factor = jnp.zeros(shape, jnp.float32)
        elif side == "right":
            gather_tab = jnp.asarray(np.ascontiguousarray(fac.f.T))
            g_b = fac.g[:, w_np]                    # (r, *stack, K, N)
            factor = jnp.asarray(np.ascontiguousarray(
                np.moveaxis(g_b, 0, -2)))           # (*stack, K, r, N)
        else:
            gather_tab = jnp.asarray(fac.g)                    # (r, span)
            factor = jnp.asarray(fac.f[w_np])       # (*stack, M, K, r)
        stack = w_np.shape[:-2]
        if stack:
            # the moving-side table is weight-independent, but a stacked
            # preparation rides lax.scan — every leaf needs the stack dims
            gather_tab = jnp.broadcast_to(gather_tab, stack + gather_tab.shape)
    return PreparedDelta(side, r_eff, spec, w_u, w_s, gather_tab, factor)


@functools.partial(jax.jit, static_argnames=("side", "rank", "n_bits",
                                             "signed", "use_defect"))
def _delta_prepared_impl(x, w_u, w_s, factor, gather_tab, defect_flat, *,
                         side: str, rank: int, n_bits: int, signed: bool,
                         use_defect: bool):
    span = 1 << n_bits
    x_u = jnp.asarray(x, jnp.int32) & (span - 1)
    x_s = _signed_values(x_u, n_bits, signed)
    if side == "right":
        a_u, b_u = x_u, w_u
        base = _base_matmul(x_s, w_s, signed)
    else:
        a_u, b_u = w_u, x_u
        base = _base_matmul(w_s, x_s, signed)
    if rank:
        # transposed gather — r' row-contiguous sweeps over the flat moving
        # indices (far faster than per-index rank-r row gathers on CPU), then
        # one two-axis contraction against the precomputed stationary factor
        mov = jnp.take(gather_tab, x_u.reshape(-1), axis=1)
        if side == "right":
            m, kd = x_u.shape
            corr = jax.lax.dot_general(                 # (r,M,K) x (K,r,N)
                mov.reshape(rank, m, kd), factor, (((0, 2), (1, 0)), ((), ())))
        else:
            kd, n = x_u.shape
            corr = jax.lax.dot_general(                 # (M,K,r) x (r,K,N)
                factor, mov.reshape(rank, kd, n), (((1, 2), (1, 0)), ((), ())))
    else:
        corr = jnp.zeros(base.shape, jnp.float32)
    if use_defect:
        from . import lut
        corr = corr + lut.table_gather_matmul(a_u, b_u, defect_flat, span=span)
    return base + jnp.round(corr).astype(jnp.int32)


def delta_matmul_prepared(x, prep: PreparedDelta, *,
                          apply_residual: bool = True) -> jnp.ndarray:
    """Weight-stationary delta GEMM: only the moving operand ``x`` is gathered.

    ``x`` is the activations — (M, K) when the prepared weights are on the
    right, (K, N) when on the left. The whole call is one jit'd fusion of the
    exact int8 base matmul, the moving operand's rank-r' gathers, and the
    correction contraction against the precomputed stationary factor.
    Bit-identical to ``delta_matmul_ref`` / ``lut.lut_matmul`` at the exact
    rank and at any rank with ``apply_residual=True`` (single global rounding
    over correction + defect, exact while K·max|E|·eps_f32 stays far below
    0.5 — all app workloads)."""
    fac = prep.fac
    use_defect = apply_residual and not fac.exact
    if use_defect:
        _, _, defect_flat = _device_factors(fac.n_bits, fac.k, fac.signed,
                                            fac.acc_bits, fac.rank)
    else:
        defect_flat = jnp.zeros((1,), jnp.float32)
    return _delta_prepared_impl(x, prep.w_u, prep.w_s, prep.factor,
                                prep.gather_tab, defect_flat, side=prep.side,
                                rank=prep.rank, n_bits=fac.n_bits,
                                signed=fac.signed, use_defect=use_defect)


def delta_matmul_ref(a, b, *, k: int = 4, n_bits: int = 8, signed: bool = True,
                     acc_bits: int = 24, rank: Optional[int] = None,
                     tol: Optional[float] = None,
                     apply_residual: bool = True) -> jnp.ndarray:
    """Pure-jnp reference of the delta backend: base matmul + rank-r correction.

    Bit-identical to ``lut.lut_matmul`` at the exact rank, and at *any* rank
    when ``apply_residual=True`` (the defect gather restores exactness), for
    any (M, K) x (K, N).
    """
    fac = delta_factors(n_bits, k, signed, acc_bits, rank=rank, tol=tol)
    span = 1 << n_bits
    mask = span - 1
    half = span >> 1
    a_u = jnp.asarray(a, jnp.int32) & mask                    # (M, K) bit patterns
    b_u = jnp.asarray(b, jnp.int32) & mask                    # (K, N)
    if signed:
        a_s = (a_u ^ half) - half                             # sign-extend
        b_s = (b_u ^ half) - half
    else:
        a_s, b_s = a_u, b_u
    out = a_s @ b_s                                           # exact int32 base
    corr = _correction(a_u, b_u, fac) if fac.rank else jnp.zeros(out.shape,
                                                                 jnp.float32)
    if apply_residual and not fac.exact:
        corr = corr + defect_gather_matmul(a_u, b_u, fac)
    return out + jnp.round(corr).astype(jnp.int32)
