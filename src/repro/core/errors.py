"""Error metrics for approximate arithmetic (Liang/Han/Lombardi metrics [16]).

Reproduces the paper's Table V methodology: exhaustively sweep all 2^{2N} operand
pairs of the N-bit PE (c = 0), compare approximate vs exact output, and report

* ER    — error rate, fraction of pairs with any deviation
* MED   — mean |error distance|
* NMED  — MED normalized by the maximum output magnitude
* MRED  — mean relative error distance |ED| / max(1, |exact|)
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from .emulate import pe_mac


def _all_pairs(n_bits: int, signed: bool):
    span = 1 << n_bits
    if signed:
        vals = np.arange(span, dtype=np.int32) - (span >> 1)
    else:
        vals = np.arange(span, dtype=np.int32)
    a = np.repeat(vals, span)
    b = np.tile(vals, span)
    return a, b


def max_output_magnitude(n_bits: int, signed: bool) -> int:
    if signed:
        return (1 << (n_bits - 1)) ** 2          # (-2^{N-1})^2
    return ((1 << n_bits) - 1) ** 2


def pe_error_metrics(n_bits: int = 8, k: int = 6, signed: bool = True,
                     acc_bits: int = 24) -> Dict[str, float]:
    """Exhaustive Table-V style metrics for the approximate PE at factor k."""
    a, b = _all_pairs(n_bits, signed)
    approx = np.asarray(pe_mac(a, b, 0, n_bits=n_bits, k=k, signed=signed,
                               acc_bits=acc_bits), np.int64)
    exact = (a.astype(np.int64) * b.astype(np.int64))
    ed = np.abs(approx - exact)
    denom = np.maximum(1, np.abs(exact))
    return {
        "ER": float((ed > 0).mean()),
        "MED": float(ed.mean()),
        "NMED": float(ed.mean() / max_output_magnitude(n_bits, signed)),
        "MRED": float((ed / denom).mean()),
        "MAX_ED": int(ed.max()),
    }


def gemm_error_metrics(approx: np.ndarray, exact: np.ndarray) -> Dict[str, float]:
    """Error metrics between two GEMM outputs (used by application benchmarks)."""
    approx = np.asarray(approx, np.int64)
    exact = np.asarray(exact, np.int64)
    ed = np.abs(approx - exact)
    denom = np.maximum(1, np.abs(exact))
    scale = max(1, int(np.abs(exact).max()))
    return {
        "ER": float((ed > 0).mean()),
        "MED": float(ed.mean()),
        "NMED": float(ed.mean() / scale),
        "MRED": float((ed / denom).mean()),
    }


def psnr(ref: np.ndarray, test: np.ndarray, peak: float = 255.0) -> float:
    """PSNR in dB of `test` against `ref` (paper compares against exact output)."""
    ref = np.asarray(ref, np.float64)
    test = np.asarray(test, np.float64)
    mse = np.mean((ref - test) ** 2)
    if mse == 0:
        return float("inf")
    return float(10.0 * np.log10(peak * peak / mse))


def ssim(ref: np.ndarray, test: np.ndarray, peak: float = 255.0) -> float:
    """Global-window SSIM with gaussian 11x11, matching the standard definition."""
    from numpy.lib.stride_tricks import sliding_window_view

    ref = np.asarray(ref, np.float64)
    test = np.asarray(test, np.float64)
    if ref.ndim != 2:
        ref = ref.reshape(ref.shape[-2:])
        test = test.reshape(test.shape[-2:])
    k1, k2, win = 0.01, 0.03, 11
    c1, c2 = (k1 * peak) ** 2, (k2 * peak) ** 2
    if min(ref.shape) < win:
        win = min(ref.shape) | 1
    ax = np.arange(win) - win // 2
    g = np.exp(-(ax ** 2) / (2 * 1.5 ** 2))
    kern = np.outer(g, g)
    kern /= kern.sum()

    def filt(img):
        v = sliding_window_view(img, (win, win))
        return np.einsum("ijkl,kl->ij", v, kern)

    mu_r, mu_t = filt(ref), filt(test)
    sig_r = filt(ref * ref) - mu_r ** 2
    sig_t = filt(test * test) - mu_t ** 2
    sig_rt = filt(ref * test) - mu_r * mu_t
    num = (2 * mu_r * mu_t + c1) * (2 * sig_rt + c2)
    den = (mu_r ** 2 + mu_t ** 2 + c1) * (sig_r + sig_t + c2)
    return float((num / den).mean())
