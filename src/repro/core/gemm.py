"""GEMM backend registry — the paper's technique as a first-class framework feature.

Every matmul in every model goes through `sa_dot(x, w, policy, layer=...)`. The
policy selects, per layer, which arithmetic executes it:

* ``exact``         — float dot (bf16/f32); the production path for training and
                      the large-model dry-runs (the MXU *is* the exact PE array).
* ``mxu_int8``      — symmetric int8 quantize -> exact int8 systolic GEMM (Pallas
                      kernel on TPU, jnp fallback elsewhere) -> dequantize.
* ``approx_lut``    — int8 quantize -> approximate GEMM via the PE product table at
                      factor k (Pallas gather kernel / jnp fallback) -> dequantize.
* ``approx_oracle`` — int8 quantize -> full fused bit-level PE-chain oracle.
* ``approx_onehot`` — one-hot rewrite running the approximate GEMM on the exact MXU.
* ``approx_delta``  — exact int8 MXU matmul + rank-r error-correction matmul
                      (core/error_delta.py): bit-identical to ``approx_lut`` at the
                      default (exact) rank, but MXU-resident — the fast path for
                      activations that change every call.

The per-layer policy generalizes the paper's hybrid BDCN (approximate early blocks,
exact later blocks) to arbitrary networks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax.numpy as jnp

from . import emulate, lut, quant

BACKENDS = ("exact", "mxu_int8", "approx_lut", "approx_oracle", "approx_onehot",
            "approx_delta")


@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """Which backend executes each layer's matmuls.

    `backend` is the default; `overrides` maps layer-name prefixes to backends
    (longest prefix wins), mirroring the paper's hybrid early-approx/late-exact BDCN.
    `k` is the approximation factor for approximate backends. `delta_rank` /
    `delta_tol` tune the ``approx_delta`` correction rank (None = exact rank,
    bit-identical to ``approx_lut``; a tolerance trades correction FLOPs for a
    bounded per-product error on top of the paper's approximation).
    """
    backend: str = "exact"
    k: int = 4
    n_bits: int = 8
    acc_bits: int = 24
    overrides: Optional[Dict[str, str]] = None
    delta_rank: Optional[int] = None
    delta_tol: Optional[float] = None

    def resolve(self, layer: str = "") -> str:
        if self.overrides:
            best = ""
            choice = self.backend
            for prefix, be in self.overrides.items():
                if layer.startswith(prefix) and len(prefix) > len(best):
                    best, choice = prefix, be
            return choice
        return self.backend


EXACT = GemmPolicy(backend="exact")


def _int_gemm(x_q, w_q, backend: str, policy: GemmPolicy):
    if backend == "mxu_int8":
        from repro.kernels import ops
        return ops.systolic_matmul(x_q, w_q)
    if backend == "approx_lut":
        from repro.kernels import ops
        return ops.approx_matmul(x_q, w_q, k=policy.k, n_bits=policy.n_bits,
                                 acc_bits=policy.acc_bits)
    if backend == "approx_oracle":
        return emulate.matmul_oracle(x_q, w_q, n_bits=policy.n_bits, k=policy.k,
                                     acc_bits=policy.acc_bits)
    if backend == "approx_onehot":
        t_b = lut.build_onehot_weights(w_q, n_bits=policy.n_bits, k=policy.k,
                                       acc_bits=policy.acc_bits)
        return lut.onehot_matmul(x_q, t_b, n_bits=policy.n_bits)
    if backend == "approx_delta":
        from repro.kernels import ops
        return ops.approx_delta_matmul(x_q, w_q, k=policy.k,
                                       n_bits=policy.n_bits,
                                       acc_bits=policy.acc_bits,
                                       rank=policy.delta_rank,
                                       tol=policy.delta_tol)
    raise ValueError(f"unknown integer backend {backend!r}")


def sa_dot(x: jnp.ndarray, w: jnp.ndarray, policy: GemmPolicy = EXACT, *,
           layer: str = "") -> jnp.ndarray:
    """Systolic-array dot: (..., K) x (K, N) -> (..., N) under the layer's backend."""
    backend = policy.resolve(layer)
    if backend == "exact":
        return jnp.matmul(x, w)
    lead = x.shape[:-1]
    k_dim = x.shape[-1]
    x2 = x.reshape(-1, k_dim)
    xq = quant.quantize(x2, n_bits=policy.n_bits)
    wq = quant.quantize(w, n_bits=policy.n_bits, axis=0)   # per-output-channel
    acc = _int_gemm(xq.values, wq.values, backend, policy)
    out = acc.astype(jnp.float32) * xq.scale * wq.scale
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)


def int_matmul(x_q, w_q, policy: GemmPolicy, *, layer: str = ""):
    """Integer-in/integer-out GEMM under the policy (no (de)quantization)."""
    backend = policy.resolve(layer)
    if backend == "exact":
        return jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    return _int_gemm(x_q, w_q, backend, policy)
