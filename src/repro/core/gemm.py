"""GEMM backend registry — the paper's technique as a first-class framework feature.

Every matmul in every model and app goes through **one** entry point,
`dot(a, b, policy, layer=...)`. The policy selects, per layer, which arithmetic
executes it:

* ``exact``         — float dot (bf16/f32); the production path for training and
                      the large-model dry-runs (the MXU *is* the exact PE array).
* ``mxu_int8``      — symmetric int8 quantize -> exact int8 systolic GEMM (Pallas
                      kernel on TPU, jnp fallback elsewhere) -> dequantize.
* ``approx_lut``    — int8 quantize -> approximate GEMM via the PE product table at
                      factor k (Pallas gather kernel / jnp fallback) -> dequantize.
* ``approx_oracle`` — int8 quantize -> full fused bit-level PE-chain oracle.
* ``approx_onehot`` — one-hot rewrite running the approximate GEMM on the exact MXU.
* ``approx_delta``  — exact int8 MXU matmul + rank-r error-correction matmul
                      (core/error_delta.py): bit-identical to ``approx_lut`` at the
                      default (exact) rank, but MXU-resident — the fast path for
                      activations that change every call.

``dot`` accepts raw floats (quantize -> integer GEMM -> dequantize), raw
integers (integer-in / int32-out), or a ``PreparedOperand`` on either side —
the paper's weight-stationary dataflow: the fixed operand's quantization and
backend precompute (delta factors, one-hot tables) are done **once** and every
call pays only for the moving operand. ``bind(params, policy)`` applies this
to a whole model parameter pytree, returning ``BoundParams`` that the model
stack accepts interchangeably with raw params — decode then runs fully
weight-stationary.

The per-layer policy generalizes the paper's hybrid BDCN (approximate early
blocks, exact later blocks) to arbitrary networks.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import emulate, lut, quant

BACKENDS = ("exact", "mxu_int8", "approx_lut", "approx_oracle", "approx_onehot",
            "approx_delta")
GUARDS = ("none", "detect", "recompute")     # GemmPolicy.guard modes


@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """Which backend executes each layer's matmuls.

    `backend` is the default; `overrides` maps layer-name prefixes to backends
    (longest prefix wins; the empty prefix matches every layer and acts as a
    default-override), mirroring the paper's hybrid early-approx/late-exact
    BDCN. `k` is the approximation factor for approximate backends.
    `delta_rank` / `delta_tol` tune the ``approx_delta`` correction rank
    (None = exact rank, bit-identical to ``approx_lut``; a tolerance trades
    correction FLOPs for a bounded per-product error on top of the paper's
    approximation).

    ``delta_adaptive`` auto-selects the correction *form* per layer on the
    weight-stationary path: when the weight-restricted rank r' of a prepared
    layer exceeds its output width, the rank-r' correction matmuls cost more
    than the per-element gather they replace (the ROADMAP DCT-k=6 regime),
    so ``resolve`` falls back to ``approx_lut`` for that layer — bit-
    identical output, strictly less work. `prepare_weights` supplies the
    (out_width, delta_rank) hints; without hints resolution is unchanged.

    ``guard`` selects the ABFT fault-detection mode (core/abft.py):
    ``'none'`` (default, zero overhead), ``'detect'`` (checksum every GEMM,
    raise/record ``AbftFaultError`` on mismatch), or ``'recompute'``
    (re-execute a flagged tile once and re-check). Thresholds come from the
    approximation's own error bounds, so intended approximation error never
    false-positives.
    """
    backend: str = "exact"
    k: int = 4
    n_bits: int = 8
    acc_bits: int = 24
    overrides: Optional[Dict[str, str]] = None
    delta_rank: Optional[int] = None
    delta_tol: Optional[float] = None
    delta_adaptive: bool = False
    guard: str = "none"

    def resolve(self, layer: str = "", *, out_width: Optional[int] = None,
                delta_rank: Optional[int] = None) -> str:
        if self.overrides:
            best = None
            choice = self.backend
            for prefix, be in self.overrides.items():
                if layer.startswith(prefix) and (best is None
                                                 or len(prefix) > len(best)):
                    best, choice = prefix, be
        else:
            choice = self.backend
        if (choice == "approx_delta" and self.delta_adaptive
                and out_width is not None and delta_rank is not None
                and delta_rank > out_width):
            return "approx_lut"
        return choice


EXACT = GemmPolicy(backend="exact")


def as_policy(policy=None, *, backend: str = "approx_lut",
              k: Optional[int] = None) -> GemmPolicy:
    """Coerce ``None`` / a backend name / a GemmPolicy into a GemmPolicy.

    Application entry points accept all three; ``k`` (when given) overrides
    the policy's approximation factor, so apps can sweep k under one policy.
    """
    if policy is None:
        policy = GemmPolicy(backend=backend)
    elif isinstance(policy, str):
        if policy not in BACKENDS:
            raise ValueError(f"unknown backend {policy!r}; one of {BACKENDS}")
        policy = GemmPolicy(backend=policy)
    elif not isinstance(policy, GemmPolicy):
        raise TypeError(f"policy must be None, a backend name or a GemmPolicy,"
                        f" got {type(policy).__name__}")
    if k is not None and policy.k != k:
        policy = dataclasses.replace(policy, k=k)
    if policy.guard not in GUARDS:
        raise ValueError(f"unknown guard {policy.guard!r}; "
                         "one of ('none', 'detect', 'recompute')")
    return policy


def _int_gemm(x_q, w_q, backend: str, policy: GemmPolicy):
    if backend == "mxu_int8":
        from repro.kernels import ops
        return ops.systolic_matmul(x_q, w_q)
    if backend == "approx_lut":
        from repro.kernels import ops
        return ops.approx_matmul(x_q, w_q, k=policy.k, n_bits=policy.n_bits,
                                 acc_bits=policy.acc_bits)
    if backend == "approx_oracle":
        return emulate.matmul_oracle(x_q, w_q, n_bits=policy.n_bits, k=policy.k,
                                     acc_bits=policy.acc_bits)
    if backend == "approx_onehot":
        t_b = lut.build_onehot_weights(w_q, n_bits=policy.n_bits, k=policy.k,
                                       acc_bits=policy.acc_bits)
        return lut.onehot_matmul(x_q, t_b, n_bits=policy.n_bits)
    if backend == "approx_delta":
        from repro.kernels import ops
        return ops.approx_delta_matmul(x_q, w_q, k=policy.k,
                                       n_bits=policy.n_bits,
                                       acc_bits=policy.acc_bits,
                                       rank=policy.delta_rank,
                                       tol=policy.delta_tol)
    raise ValueError(f"unknown integer backend {backend!r}")


def _guard_mm(mm2d, policy: GemmPolicy, backend: str, layer: str, prep=None):
    """Wrap a 2-D integer matmul closure with the ABFT output-checksum guard.

    The wrapped closure receives the actual 2-D operands (the batched-app
    shim hands them over flattened), so the checksum matvecs see exactly what
    the kernel saw. ``prep`` supplies the clean-weight checksum metadata when
    the fixed operand was prepared (``core.abft.AbftMeta``), which pins the
    expected-value matvec to the *bind-time* weights.
    """
    if policy.guard == "none":
        return mm2d
    from . import abft
    meta = getattr(prep, "abft", None) if prep is not None else None
    meta_side = prep.side if prep is not None else "right"

    def guarded(a, b):
        acc = mm2d(a, b)
        return abft.guard_int_matmul(
            acc, a, b, policy=policy, backend=backend, layer=layer,
            meta=meta, meta_side=meta_side,
            recompute_fn=lambda: mm2d(a, b))
    return guarded


def _check_prepared(prep, backend: str, policy: GemmPolicy, layer: str) -> None:
    mismatches = []
    # the adaptive form: prepare_weights may resolve an approx_delta layer to
    # the (bit-identical) gather path when its restricted rank exceeds the
    # output width — accept the lut-prepared operand under the delta policy.
    # Only at the exact rank: a truncated delta_rank/delta_tol policy has no
    # bit-identical gather counterpart, so there the mismatch stays fatal.
    adaptive_ok = (policy.delta_adaptive and backend == "approx_delta"
                   and prep.backend == "approx_lut"
                   and policy.delta_rank is None and policy.delta_tol is None)
    if prep.backend != backend and not adaptive_ok:
        mismatches.append(f"backend {prep.backend!r} != {backend!r}")
    if prep.k != policy.k:
        mismatches.append(f"k {prep.k} != {policy.k}")
    if (prep.n_bits, prep.acc_bits) != (policy.n_bits, policy.acc_bits):
        mismatches.append("n_bits/acc_bits differ")
    if (backend == "approx_delta" and not adaptive_ok
            and (prep.rank, prep.tol) != (policy.delta_rank,
                                          policy.delta_tol)):
        mismatches.append("delta_rank/delta_tol differ")
    if mismatches:
        raise ValueError(
            f"prepared operand is stale for layer {layer!r}: "
            + "; ".join(mismatches)
            + " — re-run prepare_weights under the current policy")


def _is_float(x) -> bool:
    dt = getattr(x, "dtype", None)
    if dt is None:
        return isinstance(x, float)
    return jnp.issubdtype(dt, jnp.floating)


def _dequant(acc, x_scale, w_scale):
    """acc * x_scale * w_scale with a pinned evaluation order.

    The two scales are combined in float32 *first*, then applied in a single
    multiply. Writing the chain as ``(acc * s_x) * s_w`` lets XLA's broadcast
    simplifier reassociate it differently depending on whether the weight
    scale is computed inline (unbound) or arrives as an input (bound), which
    breaks bit-parity between the two paths; this canonical form is stable.
    """
    scale = x_scale.astype(jnp.float32) * w_scale.astype(jnp.float32)
    return acc.astype(jnp.float32) * scale


def _round_to(out_f32, dtype):
    """Cast the f32 dequantized output to `dtype`, pinning the rounding.

    A plain ``astype`` to a narrow float emits a convert that XLA's
    excess-precision folding may collapse with a downstream widening convert
    — whether it fires depends on the surrounding graph, so bound and unbound
    programs could hand different bits to the next layer. ``reduce_precision``
    performs the same rounding but is never folded, making the handed-off
    value context-independent.
    """
    if dtype == jnp.float32 or not jnp.issubdtype(dtype, jnp.floating):
        return out_f32.astype(dtype)
    fi = jnp.finfo(dtype)
    return jax.lax.reduce_precision(out_f32, fi.nexp, fi.nmant).astype(dtype)


# ---------------------------------------------------------------------------
# The unified entry point
# ---------------------------------------------------------------------------

def dot(a, b, policy: GemmPolicy = EXACT, *, layer: str = "",
        grouped: bool = False) -> jnp.ndarray:
    """One GEMM entry point for the whole stack (models, apps, kernels).

    Operand forms (either side, at most one prepared):

    * **raw floats** — the model path: the 2-D right-hand weight is quantized
      per-output-channel, the moving activations per-row (one scale per
      token, so a token's bits never depend on what else shares its batch —
      the invariant the continuous-batching serve engine relies on for
      per-request determinism), the integer GEMM runs under the layer's
      backend, and the result is dequantized back to the activations' dtype.
      ``backend="exact"`` is a plain float matmul.
    * **raw integers** — the app path (previously ``execute``/``int_matmul``):
      integer-in / int32-out under the layer's backend, batched operands
      flattened onto the 2D kernels by ``kernels.ops.batched_app_matmul``.
    * **a ``PreparedOperand``** — the weight-stationary path: built by
      ``prepare_weights`` (or ``bind`` for a whole model), its position must
      match the side it was prepared for. A prepared operand carrying a
      dequantization ``scale`` (prepared from floats) makes the call float-in
      / float-out with only the *moving* operand quantized per call; without
      a scale the call is integer-in / int32-out.
    * **grouped** — pass ``grouped=True`` for ``(G, M, K) x (G, K, N)``
      pairs sharing a leading group dim (MoE expert stacks): per-group
      quantization/preparation via ``kernels.ops.grouped_matmul``. Explicit
      rather than inferred, because a batched activation against a stacked
      3-D weight is shape-indistinguishable whenever the batch equals the
      stack size — inference would silently compute per-slice GEMMs. A
      *stacked prepared* operand is unambiguous and dispatches on its own.
    """
    from repro.kernels import ops
    policy = as_policy(policy, backend="exact")
    backend = policy.resolve(layer)
    a_prep = isinstance(a, ops.PreparedOperand)
    b_prep = isinstance(b, ops.PreparedOperand)
    if a_prep and b_prep:
        raise ValueError("at most one operand may be prepared")
    if a_prep or b_prep:
        prep = a if a_prep else b
        want_side = "left" if a_prep else "right"
        if prep.side != want_side:
            raise ValueError(
                f"operand prepared for side {prep.side!r} passed as "
                f"the {want_side} operand")
        _check_prepared(prep, backend, policy, layer)
        x = b if a_prep else a
        if prep.scale is not None and not _is_float(x):
            raise ValueError(
                f"layer {layer!r}: operand prepared from float weights "
                "needs a float moving operand (got integer input)")
        if prep.scale is None and _is_float(x):
            raise ValueError(
                f"layer {layer!r}: operand prepared from integer weights "
                "used with float input — prepare from the float weights "
                "instead so a dequantization scale is attached")
        if policy.guard != "none":
            from . import abft
            abft.verify_tables(policy, prep.backend, layer=layer)
            abft.guard_weight_meta(prep, layer=layer, guard=policy.guard)
        if prep.values.ndim > 2:                    # stacked (grouped) prepare
            return _dot_grouped(x, prep, policy, layer)
        if prep.scale is not None:
            return _dot_float_prepared(x, prep, policy, layer)
        x = jnp.asarray(x, jnp.int32)
        if a_prep:
            mm = lambda _, bb: ops.prepared_matmul(bb, prep)  # noqa: E731
            mm = _guard_mm(mm, policy, prep.backend, layer, prep)
            return ops.batched_app_matmul(mm, prep.values, x)
        mm = lambda aa, _: ops.prepared_matmul(aa, prep)      # noqa: E731
        mm = _guard_mm(mm, policy, prep.backend, layer, prep)
        return ops.batched_app_matmul(mm, x, prep.values)

    a = jnp.asarray(a)
    b = jnp.asarray(b)
    float_mode = _is_float(a) or _is_float(b)
    if grouped and not (a.ndim == 3 and b.ndim == 3
                        and a.shape[0] == b.shape[0]):
        raise ValueError(f"grouped=True wants (G, M, K) x (G, K, N), got "
                         f"{a.shape} x {b.shape}")
    if policy.guard != "none" and backend not in ("exact",):
        from . import abft
        abft.verify_tables(policy, backend, layer=layer)
    if not float_mode:
        a = a.astype(jnp.int32)
        b = b.astype(jnp.int32)
        if backend == "exact":
            if grouped:
                return jnp.matmul(a, b)
            mm = _guard_mm(jnp.matmul, policy, "exact", layer)
            return ops.batched_app_matmul(mm, a, b)
        mm = lambda aa, bb: _int_gemm(aa, bb, backend, policy)    # noqa: E731
        mm = _guard_mm(mm, policy, backend, layer)
        if grouped:
            return ops.grouped_matmul(mm, a, b)
        return ops.batched_app_matmul(mm, a, b)

    if backend == "exact":
        out = jnp.matmul(a, b)
        if policy.guard != "none" and b.ndim == 2:
            from . import abft
            out = abft.guard_float_matmul(out, a, b, policy=policy,
                                          layer=layer)
        return out
    if grouped:
        return _dot_grouped(a, b, policy, layer)
    if b.ndim != 2:
        raise ValueError(
            f"layer {layer!r}: the float path needs a 2-D right-hand weight "
            f"(got {a.shape} x {b.shape}); use prepare_weights(side='left') "
            "for fixed left operands")
    lead = a.shape[:-1]
    k_dim = a.shape[-1]
    x2 = a.reshape(-1, k_dim)
    xq = quant.quantize(x2, n_bits=policy.n_bits, axis=-1)  # per-row (token)
    wq = quant.quantize(b, n_bits=policy.n_bits, axis=0)   # per-output-channel
    mm = _guard_mm(lambda aa, bb: _int_gemm(aa, bb, backend, policy),
                   policy, backend, layer)
    acc = mm(xq.values, wq.values)
    out = _dequant(acc, xq.scale, wq.scale)
    return _round_to(out.reshape(*lead, b.shape[-1]), a.dtype)


def _dot_float_prepared(x, prep, policy: GemmPolicy,
                        layer: str = "") -> jnp.ndarray:
    """Float-in/float-out against a float-prepared (scaled) fixed operand.

    Mirrors the unprepared float path bit-for-bit: the moving operand is
    quantized per-row (per-column when the fixed operand is on the left)
    exactly as there, the integer GEMM is the same backend kernel, and the
    dequantization multiplies the same two scales.
    """
    from repro.kernels import ops
    x = jnp.asarray(x)
    if prep.side == "right":
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        xq = quant.quantize(x2, n_bits=policy.n_bits, axis=-1)     # per-row
        mm = lambda aa, _: ops.prepared_matmul(aa, prep)           # noqa: E731
        mm = _guard_mm(mm, policy, prep.backend, layer, prep)
        acc = mm(xq.values, prep.values)
        out = _dequant(acc, xq.scale, prep.scale)          # (R, 1) x (1, N)
        return _round_to(out.reshape(*lead, prep.values.shape[-1]), x.dtype)
    # fixed left operand W (M, K) x moving (..., K, N)
    xq = quant.quantize(x, n_bits=policy.n_bits, axis=-2)          # per-column
    mm = lambda _, bb: ops.prepared_matmul(bb, prep)               # noqa: E731
    mm = _guard_mm(mm, policy, prep.backend, layer, prep)
    acc = ops.batched_app_matmul(mm, prep.values, xq.values)
    out = _dequant(acc, xq.scale, prep.scale)          # (M, 1) x (..., 1, N)
    return _round_to(out, x.dtype)


def _dot_grouped(x, w_or_prep, policy: GemmPolicy, layer: str) -> jnp.ndarray:
    """Grouped GEMM (MoE experts): per-group quantize/prepare, 2-D kernels."""
    from repro.kernels import ops
    x = jnp.asarray(x)
    if _is_float(x):
        def mm(x2, w2):
            if isinstance(w2, ops.PreparedOperand):
                return _dot_float_prepared(x2, w2, policy, layer)
            xq = quant.quantize(x2, n_bits=policy.n_bits, axis=-1)
            wq = quant.quantize(w2, n_bits=policy.n_bits, axis=0)
            backend = policy.resolve(layer)
            gm = _guard_mm(lambda aa, bb: _int_gemm(aa, bb, backend, policy),
                           policy, backend, layer)
            acc = gm(xq.values, wq.values)
            return _round_to(_dequant(acc, xq.scale, wq.scale), x2.dtype)
        return ops.grouped_matmul(mm, x, w_or_prep)
    x = x.astype(jnp.int32)
    if isinstance(w_or_prep, ops.PreparedOperand):
        def mm(x2, p2):
            gm = lambda aa, _: ops.prepared_matmul(aa, p2)         # noqa: E731
            gm = _guard_mm(gm, policy, p2.backend, layer, p2)
            return gm(x2, p2.values)
    else:
        backend = policy.resolve(layer)
        mm = _guard_mm(lambda x2, w2: _int_gemm(x2, w2, backend, policy),
                       policy, backend, layer)
    return ops.grouped_matmul(mm, x, w_or_prep)


# ---------------------------------------------------------------------------
# Weight preparation + bound parameter pytrees
# ---------------------------------------------------------------------------

def prepare_weights(w, policy: GemmPolicy, *, layer: str = "",
                    side: str = "right", restrict: bool = True):
    """Precompute the backend-specific factor for a fixed weight matrix.

    Returns a ``kernels.ops.PreparedOperand`` that ``dot`` accepts in place
    of the raw matrix. Integer weights prepare as-is (integer-in/int32-out
    calls); **float** weights are first quantized per-output-channel (the
    second-to-last axis for ``side="right"``, the last for ``side="left"`` —
    the output dimension either way) and the scale is attached, so ``dot``
    runs float-in/float-out quantizing only the moving activations per call.

    For ``approx_delta`` this builds the rank-r ``G_B`` (or ``F_A`` for
    ``side="left"``, e.g. the DCT matrix multiplying from the left) once; for
    ``approx_onehot`` the ``T_B`` table. Prepare once per (weights, policy,
    layer) and reuse across every call — or use ``bind`` for a whole model.

    ``w`` may carry extra *leading* stack dimensions (scan-over-layers
    params, MoE expert stacks); the whole stack is then quantized and
    prepared in one vectorized pass — a single gather over the stacked bit
    patterns instead of a host loop over slices. Stacked preparation
    requires ``restrict=False`` so every slice shares one rank and the
    prepared pytree can ride a ``lax.scan`` (see ``bind``).

    Under ``policy.delta_adaptive``, an ``approx_delta`` layer whose
    weight-restricted rank exceeds its output width is prepared for the
    (bit-identical) ``approx_lut`` gather path instead — the per-layer
    correction-form auto-selection (`GemmPolicy.resolve` hints). Adaptive
    selection needs the restricted rank, so it applies to the 2-D
    ``restrict=True`` path only (stacked/bound preparations share the
    generic rank and keep the delta form).
    """
    from repro.core import error_delta
    from repro.kernels import ops
    backend = policy.resolve(layer)
    scale = None
    if _is_float(w):
        if backend == "exact":
            raise ValueError(
                f"layer {layer!r} resolves to the exact float backend — "
                "nothing to prepare; pass the raw weights to dot()")
        axis = -2 if side == "right" else -1
        wq = quant.quantize(jnp.asarray(w), n_bits=policy.n_bits, axis=axis)
        w, scale = wq.values, wq.scale
    if (backend == "approx_delta" and policy.delta_adaptive and restrict
            and policy.delta_rank is None and policy.delta_tol is None
            and getattr(w, "ndim", 0) == 2):
        # adaptive only at the exact (default) rank, where the delta and
        # gather forms are bit-identical — a truncated delta_rank/delta_tol
        # correction is deliberately approximate and must not be silently
        # swapped for the exact gather path
        r_eff = error_delta.restricted_rank(
            w, side=side, n_bits=policy.n_bits, k=policy.k,
            acc_bits=policy.acc_bits)
        out_w = w.shape[-1] if side == "right" else w.shape[-2]
        backend = policy.resolve(layer, out_width=out_w, delta_rank=r_eff)
    prep = ops.prepare_operand(w, backend=backend, k=policy.k,
                               n_bits=policy.n_bits, acc_bits=policy.acc_bits,
                               side=side, rank=policy.delta_rank,
                               tol=policy.delta_tol, restrict=restrict)
    if scale is not None:
        prep = dataclasses.replace(prep, scale=scale)
    # clean-weight checksums for the ABFT guard — attached unconditionally
    # (cheap, and keeps the prepared pytree structure guard-independent, so
    # the prepared-weights cache and jitted consumers never fork on `guard`)
    from . import abft
    prep = dataclasses.replace(
        prep, abft=abft.meta_for(prep.values, abft.prep_derived(prep)))
    return prep


_PREPARED_CACHE: "OrderedDict[tuple, Any]" = OrderedDict()
_PREPARED_CACHE_MAX = 256


def prepare_weights_cached(w, policy: GemmPolicy, *, layer: str = "",
                           side: str = "right", restrict: bool = True):
    """``prepare_weights`` memoized by weight *value* and policy parameters.

    Callers hit this on genuinely fixed matrices (the DCT matrix, conv
    kernels, model weights under ``bind``) so repeated forwards — every k of
    a sweep, every benchmark rep, every re-bind — reuse the stationary
    precompute. Keys hold a 16-byte BLAKE2b digest of the weight bytes (not
    the bytes themselves, which would pin every weight matrix alive in the
    key); shape/dtype ride along so a digest collision across layouts cannot
    alias. Eviction is LRU: the least-recently-used entry is dropped when the
    cache is full, so a long sweep no longer dumps the whole working set.
    """
    w_np = np.ascontiguousarray(np.asarray(w))
    digest = hashlib.blake2b(w_np.tobytes(), digest_size=16).digest()
    key = (digest, w_np.shape, w_np.dtype.str, policy.resolve(layer),
           policy.k, policy.n_bits, policy.acc_bits, policy.delta_rank,
           policy.delta_tol, policy.delta_adaptive, side, restrict)
    hit = _PREPARED_CACHE.get(key)
    if hit is not None:
        _PREPARED_CACHE.move_to_end(key)
        return hit
    hit = prepare_weights(w_np, policy, layer=layer, side=side,
                          restrict=restrict)
    _PREPARED_CACHE[key] = hit
    while len(_PREPARED_CACHE) > _PREPARED_CACHE_MAX:
        _PREPARED_CACHE.popitem(last=False)
    return hit


class BoundParams(dict):
    """A model parameter pytree whose weight leaves are policy-prepared.

    Behaves exactly like the raw params dict (same keys, same indexing, a
    registered pytree) so models, step builders, and the serving/eval loops
    accept it interchangeably with raw params — but every 2-D weight leaf
    that ``bind`` recognized is a ``PreparedOperand``: quantized once,
    backend factors built once, zero per-call weight work on the decode path.
    """


# Registered *with keys* so path-based flattening yields DictKeys, exactly
# like a plain dict — `bind` derives layer names from key paths, and a
# keyless registration would make re-binding a BoundParams under a new
# policy silently skip every top-level leaf (the path would carry an opaque
# FlattenedIndexKey instead of the leaf's name).
jax.tree_util.register_pytree_with_keys(
    BoundParams,
    lambda bp: (tuple((jax.tree_util.DictKey(k), bp[k]) for k in sorted(bp)),
                tuple(sorted(bp))),
    lambda keys, ch: BoundParams(zip(keys, ch)))


# Path components that are pure structure (stacking containers); they are
# dropped when deriving a leaf's layer name so bind-time names match the
# `layer=` strings the model code passes to `dot`.
STRUCTURAL_KEYS = frozenset({
    "layers", "groups", "tail", "mlstm_blocks", "slstm_blocks", "shared_attn",
})

# Leaf names that are 2-D GEMM weights consumed through `dot` (everything
# else — embeddings gathered by index, router logits, conv filters, gate
# matrices, norms — stays raw).
BINDABLE_LEAVES = frozenset({
    "wq", "wk", "wv", "wo", "w1", "w2", "w3", "up", "down", "w_in", "out",
    "in_proj", "out_proj", "lm_head", "patch_proj",
})


def default_layer_name(path) -> Optional[str]:
    """Map a pytree key path to the `layer=` name its `dot` call site uses.

    Structural container keys are dropped; the rest join with ``/`` — e.g.
    ``("layers", "attn", "wq") -> "attn/wq"``, ``("shared_attn", "mlp",
    "w1") -> "mlp/w1"``, ``("layers", "moe", "w1") -> "moe/w1"``. Returns
    ``None`` for leaves that are not bindable GEMM weights.
    """
    keys = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    if not keys or keys[-1] not in BINDABLE_LEAVES:
        return None
    return "/".join(k for k in keys if k not in STRUCTURAL_KEYS)


def _bind_leaf(w, policy: GemmPolicy, name: str, cached: bool):
    """Prepare one weight leaf; extra leading dims are per-layer/expert stacks."""
    prep_fn = prepare_weights_cached if cached else prepare_weights
    if w.ndim == 2:
        return prep_fn(w, policy, layer=name)
    # Stacked weights (scan-over-layers params, MoE expert stacks): one
    # vectorized quantize + one gather over the stacked bit patterns, with
    # the generic (unrestricted) factors so all slices share one rank/pytree
    # structure. lax.scan / indexed tree.map slice the stack back off at run
    # time.
    return prep_fn(w, policy, layer=name, restrict=False)


def bind(params, policy: GemmPolicy, *,
         layer_fn: Optional[Callable] = None,
         tie_lm_head: bool = True, cached: bool = True) -> Any:
    """Bind a model parameter pytree to a policy: weight-stationary serving.

    Walks ``params``, and for every float 2-D (or stacked 3-D/4-D) weight
    leaf whose derived layer name resolves to a non-exact backend, replaces
    it with a ``PreparedOperand`` — quantized per-output-channel and
    backend-prepared **once**. Leaves under exact layers, non-GEMM leaves
    (embeddings, norms, routers, conv filters) and already-prepared leaves
    pass through untouched, so ``bind`` is idempotent and the result is
    accepted anywhere raw params are (models, ``launch.steps`` step builders,
    ``launch.serve``, ``train.loop.evaluate``).

    ``layer_fn(path) -> Optional[str]`` overrides ``default_layer_name`` to
    customize the path -> layer-name mapping. ``cached=False`` skips the
    module-level prepared-weights cache — use it when binding *transient*
    params (e.g. mid-training eval of the current optimizer state): those
    weights never repeat, so caching them would only pin dead prepared
    tensors in device memory until LRU eviction. With ``tie_lm_head``
    (default),
    a model with tied embeddings (no ``lm_head`` leaf) gets a prepared
    ``lm_head`` entry built from ``embed.T`` when the ``"lm_head"`` layer
    resolves non-exact — the vocab projection is the single hottest decode
    GEMM, and the raw tied path would otherwise re-quantize the embedding
    table every step.
    """
    from repro.kernels import ops
    layer_fn = layer_fn or default_layer_name
    is_prep = lambda x: isinstance(x, ops.PreparedOperand)        # noqa: E731
    flat, treedef = jax.tree_util.tree_flatten_with_path(params, is_leaf=is_prep)
    leaves = []
    for path, leaf in flat:
        name = None if is_prep(leaf) else layer_fn(path)
        if (name is None or not hasattr(leaf, "ndim") or leaf.ndim < 2
                or not _is_float(leaf) or policy.resolve(name) == "exact"):
            leaves.append(leaf)
            continue
        leaves.append(_bind_leaf(leaf, policy, name, cached))
    out = jax.tree_util.tree_unflatten(treedef, leaves)
    if isinstance(out, dict):
        out = BoundParams(out)
        if (tie_lm_head and "embed" in out and "lm_head" not in out
                and policy.resolve("lm_head") != "exact"
                and _is_float(out["embed"])):
            prep_fn = prepare_weights_cached if cached else prepare_weights
            out["lm_head"] = prep_fn(
                jnp.asarray(out["embed"]).T, policy, layer="lm_head")
    return out


