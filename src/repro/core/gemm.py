"""GEMM backend registry — the paper's technique as a first-class framework feature.

Every matmul in every model goes through `sa_dot(x, w, policy, layer=...)`. The
policy selects, per layer, which arithmetic executes it:

* ``exact``         — float dot (bf16/f32); the production path for training and
                      the large-model dry-runs (the MXU *is* the exact PE array).
* ``mxu_int8``      — symmetric int8 quantize -> exact int8 systolic GEMM (Pallas
                      kernel on TPU, jnp fallback elsewhere) -> dequantize.
* ``approx_lut``    — int8 quantize -> approximate GEMM via the PE product table at
                      factor k (Pallas gather kernel / jnp fallback) -> dequantize.
* ``approx_oracle`` — int8 quantize -> full fused bit-level PE-chain oracle.
* ``approx_onehot`` — one-hot rewrite running the approximate GEMM on the exact MXU.
* ``approx_delta``  — exact int8 MXU matmul + rank-r error-correction matmul
                      (core/error_delta.py): bit-identical to ``approx_lut`` at the
                      default (exact) rank, but MXU-resident — the fast path for
                      activations that change every call.

The per-layer policy generalizes the paper's hybrid BDCN (approximate early blocks,
exact later blocks) to arbitrary networks.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from . import emulate, lut, quant

BACKENDS = ("exact", "mxu_int8", "approx_lut", "approx_oracle", "approx_onehot",
            "approx_delta")


@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """Which backend executes each layer's matmuls.

    `backend` is the default; `overrides` maps layer-name prefixes to backends
    (longest prefix wins), mirroring the paper's hybrid early-approx/late-exact BDCN.
    `k` is the approximation factor for approximate backends. `delta_rank` /
    `delta_tol` tune the ``approx_delta`` correction rank (None = exact rank,
    bit-identical to ``approx_lut``; a tolerance trades correction FLOPs for a
    bounded per-product error on top of the paper's approximation).
    """
    backend: str = "exact"
    k: int = 4
    n_bits: int = 8
    acc_bits: int = 24
    overrides: Optional[Dict[str, str]] = None
    delta_rank: Optional[int] = None
    delta_tol: Optional[float] = None

    def resolve(self, layer: str = "") -> str:
        if self.overrides:
            best = ""
            choice = self.backend
            for prefix, be in self.overrides.items():
                if layer.startswith(prefix) and len(prefix) > len(best):
                    best, choice = prefix, be
            return choice
        return self.backend


EXACT = GemmPolicy(backend="exact")


def as_policy(policy=None, *, backend: str = "approx_lut",
              k: Optional[int] = None) -> GemmPolicy:
    """Coerce ``None`` / a backend name / a GemmPolicy into a GemmPolicy.

    Application entry points accept all three; ``k`` (when given) overrides
    the policy's approximation factor, so apps can sweep k under one policy.
    """
    if policy is None:
        policy = GemmPolicy(backend=backend)
    elif isinstance(policy, str):
        if policy not in BACKENDS:
            raise ValueError(f"unknown backend {policy!r}; one of {BACKENDS}")
        policy = GemmPolicy(backend=policy)
    elif not isinstance(policy, GemmPolicy):
        raise TypeError(f"policy must be None, a backend name or a GemmPolicy,"
                        f" got {type(policy).__name__}")
    if k is not None and policy.k != k:
        policy = dataclasses.replace(policy, k=k)
    return policy


def _int_gemm(x_q, w_q, backend: str, policy: GemmPolicy):
    if backend == "mxu_int8":
        from repro.kernels import ops
        return ops.systolic_matmul(x_q, w_q)
    if backend == "approx_lut":
        from repro.kernels import ops
        return ops.approx_matmul(x_q, w_q, k=policy.k, n_bits=policy.n_bits,
                                 acc_bits=policy.acc_bits)
    if backend == "approx_oracle":
        return emulate.matmul_oracle(x_q, w_q, n_bits=policy.n_bits, k=policy.k,
                                     acc_bits=policy.acc_bits)
    if backend == "approx_onehot":
        t_b = lut.build_onehot_weights(w_q, n_bits=policy.n_bits, k=policy.k,
                                       acc_bits=policy.acc_bits)
        return lut.onehot_matmul(x_q, t_b, n_bits=policy.n_bits)
    if backend == "approx_delta":
        from repro.kernels import ops
        return ops.approx_delta_matmul(x_q, w_q, k=policy.k,
                                       n_bits=policy.n_bits,
                                       acc_bits=policy.acc_bits,
                                       rank=policy.delta_rank,
                                       tol=policy.delta_tol)
    raise ValueError(f"unknown integer backend {backend!r}")


def sa_dot(x: jnp.ndarray, w: jnp.ndarray, policy: GemmPolicy = EXACT, *,
           layer: str = "") -> jnp.ndarray:
    """Systolic-array dot: (..., K) x (K, N) -> (..., N) under the layer's backend."""
    backend = policy.resolve(layer)
    if backend == "exact":
        return jnp.matmul(x, w)
    lead = x.shape[:-1]
    k_dim = x.shape[-1]
    x2 = x.reshape(-1, k_dim)
    xq = quant.quantize(x2, n_bits=policy.n_bits)
    wq = quant.quantize(w, n_bits=policy.n_bits, axis=0)   # per-output-channel
    acc = _int_gemm(xq.values, wq.values, backend, policy)
    out = acc.astype(jnp.float32) * xq.scale * wq.scale
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)


def int_matmul(x_q, w_q, policy: GemmPolicy, *, layer: str = ""):
    """Integer-in/integer-out GEMM under the policy (no (de)quantization)."""
    backend = policy.resolve(layer)
    if backend == "exact":
        return jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    return _int_gemm(x_q, w_q, backend, policy)


def prepare_weights(w, policy: GemmPolicy, *, layer: str = "",
                    side: str = "right"):
    """Precompute the backend-specific factor for a fixed weight matrix.

    Returns a ``kernels.ops.PreparedOperand`` that ``execute`` accepts in
    place of the raw matrix. For ``approx_delta`` this builds the rank-r
    ``G_B`` (or ``F_A`` for ``side="left"``, e.g. the DCT matrix multiplying
    from the left) once; for ``approx_onehot`` the ``T_B`` table. Prepare
    once per (weights, policy, layer) and reuse across every DCT block /
    im2col row batch.
    """
    from repro.kernels import ops
    backend = policy.resolve(layer)
    return ops.prepare_operand(w, backend=backend, k=policy.k,
                               n_bits=policy.n_bits, acc_bits=policy.acc_bits,
                               side=side, rank=policy.delta_rank,
                               tol=policy.delta_tol)


_PREPARED_CACHE: Dict = {}
_PREPARED_CACHE_MAX = 256


def prepare_weights_cached(w, policy: GemmPolicy, *, layer: str = "",
                           side: str = "right"):
    """``prepare_weights`` memoized by weight *value* and policy parameters.

    The apps call this on genuinely fixed matrices (the DCT matrix, conv
    kernels, seeded layer weights) so repeated forwards — every k of a sweep,
    every benchmark reps — reuse the stationary precompute instead of
    re-uploading it. Keys include the raw bytes, so distinct weights can
    never alias; the cache is bounded and simply resets when full.
    """
    w_np = np.ascontiguousarray(np.asarray(w))
    key = (w_np.shape, w_np.dtype.str, w_np.tobytes(), policy.resolve(layer),
           policy.k, policy.n_bits, policy.acc_bits, policy.delta_rank,
           policy.delta_tol, side)
    hit = _PREPARED_CACHE.get(key)
    if hit is None:
        if len(_PREPARED_CACHE) >= _PREPARED_CACHE_MAX:
            _PREPARED_CACHE.clear()
        hit = _PREPARED_CACHE[key] = prepare_weights(w_np, policy, layer=layer,
                                                     side=side)
    return hit


def _check_prepared(prep, backend: str, policy: GemmPolicy, layer: str) -> None:
    mismatches = []
    if prep.backend != backend:
        mismatches.append(f"backend {prep.backend!r} != {backend!r}")
    if prep.k != policy.k:
        mismatches.append(f"k {prep.k} != {policy.k}")
    if (prep.n_bits, prep.acc_bits) != (policy.n_bits, policy.acc_bits):
        mismatches.append("n_bits/acc_bits differ")
    if backend == "approx_delta" and (prep.rank, prep.tol) != (
            policy.delta_rank, policy.delta_tol):
        mismatches.append("delta_rank/delta_tol differ")
    if mismatches:
        raise ValueError(
            f"prepared operand is stale for layer {layer!r}: "
            + "; ".join(mismatches)
            + " — re-run prepare_weights under the current policy")


def execute(policy: GemmPolicy, a, b, *, layer: str = "") -> jnp.ndarray:
    """Single integer-GEMM entry point for the application workloads.

    ``a`` and ``b`` are integer operands; either one (not both) may instead be
    a ``PreparedOperand`` from ``prepare_weights`` — its position must match
    the side it was prepared for. Either raw operand may carry leading batch
    dimensions (``(..., M, K) x (K, N)`` or ``(M, K) x (..., K, N)``); the
    pad-and-batch shim (``kernels.ops.batched_app_matmul``) flattens them onto
    the 2D kernels. Returns the int32 product under the layer's backend.
    """
    from repro.kernels import ops
    backend = policy.resolve(layer)
    a_prep = isinstance(a, ops.PreparedOperand)
    b_prep = isinstance(b, ops.PreparedOperand)
    if a_prep and b_prep:
        raise ValueError("at most one operand may be prepared")
    if a_prep or b_prep:
        prep = a if a_prep else b
        want_side = "left" if a_prep else "right"
        if prep.side != want_side:
            raise ValueError(
                f"operand prepared for side {prep.side!r} passed as "
                f"the {want_side} operand")
        _check_prepared(prep, backend, policy, layer)
        x = jnp.asarray(b if a_prep else a, jnp.int32)
        if a_prep:
            mm = lambda _, bb: ops.prepared_matmul(bb, prep)  # noqa: E731
            return ops.batched_app_matmul(mm, prep.values, x)
        mm = lambda aa, _: ops.prepared_matmul(aa, prep)      # noqa: E731
        return ops.batched_app_matmul(mm, x, prep.values)
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    if backend == "exact":
        return ops.batched_app_matmul(jnp.matmul, a, b)
    mm = lambda aa, bb: _int_gemm(aa, bb, backend, policy)    # noqa: E731
    return ops.batched_app_matmul(mm, a, b)
