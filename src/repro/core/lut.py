"""LUT-based fast functional model of the approximate GEMM.

The fused bit-level oracle (`emulate.matmul_oracle`) is exact-to-the-netlist but
slow. For application-scale workloads we factor the approximation:

    approx(a*b + c)  ≈  approx_product(a, b) + c        ("multiplier-approx model")

where approx_product is the 2^N x 2^N table of PE outputs at c = 0. This keeps the
approximate-multiplier error exactly and drops only the (small) error component the
fused accumulator contributes; tests quantify the residual against the oracle.

Two execution strategies:

* `lut_matmul`      — direct gather: out[m,n] = sum_k T[a[m,k], b[k,n]] (VPU path;
                      also the reference for the Pallas approx kernel).
* `onehot_matmul`   — beyond-paper TPU trick: one-hot-encode A against the table so
                      the *approximate* GEMM runs on the *exact* MXU:
                        out = onehot(A) @ T_B, with T_B[k*V + v, n] = T[v, b[k,n]].
                      256x FLOP inflation, but MXU FLOPs are ~100x cheaper than VPU
                      gathers — and for fixed weights T_B is precomputed once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .emulate import product_table


def _lut_for(n_bits: int, k: int, signed: bool, acc_bits: int) -> jnp.ndarray:
    return jnp.asarray(product_table(n_bits, k, signed, acc_bits))


def lut_matmul(a, b, *, n_bits: int = 8, k: int = 4, signed: bool = True,
               acc_bits: int = 24):
    """(M,K) x (K,N) approximate GEMM via product-table gathers, int32 accumulate."""
    table = _lut_for(n_bits, k, signed, acc_bits)
    span = 1 << n_bits
    mask = span - 1
    a_u = jnp.asarray(a, jnp.int32) & mask          # (M, K) unsigned patterns
    b_u = jnp.asarray(b, jnp.int32) & mask          # (K, N)
    flat = table.reshape(-1)                        # (span*span,)

    def one_k(carry, inputs):
        a_col, b_row = inputs                       # (M,), (N,)
        idx = a_col[:, None] * span + b_row[None, :]
        carry = carry + jnp.take(flat, idx, axis=0)
        return carry, None

    init = jnp.zeros((a_u.shape[0], b_u.shape[1]), jnp.int32)
    out, _ = jax.lax.scan(one_k, init, (a_u.T, b_u))
    return out


def build_onehot_weights(b, *, n_bits: int = 8, k: int = 4, signed: bool = True,
                         acc_bits: int = 24) -> jnp.ndarray:
    """Precompute T_B (K*V, N) for `onehot_matmul` from weight matrix b (K, N)."""
    table = np.asarray(product_table(n_bits, k, signed, acc_bits))  # (V, V)
    span = 1 << n_bits
    b_u = np.asarray(b, np.int32) & (span - 1)      # (K, N)
    t_b = table[:, b_u]                             # (V, K, N)
    t_b = np.transpose(t_b, (1, 0, 2))              # (K, V, N)
    kk, _, nn = t_b.shape
    return jnp.asarray(t_b.reshape(kk * span, nn), jnp.float32)


def onehot_matmul(a, t_b, *, n_bits: int = 8):
    """Approximate GEMM on the MXU: onehot(A) (M, K*V) @ T_B (K*V, N)."""
    span = 1 << n_bits
    a_u = jnp.asarray(a, jnp.int32) & (span - 1)    # (M, K)
    m, kk = a_u.shape
    onehot = jax.nn.one_hot(a_u, span, dtype=jnp.float32)   # (M, K, V)
    out = onehot.reshape(m, kk * span) @ t_b                # exact MXU matmul
    return out.astype(jnp.int32)
