"""LUT-based fast functional model of the approximate GEMM.

The fused bit-level oracle (`emulate.matmul_oracle`) is exact-to-the-netlist but
slow. For application-scale workloads we factor the approximation:

    approx(a*b + c)  ≈  approx_product(a, b) + c        ("multiplier-approx model")

where approx_product is the 2^N x 2^N table of PE outputs at c = 0. This keeps the
approximate-multiplier error exactly and drops only the (small) error component the
fused accumulator contributes; tests quantify the residual against the oracle.

Two execution strategies:

* `lut_matmul`      — direct gather: out[m,n] = sum_k T[a[m,k], b[k,n]] (VPU path;
                      also the reference for the Pallas approx kernel).
* `onehot_matmul`   — beyond-paper TPU trick: one-hot-encode A against the table so
                      the *approximate* GEMM runs on the *exact* MXU:
                        out = onehot(A) @ T_B, with T_B[k*V + v, n] = T[v, b[k,n]].
                      256x FLOP inflation, but MXU FLOPs are ~100x cheaper than VPU
                      gathers — and for fixed weights T_B is precomputed once.

HBM footprint of `onehot_matmul`: the one-hot operand is (M, K*V) — with V=256
and bf16 encoding that is 512*K bytes per output row (it was 1024*K as float32),
plus the (K*V, N) float32 T_B. The 0/1 one-hot is exact in bf16 and the f32
accumulation is unchanged, so bf16 halves the dominant HBM term with no loss.
For activations that change every call, `kernels/ops.approx_delta_matmul`
(core/error_delta.py) reaches the MXU with only rank-r (r ~ 7 at k=4) inflation
instead of 256x and is the preferred fast path; `onehot_matmul` remains useful
when B is fixed and T_B amortizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .emulate import product_table_jnp


def table_gather_matmul(a_u: jnp.ndarray, b_u: jnp.ndarray,
                        flat_table: jnp.ndarray, *, span: int) -> jnp.ndarray:
    """Gather-GEMM over any (span*span,) table: out[m,n] = sum_kk T[a[m,kk], b[kk,n]].

    The one gather loop shared by the LUT model, the error-delta defect
    cancellation, and the kernel references; accumulates in the table's dtype.
    """
    def one_k(carry, inputs):
        a_col, b_row = inputs                       # (M,), (N,)
        idx = a_col[:, None] * span + b_row[None, :]
        carry = carry + jnp.take(flat_table, idx, axis=0)
        return carry, None

    init = jnp.zeros((a_u.shape[0], b_u.shape[1]), flat_table.dtype)
    out, _ = jax.lax.scan(one_k, init, (a_u.T, b_u))
    return out


def lut_matmul(a, b, *, n_bits: int = 8, k: int = 4, signed: bool = True,
               acc_bits: int = 24):
    """(M,K) x (K,N) approximate GEMM via product-table gathers, int32 accumulate."""
    span = 1 << n_bits
    mask = span - 1
    a_u = jnp.asarray(a, jnp.int32) & mask          # (M, K) unsigned patterns
    b_u = jnp.asarray(b, jnp.int32) & mask          # (K, N)
    flat = product_table_jnp(n_bits, k, signed, acc_bits, flat=True)
    return table_gather_matmul(a_u, b_u, flat, span=span)


def build_onehot_weights(b, *, n_bits: int = 8, k: int = 4, signed: bool = True,
                         acc_bits: int = 24) -> jnp.ndarray:
    """Precompute T_B (K*V, N) for `onehot_matmul` from weight matrix b (K, N).

    Pure-jnp gather into the cached device table, so it is traceable: the
    unbound ``approx_onehot`` model path rebuilds T_B under jit/scan (the cost
    ``core.gemm.bind`` amortizes away), while prepared operands store it once.
    """
    table = product_table_jnp(n_bits, k, signed, acc_bits)  # (V, V) device
    span = 1 << n_bits
    b_u = jnp.asarray(b, jnp.int32) & (span - 1)    # (K, N)
    t_b = jnp.take(table, b_u, axis=1)              # (V, K, N)
    t_b = jnp.transpose(t_b, (1, 0, 2))             # (K, V, N)
    kk, _, nn = t_b.shape
    return t_b.reshape(kk * span, nn).astype(jnp.float32)


def onehot_matmul(a, t_b, *, n_bits: int = 8):
    """Approximate GEMM on the MXU: onehot(A) (M, K*V) @ T_B (K*V, N).

    The one-hot operand is bf16 (0/1 is exact in bf16, halving its HBM/VMEM
    footprint vs float32); accumulation stays float32 so table-value sums up to
    2^24 remain exact, as before.
    """
    span = 1 << n_bits
    a_u = jnp.asarray(a, jnp.int32) & (span - 1)    # (M, K)
    m, kk = a_u.shape
    onehot = jax.nn.one_hot(a_u, span, dtype=jnp.bfloat16)  # (M, K, V)
    out = jax.lax.dot_general(                              # exact MXU matmul
        onehot.reshape(m, kk * span), t_b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.astype(jnp.int32)
