"""Cell-level logic for the paper's Processing Element.

Implements the four cells of Table I:

* exact PPC   — full adder over (a·b,      S_in, C_in)
* exact NPPC  — full adder over (NOT(a·b), S_in, C_in)   (Baugh-Wooley sign rows)
* approx PPC  — C = a·b               ; S = (S_in|C_in) & ~(a·b)
* approx NPPC — C = (S_in|C_in)&~(a·b); S = ~((S_in|C_in) & ~(a·b))

NOTE (DESIGN.md §1): the prose Boolean equations in the paper are inconsistent with
Table I; the truth table (whose 5/16 error rows and ED column are self-consistent) is
taken as ground truth. These functions operate bitwise on integer arrays (0/1 valued,
or full integer words when used as bit-sliced lanes), so they vectorize over any batch
shape and over 32 bit-planes at once when fed packed words.
"""
from __future__ import annotations

import itertools
from typing import Callable, NamedTuple, Tuple

import jax.numpy as jnp

Bits = jnp.ndarray  # integer array, each bit lane is an independent cell instance


class CellOut(NamedTuple):
    s: Bits
    c: Bits


def _and(x: Bits, y: Bits) -> Bits:
    return x & y


def exact_ppc(p: Bits, s_in: Bits, c_in: Bits) -> CellOut:
    """Full adder of (p, s_in, c_in) where p = a&b is the partial-product bit."""
    xor_ps = p ^ s_in
    s = xor_ps ^ c_in
    c = (p & s_in) | (c_in & xor_ps)
    return CellOut(s, c)


def exact_nppc(p: Bits, s_in: Bits, c_in: Bits, *, ones: Bits | int = 1) -> CellOut:
    """Full adder of (~p, s_in, c_in). `ones` supplies the all-ones word for bit-slicing."""
    return exact_ppc(p ^ ones, s_in, c_in)


def approx_ppc(p: Bits, s_in: Bits, c_in: Bits) -> CellOut:
    """Approximate PPC from Table I: C = p, S = (S_in|C_in) & ~p."""
    s = (s_in | c_in) & ~p
    c = p
    return CellOut(s, c)


def approx_nppc(p: Bits, s_in: Bits, c_in: Bits, *, ones: Bits | int = 1) -> CellOut:
    """Approximate NPPC from Table I: C = (S_in|C_in)&~p, S = ~C (within the bit lane).

    For multi-bit-lane (packed-word) use, complement is taken against `ones`.
    """
    c = (s_in | c_in) & ~p
    s = c ^ ones
    return CellOut(s, c)


# ---------------------------------------------------------------------------
# Truth-table utilities (pure python ints; used by tests and benchmarks)
# ---------------------------------------------------------------------------

def _as_int(x) -> int:
    return int(x) & 1


def truth_table(cell: Callable[..., CellOut], *, nppc: bool = False):
    """Return rows (a, b, c_in, s_in, C, S, value) over all 16 input combos.

    The cell's partial-product input is a&b for PPC cells and the *complement is applied
    inside* exact_nppc/approx_nppc, so we always pass p = a&b here.
    """
    rows = []
    for a, b, c_in, s_in in itertools.product((0, 1), repeat=4):
        p = a & b
        out = cell(jnp.uint32(p), jnp.uint32(s_in), jnp.uint32(c_in))
        s, c = _as_int(out.s), _as_int(out.c)
        rows.append((a, b, c_in, s_in, c, s, 2 * c + s))
    return rows


def exact_value(a: int, b: int, c_in: int, s_in: int, *, nppc: bool) -> int:
    p = (a & b) ^ 1 if nppc else (a & b)
    return p + c_in + s_in


def error_cases(approx_cell: Callable[..., CellOut], *, nppc: bool):
    """(inputs, ED) for every row where the approximate cell deviates from exact."""
    cases = []
    for a, b, c_in, s_in in itertools.product((0, 1), repeat=4):
        p = a & b
        out = approx_cell(jnp.uint32(p), jnp.uint32(s_in), jnp.uint32(c_in))
        got = 2 * _as_int(out.c) + _as_int(out.s)
        want = exact_value(a, b, c_in, s_in, nppc=nppc)
        if got != want:
            cases.append(((a, b, s_in, c_in), got - want))
    return cases


def cell_error_probability(approx_cell: Callable[..., CellOut], *, nppc: bool) -> Tuple[int, int]:
    """(numerator, denominator) of the total error probability, assuming
    P(a=1)=P(b=1)=1/2 hence P(p=1)=1/4, and S_in/C_in uniform as in the paper.

    The paper derives 25/256 for the proposed PPC (and states it jointly for PPC+NPPC).
    """
    num = 0
    for (a, b, s_in, c_in), _ in error_cases(approx_cell, nppc=nppc):
        # The paper's per-case P_E values (9,3,3,9,1)/256 correspond to modeling every
        # input a, b, S_in, C_in as Bernoulli(1/4): weight 1 if the input is 1 else 3,
        # over denominator 4^4 = 256.
        w = 1
        for bit in (a, b, s_in, c_in):
            w *= 1 if bit else 3
        num += w
    return num, 256
