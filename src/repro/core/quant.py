"""Symmetric int8 quantization for routing real-valued matmuls through the PE.

The paper's PE consumes N-bit integers; DNN activations/weights are real-valued, so
the framework quantizes symmetrically (per-tensor for activations, per-channel for
weights), runs the integer GEMM (exact MXU / approx LUT / bit-level oracle), and
dequantizes. A straight-through estimator makes the whole path differentiable so
the same machinery supports quantization-aware training.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    values: jnp.ndarray   # int8 payload (held as int32 for emulation friendliness)
    scale: jnp.ndarray    # per-tensor scalar or per-channel vector


def quantize(x: jnp.ndarray, *, n_bits: int = 8, axis: Optional[int] = None,
             eps: float = 1e-8) -> Quantized:
    """Symmetric quantization to [-2^{N-1}+1, 2^{N-1}-1]."""
    qmax = (1 << (n_bits - 1)) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return Quantized(q, scale)


def dequantize(q: Quantized) -> jnp.ndarray:
    return q.values.astype(jnp.float32) * q.scale


@jax.custom_vjp
def ste_round(x):
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(x: jnp.ndarray, *, n_bits: int = 8, axis: Optional[int] = None,
               eps: float = 1e-8) -> jnp.ndarray:
    """Differentiable quantize->dequantize (QAT). Gradients pass straight through."""
    qmax = (1 << (n_bits - 1)) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(ste_round(x / scale), -qmax, qmax)
    return q * scale
