"""Symmetric int8 quantization for routing real-valued matmuls through the PE.

The paper's PE consumes N-bit integers; DNN activations/weights are real-valued, so
the framework quantizes symmetrically (per-tensor for activations, per-channel for
weights), runs the integer GEMM (exact MXU / approx LUT / bit-level oracle), and
dequantizes. A straight-through estimator makes the whole path differentiable so
the same machinery supports quantization-aware training.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Quantized(NamedTuple):
    values: jnp.ndarray   # int8 payload (held as int32 for emulation friendliness)
    scale: jnp.ndarray    # per-tensor scalar or per-channel vector


def quantize(x: jnp.ndarray, *, n_bits: int = 8, axis: Optional[int] = None,
             eps: float = 1e-8) -> Quantized:
    """Symmetric quantization to [-2^{N-1}+1, 2^{N-1}-1].

    The scale (and the division) are computed in float32 regardless of the
    input dtype. Besides precision, this pins bit-parity between inline and
    stored scales: a bf16 scale would exist as an f32->bf16->bf16->f32
    convert chain when consumed inline, which XLA's excess-precision folding
    collapses to the *unrounded* f32 value — so a weight quantized at bind
    time (scale stored, rounded) and the same weight quantized in-line would
    dequantize differently. An f32 scale has no narrowing convert to fold.

    For the same reason, narrow-float inputs are re-rounded to their own
    precision via ``lax.reduce_precision`` (which XLA never folds): a bf16
    activation produced by an upstream f32 computation may reach this point
    as a foldable convert pair, and whether the fold fires depends on the
    surrounding graph — quantizing the pinned value makes the emitted bits a
    function of the *values*, not of the compilation context.
    """
    qmax = (1 << (n_bits - 1)) - 1
    xf = x.astype(jnp.float32)
    if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype != jnp.float32:
        fi = jnp.finfo(x.dtype)
        xf = jax.lax.reduce_precision(xf, fi.nexp, fi.nmant)
    if axis is None:
        amax = jnp.max(jnp.abs(xf))
    else:
        amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    # multiply by the host-computed reciprocal instead of dividing by qmax:
    # XLA rewrites division by a constant into reciprocal-multiply inside jit
    # but not in eager mode — a one-ulp scale difference that flips boundary
    # values, breaking eager(bind)-vs-jit(inline) quantization parity
    scale = jnp.maximum(amax, eps) * np.float32(1.0 / qmax)
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(jnp.int32)
    return Quantized(q, scale)


def dequantize(q: Quantized) -> jnp.ndarray:
    return q.values.astype(jnp.float32) * q.scale


@jax.custom_vjp
def ste_round(x):
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(x: jnp.ndarray, *, n_bits: int = 8, axis: Optional[int] = None,
               eps: float = 1e-8) -> jnp.ndarray:
    """Differentiable quantize->dequantize (QAT). Gradients pass straight through."""
    qmax = (1 << (n_bits - 1)) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, eps) / qmax
    q = jnp.clip(ste_round(x / scale), -qmax, qmax)
    return q * scale
