"""Cycle-accurate wavefront model of the output-stationary systolic array.

Reproduces the classical dataflow of Fig. 1 (Kung [7]) and the latency formula
3N-2 [11]: A streams from the left (row i delayed i cycles), B from the top
(column j delayed j cycles), PE (i,j) MACs one product per cycle once both
operands arrive, outputs drain after the last wavefront.

This model is used (a) to validate the latency claim, (b) to drive the energy
model's cycle counts, and (c) as an executable specification of the dataflow the
production kernel (the MXU) implements in hardware. It supports plugging in the
approximate PE to show dataflow-order-faithful accumulation.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .emulate import pe_mac


def latency_cycles(n: int, k: Optional[int] = None) -> int:
    """Cycles until the last output is ready for an NxN SA multiplying NxK by KxN.

    For the square case K=N this is the classical 3N-2 [11]; streaming K>N inputs
    extends it by K-N.
    """
    k = n if k is None else k
    return 3 * n - 2 + max(0, k - n)


def simulate(a: np.ndarray, b: np.ndarray, *, mac: Optional[Callable] = None,
             trace: bool = False):
    """Cycle-by-cycle simulation of an output-stationary SA computing a @ b.

    a: (N, K), b: (K, N) with the array sized N x N. `mac(a_val, b_val, acc)`
    defaults to exact integer MAC; pass a closure over `pe_mac` for approximate.
    Returns (result, cycles) or (result, cycles, activity) if trace.
    """
    a = np.asarray(a)
    b = np.asarray(b)
    n, kk = a.shape
    kb, n2 = b.shape
    assert kk == kb and n == n2, "square output-stationary array"
    if mac is None:
        mac = lambda x, y, acc: acc + int(x) * int(y)

    acc = np.zeros((n, n), dtype=np.int64)
    # skewed operand schedules: a[i, t - i] enters row i at cycle t (t >= i)
    total = latency_cycles(n, kk)
    activity = np.zeros(total, dtype=np.int64)
    for t in range(total):
        for i in range(n):
            for j in range(n):
                ka = t - i - j  # the K-index whose product PE(i,j) computes at cycle t
                if 0 <= ka < kk:
                    acc[i, j] = mac(a[i, ka], b[ka, j], acc[i, j])
                    activity[t] += 1
    if trace:
        return acc, total, activity
    return acc, total


def simulate_approx(a: np.ndarray, b: np.ndarray, *, n_bits: int = 8, k: int = 0,
                    signed: bool = True, acc_bits: int = 24):
    """SA simulation with the paper's approximate PE plugged into every cell."""
    def mac(x, y, acc):
        return int(pe_mac(np.int32(x), np.int32(y), np.int32(acc), n_bits=n_bits,
                          k=k, signed=signed, acc_bits=acc_bits))
    return simulate(a, b, mac=mac)
