from .pipeline import DataConfig, FileTokens, SyntheticLM  # noqa: F401
