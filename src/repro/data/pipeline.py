"""Deterministic, shardable data pipeline.

Sources:
* `SyntheticLM` — seeded zipfian token stream (CPU tests, dry-runs, perf work).
* `FileTokens`  — memory-mapped token file (real corpora), sharded by host.

Both are *stateless-resumable*: batch `i` is a pure function of (seed, i,
host_shard), so checkpoint/restart and elastic rescaling (different host counts)
replay identically — the checkpoint only stores the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    n_micro: int = 1


class SyntheticLM:
    """Zipf-distributed tokens with injected n-gram structure so losses move."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, dc: DataConfig):
        self.cfg, self.shape, self.dc = cfg, shape, dc
        assert shape.global_batch % dc.n_hosts == 0
        self.host_batch = shape.global_batch // dc.n_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.dc.seed, step, self.dc.host_id))
        b, s = self.host_batch, self.shape.seq_len
        v = self.cfg.vocab_size
        # zipf body + copy structure (second half echoes first half shifted)
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64) % max(v - 2, 1) + 1
        half = s // 2
        base[:, half:half * 2] = (base[:, :half] + 1) % max(v - 2, 1) + 1
        toks = base.astype(np.int32)
        out = {"tokens": toks}
        if self.cfg.family == "audio":
            out["input_embeds"] = rng.normal(
                size=(b, s, self.cfg.d_model)).astype(np.float32)
            out["loss_mask"] = (rng.random((b, s)) < 0.08).astype(np.float32)
            out["tokens"] = (toks % self.cfg.vocab_size).astype(np.int32)
        if self.cfg.family == "vlm":
            s_img = int(s * self.cfg.prefix_len_frac)
            out["input_embeds"] = rng.normal(
                size=(b, s_img, self.cfg.d_model)).astype(np.float32)
            out["tokens"] = toks[:, : s - s_img]
        if self.dc.n_micro > 1:
            nm = self.dc.n_micro
            out = {k: x.reshape(nm, x.shape[0] // nm, *x.shape[1:])
                   for k, x in out.items()}
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class FileTokens:
    """Flat binary int32 token file, deterministic strided host sharding."""

    def __init__(self, path: str, cfg: ModelConfig, shape: ShapeSpec,
                 dc: DataConfig):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg, self.shape, self.dc = cfg, shape, dc
        self.host_batch = shape.global_batch // dc.n_hosts
        self.per_step = shape.global_batch * (shape.seq_len + 1)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        s = self.shape.seq_len
        n = len(self.tokens) // (s + 1)
        rng = np.random.default_rng((self.dc.seed, step))
        order = rng.permutation(n)[: self.shape.global_batch]
        mine = order[self.dc.host_id:: self.dc.n_hosts][: self.host_batch]
        rows = np.stack([self.tokens[i * (s + 1): (i + 1) * (s + 1)][:s]
                         for i in mine])
        out = {"tokens": rows % self.cfg.vocab_size}
        if self.dc.n_micro > 1:
            nm = self.dc.n_micro
            out = {k: x.reshape(nm, x.shape[0] // nm, *x.shape[1:])
                   for k, x in out.items()}
        return out
