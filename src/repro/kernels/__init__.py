"""Pallas TPU kernels for the paper's compute hot-spot (the systolic GEMM).

systolic_gemm.py — exact int8 PE array mapped onto the MXU.
approx_gemm.py   — approximate PE via VMEM-resident product table (VPU gathers).
delta_gemm.py    — approximate PE as exact matmul + rank-r error correction
                   (MXU-resident; see core/error_delta.py, docs/backends.md).
ops.py           — public wrappers (padding, interpret fallback on CPU).
ref.py           — pure-jnp oracles.
"""
from . import ops, ref  # noqa: F401
