"""Approximate systolic GEMM as a Pallas TPU kernel (product-table model).

TPU adaptation of the paper's approximate PE (DESIGN.md §2): gate-level column
approximation has no TPU analogue, so the kernel realizes the *functional* model —
the 2^N x 2^N approximate-product table (exactly the PE's c=0 transfer function)
gathered per (a, b) pair, with exact int32 accumulation.

VMEM budget: the full int32 table is 2^16 * 4 B = 256 KiB, held resident across the
whole kernel (one copy per core, re-used by every block — HBM traffic for the table
is amortized to zero by the grid). A/B blocks stream as in the exact kernel. The
inner loop walks the K-block one row at a time, forming a (bm, bn) index matrix and
gathering — a VPU-bound schedule, which is why `ops.py` also exposes the one-hot
MXU rewrite for throughput-critical use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import emulate

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 128


def tpu_contract(m: int, n: int, k: int, *, span: int = 256,
                 bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                 bk: int = DEFAULT_BK):
    """Static lowering contract mirroring `approx_matmul_lut`'s pallas_call.

    Shape/dtype geometry only (no tracing, no jax) — evaluated by
    `repro.analysis.kernel_audit`. Operands ride as int32 bit patterns (the
    wrapper masks to span) and the (span*span,) table is VMEM-resident.
    """
    from repro.analysis import contracts as C
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (-(-m // bm), -(-n // bn), -(-k // bk))
    return C.KernelGeometry(
        kernel="kernels.approx_gemm.approx_matmul_lut",
        grid=grid,
        operands=(
            C.OperandSpec("a", (m, k), "int32", (bm, bk),
                          lambda i, j, kk: (i, kk)),
            C.OperandSpec("b", (k, n), "int32", (bk, bn),
                          lambda i, j, kk: (kk, j)),
            C.OperandSpec("table", (span * span,), "int32", (span * span,),
                          lambda i, j, kk: (0,)),
            C.OperandSpec("o", (m, n), "int32", (bm, bn),
                          lambda i, j, kk: (i, j)),
        ),
        tag=f"m{m}n{n}k{k}s{span}bm{bm}bn{bn}bk{bk}",
    )


def _kernel(a_ref, b_ref, lut_ref, o_ref, *, span: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_blk = a_ref[...]          # (bm, bk) unsigned bit patterns, int32
    b_blk = b_ref[...]          # (bk, bn)
    table = lut_ref[...]        # (span*span,)
    bk = a_blk.shape[1]

    def body(kk, acc):
        idx = a_blk[:, kk][:, None] * span + b_blk[kk, :][None, :]
        return acc + jnp.take(table, idx, axis=0)

    o_ref[...] += jax.lax.fori_loop(0, bk, body, jnp.zeros_like(o_ref))


@functools.partial(jax.jit, static_argnames=("span", "bm", "bn", "bk", "interpret"))
def approx_matmul_lut(a_u: jnp.ndarray, b_u: jnp.ndarray, table_flat: jnp.ndarray,
                      *, span: int = 256, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      bk: int = DEFAULT_BK, interpret: bool = False) -> jnp.ndarray:
    """(M, K) x (K, N) via table gathers. a_u/b_u hold unsigned bit patterns
    (x & (span-1)); table_flat is the flattened (span*span,) product table."""
    m, k = a_u.shape
    k2, n = b_u.shape
    assert k == k2, (a_u.shape, b_u.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k})x({k},{n}) not multiples of blocks ({bm},{bn},{bk})")
    grid = (m // bm, n // bn, k // bk)
    kern = functools.partial(_kernel, span=span)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((span * span,), lambda i, j, kk: (0,)),  # resident table
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_u.astype(jnp.int32), b_u.astype(jnp.int32), table_flat.astype(jnp.int32))


def make_table(k: int, *, n_bits: int = 8, signed: bool = True,
               acc_bits: int = 24) -> jnp.ndarray:
    """Flattened (2^N * 2^N,) approximate-product table for factor k.

    Device-resident and cached: repeated GEMM calls share one upload (see
    emulate.product_table_jnp).
    """
    return emulate.product_table_jnp(n_bits, k, signed, acc_bits, flat=True)
