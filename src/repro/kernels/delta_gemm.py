"""Fused exact-plus-error-delta GEMM as a Pallas TPU kernel.

MXU-resident form of the approximate systolic array (see core/error_delta.py):
each (bm, bn, bk) block computes

    o += dot_i8(a, b)                          # exact PE array == the MXU
       + round( sum_r f_r[a_u] @ g_r[b_u] )    # rank-r float32 correction

in one kernel — both contractions stream the same A/B blocks, so the
correction costs no extra HBM traffic, and the per-element f/g lookups are
O(bm*bk + bk*bn) gathers into VMEM-resident 256-entry vectors (vs the
O(bm*bn*bk) table gathers of approx_gemm.py).

Rounding happens per K-block: the true block correction is an integer (a sum
of integer E entries), and the float32 noise per block is ~1e-2, so each
rounded block is exact and the int32 accumulation across the K grid introduces
no drift — the kernel is bit-identical to the gather path at the exact rank
for arbitrary K.

VMEM budget: f and g are (span * rank) float32 each — 21 KiB at the k=6 rank
of 21 — held resident across the whole grid like approx_gemm's table, but
~12x smaller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 256


def tpu_contract(m: int, n: int, k: int, *, rank: int, span: int = 256,
                 bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                 bk: int = DEFAULT_BK):
    """Static lowering contract mirroring `delta_matmul_fused`'s pallas_call.

    Shape/dtype geometry only (no tracing, no jax) — evaluated by
    `repro.analysis.kernel_audit`; `autotune.gemm_block_plan` prunes block
    candidates through it so the TPU path never launches a geometry the
    auditor rejects.
    """
    from repro.analysis import contracts as C
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    tab = span * max(rank, 1)
    grid = (-(-m // bm), -(-n // bn), -(-k // bk))
    return C.KernelGeometry(
        kernel="kernels.delta_gemm.delta_matmul_fused",
        grid=grid,
        operands=(
            C.OperandSpec("a", (m, k), "int8", (bm, bk),
                          lambda i, j, kk: (i, kk)),
            C.OperandSpec("b", (k, n), "int8", (bk, bn),
                          lambda i, j, kk: (kk, j)),
            C.OperandSpec("f", (tab,), "float32", (tab,),
                          lambda i, j, kk: (0,)),
            C.OperandSpec("g", (tab,), "float32", (tab,),
                          lambda i, j, kk: (0,)),
            C.OperandSpec("o", (m, n), "int32", (bm, bn),
                          lambda i, j, kk: (i, j)),
        ),
        tag=f"m{m}n{n}k{k}r{rank}bm{bm}bn{bn}bk{bk}",
    )


def _kernel(a_ref, b_ref, f_ref, g_ref, o_ref, *, rank: int, span: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_blk = a_ref[...]          # (bm, bk) int8 signed values (sign-extended patterns)
    b_blk = b_ref[...]          # (bk, bn)
    # exact base: int8 x int8 -> int32 on the MXU
    acc = jax.lax.dot_general(a_blk, b_blk, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    if rank:
        mask = span - 1
        a_u = a_blk.astype(jnp.int32) & mask
        b_u = b_blk.astype(jnp.int32) & mask
        f = f_ref[...]          # (span*rank,) f[v*rank + r]
        g = g_ref[...]          # (rank*span,) g[r*span + v]
        corr = jnp.zeros(acc.shape, jnp.float32)
        for rr in range(rank):  # static unroll: rank MXU dots per block
            f_a = jnp.take(f, a_u * rank + rr, axis=0)      # (bm, bk)
            g_b = jnp.take(g, b_u + rr * span, axis=0)      # (bk, bn)
            corr += jax.lax.dot_general(f_a, g_b, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)
        acc += jnp.round(corr).astype(jnp.int32)
    o_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("rank", "span", "bm", "bn", "bk", "interpret"))
def delta_matmul_fused(a_s: jnp.ndarray, b_s: jnp.ndarray, f_flat: jnp.ndarray,
                       g_flat: jnp.ndarray, *, rank: int, span: int = 256,
                       bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                       bk: int = DEFAULT_BK,
                       interpret: bool = False) -> jnp.ndarray:
    """(M, K) x (K, N) -> (M, N) int32 via base matmul + rank-r correction.

    a_s/b_s hold *signed* operand values (int8-representable; ops.py converts
    bit patterns); f_flat/g_flat come from error_delta.factor_tables_jnp.
    Shapes must be block multiples (ops.approx_delta_matmul pads).
    """
    m, k = a_s.shape
    k2, n = b_s.shape
    assert k == k2, (a_s.shape, b_s.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k})x({k},{n}) not multiples of blocks ({bm},{bn},{bk})")
    tab = span * max(rank, 1)
    assert f_flat.shape == (tab,) and g_flat.shape == (tab,), (
        f_flat.shape, g_flat.shape, rank, span)
    grid = (m // bm, n // bn, k // bk)
    kern = functools.partial(_kernel, rank=rank, span=span)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tab,), lambda i, j, kk: (0,)),    # resident f
            pl.BlockSpec((tab,), lambda i, j, kk: (0,)),    # resident g
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_s.astype(jnp.int8), b_s.astype(jnp.int8),
      f_flat.astype(jnp.float32), g_flat.astype(jnp.float32))
