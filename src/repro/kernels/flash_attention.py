"""Flash attention as a Pallas TPU kernel (forward).

The model stack uses the pure-JAX chunked attention (layers.chunked_attention)
everywhere — this kernel is the TPU-native drop-in for the prefill hot spot:
grid (batch*heads, q_blocks), online softmax over K/V blocks streamed through
VMEM, causal + sliding-window masking computed from block indices so fully
masked K blocks are skipped via `pl.when`.

Ragged lengths: callers pad S_kv up to a multiple of the block size, and the
padded K rows are zeros — under causal self-attention they land at positions
the causal mask already hides, but non-causal (or cross-attention) padded rows
score ``s = 0`` and would contribute ``exp(0 - m)`` mass to every softmax.
``kv_valid_len`` (scalar or per-batch ``(B,)``) masks key positions ``>= len``
explicitly and clamps the K-block scan to the last live block, so the result
matches the unpadded jnp reference bit-for-bit. Rows whose mask admits no key
at all (``kv_valid_len == 0``) are out of contract, as is any
``kv_valid_len > S_kv``.

Block shapes default to MXU/VPU-aligned (128 q rows x 128 kv cols x head_dim).
Validated in interpret mode against layers.chunked_attention / a naive oracle
(tests/test_flash_kernel.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
BIG_NEG = -2.3819763e38


def tpu_contract(b: int, h: int, sq: int, skv: int, d: int, *,
                 dtype: str = "float32", bq: int = DEFAULT_BQ,
                 bk: int = DEFAULT_BK):
    """Static lowering contract mirroring `flash_attention`'s pallas_call.

    Shape/dtype geometry only (no tracing, no jax). Note the kernel holds a
    row's *entire* padded KV in VMEM per grid cell (the K scan is an
    in-kernel fori_loop, not a grid axis), so the auditable envelope is
    bounded by ``2 * 2 * skv * d * itemsize <= VMEM`` — the auditor flags
    longer contexts as vmem-overflow (see docs/analysis.md).
    """
    from repro.analysis import contracts as C
    bh = b * h
    return C.KernelGeometry(
        kernel="kernels.flash_attention.flash_attention",
        grid=(bh, -(-sq // bq)),
        operands=(
            C.OperandSpec("q", (bh, sq, d), dtype, (1, bq, d),
                          lambda bhi, qi, *_: (bhi, qi, 0)),
            C.OperandSpec("k", (bh, skv, d), dtype, (1, skv, d),
                          lambda bhi, qi, *_: (bhi, 0, 0)),
            C.OperandSpec("v", (bh, skv, d), dtype, (1, skv, d),
                          lambda bhi, qi, *_: (bhi, 0, 0)),
            C.OperandSpec("o", (bh, sq, d), dtype, (1, bq, d),
                          lambda bhi, qi, *_: (bhi, qi, 0)),
        ),
        scalar_prefetch=(C.ScalarSpec("kv_valid_len", (b,), "int32"),),
        tag=f"b{b}h{h}sq{sq}skv{skv}d{d}{dtype}bq{bq}bk{bk}",
    )


def _kernel(kvl_ref, q_ref, k_ref, v_ref, o_ref, *, h: int, bq: int, bk: int,
            skv: int, causal: bool, window: int, softcap: float,
            scale: float):
    bhi = pl.program_id(0)
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
    n_kb = skv // bk
    kvl = kvl_ref[bhi // h]
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    def body(kb, carry):
        acc, m, l = carry
        # index the leading block dim with a length-1 slice: pl.load rejects
        # bare int indices on this jax version
        k_blk = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(kb * bk, bk),
                                slice(None)))[0].astype(jnp.float32)
        v_blk = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(kb * bk, bk),
                                slice(None)))[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        valid = kpos < kvl
        if causal:
            delta = qpos - kpos
            valid &= (delta >= 0)
            if window > 0:
                valid &= (delta < window)
        s = jnp.where(valid, s, BIG_NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    d = q_ref.shape[-1]
    init = (jnp.zeros((bq, d), jnp.float32),
            jnp.full((bq,), BIG_NEG, jnp.float32),
            jnp.zeros((bq,), jnp.float32))
    # K blocks past the live length contribute nothing — skip them. Blocks
    # strictly after this Q block are likewise dead under the causal mask.
    last_kb = jnp.minimum(n_kb, (kvl + bk - 1) // bk).astype(jnp.int32)
    if causal:
        last_kb = jnp.minimum(
            last_kb, (qi + 1) * bq // bk + (1 if bq % bk else 0))
    acc, m, l = jax.lax.fori_loop(0, last_kb, body, init)
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    kv_valid_len: Optional[Union[int, jnp.ndarray]] = None,
                    causal: bool = True, window: int = 0, softcap: float = 0.0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jnp.ndarray:
    """q/k/v: (B, H, S, D) with S a multiple of the block sizes (ops-level
    wrappers pad). MQA/GQA callers broadcast KV heads before the call.
    ``kv_valid_len``: live key count per batch row (scalar or ``(B,)``) when
    S_kv carries right-padding; ``None`` means every key row is live."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    bh = b * h
    qf = q.reshape(bh, sq, d)
    kf = k.reshape(bh, skv, d)
    vf = v.reshape(bh, skv, d)
    if kv_valid_len is None:
        kv_valid_len = skv
    kvl = jnp.broadcast_to(
        jnp.asarray(kv_valid_len, jnp.int32).reshape(-1), (b,))
    grid = (bh, sq // bq)
    kern = functools.partial(_kernel, h=h, bq=bq, bk=bk, skv=skv,
                             causal=causal, window=window, softcap=softcap,
                             scale=d ** -0.5)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bq, d), lambda bhi, qi, *_: (bhi, qi, 0)),
                pl.BlockSpec((1, skv, d), lambda bhi, qi, *_: (bhi, 0, 0)),
                pl.BlockSpec((1, skv, d), lambda bhi, qi, *_: (bhi, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bq, d),
                                   lambda bhi, qi, *_: (bhi, qi, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(kvl, qf, kf, vf)
    return out.reshape(b, h, sq, d)
