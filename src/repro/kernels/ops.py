"""Public jit'd wrappers for the Pallas kernels.

Handles: arbitrary-shape padding to block multiples, signed->bit-pattern
conversion for the LUT kernel, padding-contribution correction (padded K rows
contribute T[0,0] per row, subtracted after the call), and automatic
interpret-mode fallback when not running on TPU (this container is CPU-only, so
tests exercise the kernels with interpret=True; on TPU the same wrappers emit
real Mosaic kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import emulate
from . import approx_gemm, systolic_gemm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mult_r: int, mult_c: int) -> jnp.ndarray:
    r, c = x.shape
    pr = (-r) % mult_r
    pc = (-c) % mult_c
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def _blocks(dim: int, pref: int, align: int) -> int:
    """Largest block <= pref that is a multiple of `align` covering dim decently."""
    if dim <= align:
        return dim if dim > 0 else align
    b = min(pref, dim)
    return max(align, (b // align) * align)


def systolic_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int | None = None,
                    bn: int | None = None, bk: int | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Exact int8 GEMM (int32 accumulate) for arbitrary (M, K) x (K, N)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, k = a.shape
    _, n = b.shape
    bm = bm or systolic_gemm.DEFAULT_BM
    bn = bn or systolic_gemm.DEFAULT_BN
    bk = bk or systolic_gemm.DEFAULT_BK
    # in interpret mode alignment is irrelevant; on TPU stay MXU-aligned
    align = 8 if interpret else 128
    bm_, bn_, bk_ = (_blocks(m, bm, align), _blocks(n, bn, align),
                     _blocks(k, bk, align))
    a_p = _pad_to(a, bm_, bk_)
    b_p = _pad_to(b, bk_, bn_)
    out = systolic_gemm.systolic_matmul(a_p, b_p, bm=bm_, bn=bn_, bk=bk_,
                                        interpret=interpret)
    return out[:m, :n]


def approx_matmul(a: jnp.ndarray, b: jnp.ndarray, *, k: int = 4, n_bits: int = 8,
                  acc_bits: int = 24, signed: bool = True,
                  bm: int | None = None, bn: int | None = None,
                  bk: int | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Approximate GEMM at factor k for arbitrary shapes (signed operands)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    span = 1 << n_bits
    mask = span - 1
    m, kd = a.shape
    _, n = b.shape
    table = approx_gemm.make_table(k, n_bits=n_bits, signed=signed,
                                   acc_bits=acc_bits)
    a_u = jnp.asarray(a, jnp.int32) & mask
    b_u = jnp.asarray(b, jnp.int32) & mask
    bm = bm or approx_gemm.DEFAULT_BM
    bn = bn or approx_gemm.DEFAULT_BN
    bk = bk or approx_gemm.DEFAULT_BK
    align = 8 if interpret else 128
    bm_, bn_, bk_ = (_blocks(m, bm, align), _blocks(n, bn, align),
                     _blocks(kd, bk, align))
    a_p = _pad_to(a_u, bm_, bk_)
    b_p = _pad_to(b_u, bk_, bn_)
    out = approx_gemm.approx_matmul_lut(a_p, b_p, table, span=span, bm=bm_,
                                        bn=bn_, bk=bk_, interpret=interpret)
    out = out[:m, :n]
    k_pad = a_p.shape[1] - kd
    if k_pad:
        # padded K rows each contribute T[0,0] (nonzero for deep approximation)
        t00 = table[0]
        out = out - k_pad * t00
    return out
