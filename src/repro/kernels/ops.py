"""Public jit'd wrappers for the Pallas kernels.

Handles: arbitrary-shape padding to block multiples, signed->bit-pattern
conversion for the LUT kernel, padding-contribution correction (padded K rows
contribute T[0,0] per row, subtracted after the call), and automatic
interpret-mode fallback when not running on TPU (this container is CPU-only, so
tests exercise the kernels with interpret=True; on TPU the same wrappers emit
real Mosaic kernels).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error_delta, lut
from . import approx_gemm, delta_gemm, systolic_gemm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mult_r: int, mult_c: int) -> jnp.ndarray:
    r, c = x.shape
    pr = (-r) % mult_r
    pc = (-c) % mult_c
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def _blocks(dim: int, pref: int, align: int) -> int:
    """Largest block <= pref that is a multiple of `align` covering dim decently."""
    if dim <= align:
        return dim if dim > 0 else align
    b = min(pref, dim)
    return max(align, (b // align) * align)


def _tpu_blocks(m: int, n: int, k: int, kernel: str, prefs, *, rank: int = 0):
    """TPU block picker: `_blocks` alignment arithmetic pruned through the
    static lowering contract, so the non-interpret path never launches a
    geometry `repro.analysis.kernel_audit` rejects."""
    from repro.analysis.kernel_audit import gemm_block_plan
    return gemm_block_plan(m, n, k, kernel=kernel, rank=rank, prefs=prefs)


def systolic_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int | None = None,
                    bn: int | None = None, bk: int | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Exact int8 GEMM (int32 accumulate) for arbitrary (M, K) x (K, N)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    m, k = a.shape
    _, n = b.shape
    bm = bm or systolic_gemm.DEFAULT_BM
    bn = bn or systolic_gemm.DEFAULT_BN
    bk = bk or systolic_gemm.DEFAULT_BK
    # in interpret mode alignment is irrelevant; on TPU the block plan is
    # MXU-aligned and contract-pruned
    if interpret:
        bm_, bn_, bk_ = (_blocks(m, bm, 8), _blocks(n, bn, 8),
                         _blocks(k, bk, 8))
    else:
        bm_, bn_, bk_ = _tpu_blocks(m, n, k, "systolic", (bm, bn, bk))
    a_p = _pad_to(a, bm_, bk_)
    b_p = _pad_to(b, bk_, bn_)
    out = systolic_gemm.systolic_matmul(a_p, b_p, bm=bm_, bn=bn_, bk=bk_,
                                        interpret=interpret)
    return out[:m, :n]


def approx_matmul(a: jnp.ndarray, b: jnp.ndarray, *, k: int = 4, n_bits: int = 8,
                  acc_bits: int = 24, signed: bool = True,
                  bm: int | None = None, bn: int | None = None,
                  bk: int | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Approximate GEMM at factor k for arbitrary shapes (signed operands)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    span = 1 << n_bits
    mask = span - 1
    m, kd = a.shape
    _, n = b.shape
    table = approx_gemm.make_table(k, n_bits=n_bits, signed=signed,
                                   acc_bits=acc_bits)
    a_u = jnp.asarray(a, jnp.int32) & mask
    b_u = jnp.asarray(b, jnp.int32) & mask
    bm = bm or approx_gemm.DEFAULT_BM
    bn = bn or approx_gemm.DEFAULT_BN
    bk = bk or approx_gemm.DEFAULT_BK
    if interpret:
        bm_, bn_, bk_ = (_blocks(m, bm, 8), _blocks(n, bn, 8),
                         _blocks(kd, bk, 8))
    else:
        bm_, bn_, bk_ = _tpu_blocks(m, n, kd, "lut", (bm, bn, bk))
    a_p = _pad_to(a_u, bm_, bk_)
    b_p = _pad_to(b_u, bk_, bn_)
    out = approx_gemm.approx_matmul_lut(a_p, b_p, table, span=span, bm=bm_,
                                        bn=bn_, bk=bk_, interpret=interpret)
    out = out[:m, :n]
    k_pad = a_p.shape[1] - kd
    if k_pad:
        # padded K rows each contribute T[0,0] (nonzero for deep approximation)
        t00 = table[0]
        out = out - k_pad * t00
    return out


def approx_delta_matmul(a: jnp.ndarray, b: jnp.ndarray, *, k: int = 4,
                        n_bits: int = 8, acc_bits: int = 24, signed: bool = True,
                        rank: int | None = None, tol: float | None = None,
                        apply_residual: bool = True,
                        bm: int | None = None, bn: int | None = None,
                        bk: int | None = None,
                        interpret: bool | None = None) -> jnp.ndarray:
    """Approximate GEMM via the exact-plus-error-delta decomposition.

    Computes ``A_s @ B_s + round(F_A @ G_B)`` (see core/error_delta.py): one
    exact int8 MXU matmul plus a rank-r float32 correction matmul, fused in a
    single Pallas kernel. At the default rank (``rank_for_exact``) the result
    is bit-identical to ``approx_matmul`` / ``lut.lut_matmul``; a truncated
    ``rank``/``tol`` trades correction FLOPs for bounded extra error, which
    ``apply_residual=True`` cancels again via a gather pass over the integer
    residual table.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    fac = error_delta.delta_factors(n_bits, k, signed, acc_bits, rank=rank,
                                    tol=tol)
    span = 1 << n_bits
    mask = span - 1
    half = span >> 1
    m, kd = a.shape
    _, n = b.shape
    a_u = jnp.asarray(a, jnp.int32) & mask
    b_u = jnp.asarray(b, jnp.int32) & mask
    if not signed:
        # unsigned 8-bit values don't fit the kernel's int8 base dot; the
        # pure-jnp reference handles that (rare, off-paper) configuration.
        return error_delta.delta_matmul_ref(a, b, k=k, n_bits=n_bits,
                                            signed=signed, acc_bits=acc_bits,
                                            rank=rank, tol=tol,
                                            apply_residual=apply_residual)
    a_s = (a_u ^ half) - half                       # sign-extended operand values
    b_s = (b_u ^ half) - half
    bm = bm or delta_gemm.DEFAULT_BM
    bn = bn or delta_gemm.DEFAULT_BN
    bk = bk or delta_gemm.DEFAULT_BK
    if interpret:
        bm_, bn_, bk_ = (_blocks(m, bm, 8), _blocks(n, bn, 8),
                         _blocks(kd, bk, 8))
    else:
        bm_, bn_, bk_ = _tpu_blocks(m, n, kd, "delta", (bm, bn, bk),
                                    rank=fac.rank)
    a_p = _pad_to(a_s, bm_, bk_)
    b_p = _pad_to(b_s, bk_, bn_)
    exact_cancel = apply_residual and not fac.exact
    if exact_cancel:
        # truncated rank, bit-exactness requested: per-block rounding does not
        # commute with the defect cancellation, so run the fused kernel for the
        # base only and round correction + defect once (see error_delta docs)
        base = delta_gemm.delta_matmul_fused(
            a_p, b_p, jnp.zeros((span,), jnp.float32),
            jnp.zeros((span,), jnp.float32), rank=0, span=span, bm=bm_, bn=bn_,
            bk=bk_, interpret=interpret)[:m, :n]
        corr = (error_delta._correction(a_u, b_u, fac) if fac.rank
                else jnp.zeros((m, n), jnp.float32))
        corr = corr + error_delta.defect_gather_matmul(a_u, b_u, fac)
        return base + jnp.round(corr).astype(jnp.int32)
    f_flat, g_flat = error_delta.factor_tables_jnp(n_bits, k, signed, acc_bits,
                                                   rank=rank, tol=tol)
    out = delta_gemm.delta_matmul_fused(a_p, b_p, f_flat, g_flat, rank=fac.rank,
                                        span=span, bm=bm_, bn=bn_, bk=bk_,
                                        interpret=interpret)
    out = out[:m, :n]
    k_pad = a_p.shape[1] - kd
    if k_pad and fac.rank:
        # padded K rows contribute 0 to the base and recon(E[0,0]) each to the
        # per-block-rounded correction (== E[0,0] exactly at the exact rank)
        out = out - k_pad * int(np.round(float(fac.f[0] @ fac.g[:, 0])))
    return out


# --- weight-stationary prepared operands + batched app workloads ------------

@dataclasses.dataclass(frozen=True)
class PreparedOperand:
    """A fixed GEMM operand with its backend-specific precompute done once.

    Built by ``prepare_operand`` (or ``core.gemm.prepare_weights``) for weight
    matrices that are reused across calls — the DCT matrix, convolution
    kernels, model layer weights. ``side`` says which operand of the product
    the matrix is: ``"right"`` for ``x @ W``, ``"left"`` for ``W @ x`` (the
    approximate product table is not symmetric, so the two are distinct).

    Precomputes per backend: ``approx_delta`` stores the rank-r ``G_B`` /
    ``F_A`` correction factor (core/error_delta.PreparedDelta);
    ``approx_onehot`` stores the (K·2^N, N) ``T_B`` table (right side only —
    a fixed left operand precomputes nothing, T_B then depends on the moving
    operand). The remaining backends are stateless and store only the values.

    ``scale`` is the dequantization scale attached by ``core.gemm`` when the
    operand was prepared from *float* weights (per-output-channel): its
    presence switches ``gemm.dot`` into float mode (quantize the moving
    operand only, dequantize with ``moving_scale * scale``).

    ``abft`` is the clean-weight checksum metadata (``core.abft.AbftMeta``)
    attached by ``core.gemm.prepare_weights``: row/column sums of ``values``
    plus a bit-level fingerprint of the derived leaves. Always attached (it
    is cheap) so the pytree structure does not depend on ``GemmPolicy.guard``;
    ``gemm.dot`` only *checks* it when the policy asks.

    Registered as a JAX pytree — arrays are children, the backend/shape-free
    metadata is static aux data — so prepared operands (and whole bound
    parameter pytrees containing them) can be jit arguments and ``lax.scan``
    xs. Leaves may carry extra *leading* stack dimensions (stacked per-layer
    or per-expert preparations built by ``core.gemm.bind``); 2-D consumers
    slice them off via ``lax.scan`` / ``jax.tree.map`` indexing first.
    """
    backend: str
    side: str
    k: int
    n_bits: int
    acc_bits: int
    values: jnp.ndarray
    delta: Optional[error_delta.PreparedDelta] = None
    t_b: Optional[jnp.ndarray] = None
    rank: Optional[int] = None
    tol: Optional[float] = None
    scale: Optional[jnp.ndarray] = None
    abft: Optional[object] = None


jax.tree_util.register_pytree_node(
    PreparedOperand,
    lambda p: ((p.values, p.delta, p.t_b, p.scale, p.abft),
               (p.backend, p.side, p.k, p.n_bits, p.acc_bits, p.rank, p.tol)),
    lambda aux, ch: PreparedOperand(aux[0], aux[1], aux[2], aux[3], aux[4],
                                    ch[0], ch[1], ch[2], aux[5], aux[6],
                                    ch[3], ch[4]))


def prepare_operand(w, *, backend: str, k: int = 4, n_bits: int = 8,
                    acc_bits: int = 24, side: str = "right",
                    rank: int | None = None,
                    tol: float | None = None,
                    restrict: bool = True) -> PreparedOperand:
    """Precompute whatever ``backend`` can amortize for fixed operand ``w``.

    ``restrict=False`` disables the weight-restricted delta rank so prepared
    operands of different weights share one pytree structure (see
    ``error_delta.prepare_delta``). ``w`` may carry leading stack dims
    (``restrict=False`` only): the whole stack is prepared in one vectorized
    pass and every leaf of the result keeps the stack dims in front.
    """
    if side not in ("right", "left"):
        raise ValueError(f"side must be 'right' or 'left', got {side!r}")
    w = jnp.asarray(w, jnp.int32)
    if w.ndim < 2:
        raise ValueError(f"prepared operand must be >= 2D, got shape {w.shape}")
    if w.ndim > 2 and restrict:
        raise ValueError(
            f"stacked preparation (shape {w.shape}) requires restrict=False "
            "so every slice shares one rank/pytree structure")
    delta = t_b = None
    if backend == "approx_delta":
        delta = error_delta.prepare_delta(w, side=side, n_bits=n_bits, k=k,
                                          acc_bits=acc_bits, rank=rank, tol=tol,
                                          restrict=restrict)
    elif backend == "approx_onehot" and side == "right":
        build = functools.partial(lut.build_onehot_weights, n_bits=n_bits,
                                  k=k, acc_bits=acc_bits)
        if w.ndim == 2:
            t_b = build(w)
        else:
            lead = w.shape[:-2]
            flat = jax.vmap(build)(w.reshape((-1,) + w.shape[-2:]))
            t_b = flat.reshape(lead + flat.shape[1:])
    return PreparedOperand(backend, side, k, n_bits, acc_bits, w, delta, t_b,
                           rank, tol)


def prepared_matmul(x, prep: PreparedOperand) -> jnp.ndarray:
    """2D integer GEMM of moving operand ``x`` against a prepared operand."""
    x = jnp.asarray(x, jnp.int32)
    a, b = (x, prep.values) if prep.side == "right" else (prep.values, x)
    backend = prep.backend
    if backend == "exact":
        return jnp.matmul(a, b)
    if backend == "mxu_int8":
        return systolic_matmul(a, b)
    if backend == "approx_lut":
        return approx_matmul(a, b, k=prep.k, n_bits=prep.n_bits,
                             acc_bits=prep.acc_bits)
    if backend == "approx_oracle":
        from repro.core import emulate
        return emulate.matmul_oracle(a, b, n_bits=prep.n_bits, k=prep.k,
                                     acc_bits=prep.acc_bits)
    if backend == "approx_onehot":
        t_b = prep.t_b
        if t_b is None:     # left-fixed operand: T_B depends on the moving b
            t_b = lut.build_onehot_weights(b, n_bits=prep.n_bits,
                                           k=prep.k, acc_bits=prep.acc_bits)
        return lut.onehot_matmul(a, t_b, n_bits=prep.n_bits)
    if backend == "approx_delta":
        return error_delta.delta_matmul_prepared(x, prep.delta)
    raise ValueError(f"unknown backend {backend!r}")


def batched_app_matmul(matmul2d: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
                       a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Pad-and-batch shim: map batched app GEMMs onto the 2D kernel wrappers.

    * ``(..., M, K) x (K, N)`` — batch flattened into the M (rows) dimension.
    * ``(M, K) x (..., K, N)`` — batch flattened into the N (columns)
      dimension. The operand order is preserved (no transpose trick): the
      approximate product table is not symmetric, so ``T @ X`` computed as
      ``(X^T @ T^T)^T`` would change the approximate bits.

    The 2D wrappers then pad to block multiples, so ``(N, 8, 8)`` workloads
    (DCT blocks, im2col tiles) run on the same Pallas kernels as big GEMMs.
    At most one operand may carry batch dimensions.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if a.ndim == 2 and b.ndim == 2:
        return matmul2d(a, b)
    if a.ndim > 2 and b.ndim > 2:
        raise ValueError(
            f"at most one batched operand, got shapes {a.shape} x {b.shape}")
    if b.ndim == 2:                                   # (..., M, K) x (K, N)
        lead = a.shape[:-2]
        m, kd = a.shape[-2:]
        out = matmul2d(a.reshape(-1, kd), b)
        return out.reshape(*lead, m, b.shape[-1])
    lead = b.shape[:-2]                               # (M, K) x (..., K, N)
    kd, n = b.shape[-2:]
    b2 = jnp.moveaxis(b.reshape(-1, kd, n), 1, 0).reshape(kd, -1)
    out = matmul2d(a, b2)                             # (M, batch*N)
    m = a.shape[0]
    return jnp.moveaxis(out.reshape(m, -1, n), 0, 1).reshape(*lead, m, n)


def grouped_matmul(matmul2d: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
                   a: jnp.ndarray, b) -> jnp.ndarray:
    """Grouped GEMM shim: ``(G, M, K) x (G, K, N) -> (G, M, N)``.

    Both operands carry the *same* leading group dimension (MoE expert
    einsums: one weight matrix per expert). The 2D kernel is ``jax.vmap``-ed
    over the group axis — each group keeps its own quantization/preparation
    (which a flattening shim could not express) while the jaxpr stays O(1)
    in the expert count instead of unrolling G subgraphs per GEMM. ``b`` may
    be a raw ``(G, K, N)`` array or a stacked ``PreparedOperand`` (leading
    stack dim on every leaf — a registered pytree, so vmap maps it directly);
    pass a ``matmul2d(a2, b2_or_prep)`` that accepts the corresponding slice.
    """
    a = jnp.asarray(a)
    b_vals = b.values if isinstance(b, PreparedOperand) else jnp.asarray(b)
    if a.ndim != 3 or b_vals.ndim != 3 or b_vals.shape[0] != a.shape[0]:
        raise ValueError(f"grouped_matmul wants (G,M,K) x (G,K,N), got "
                         f"{a.shape} x {b_vals.shape}")
    if not isinstance(b, PreparedOperand):
        b = b_vals
    return jax.vmap(matmul2d)(a, b)
