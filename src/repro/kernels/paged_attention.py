"""Paged flash-attention / flash-decoding as a Pallas TPU kernel (forward).

The gather path in `models.layers.chunked_attention` reconstructs a
contiguous KV layout in HBM before the flash scan: each KV chunk does a
`jnp.take` through the block table, materializing chunk-sized K/V copies the
scan immediately consumes. This kernel removes the round trip — the block
table rides in as a *scalar-prefetch* operand (`pltpu.PrefetchScalarGridSpec`)
so the KV inner loop DMAs pool blocks straight into VMEM through the table:
storage stays paged end to end, exactly the operand-resident dataflow the
paper's systolic PEs are built around.

Two schedules share one kernel body:

* ``n_splits=1`` (default) — one grid cell owns a (batch row, Q chunk) pair
  (every KV head of the row is batched inside the cell — fewer grid cells,
  wider dots) and scans every KV chunk sequentially. The math mirrors
  `chunked_attention`'s ``kv_body`` operation for operation (same chunk grid,
  same masking, same online-softmax update, same reduction order), so the
  output is **bit-identical** to the gather path — and therefore to solo
  lockstep decode — on every backend. This is the serving configuration.
* ``n_splits>1`` — flash-decoding: the KV chunk range is split across grid
  cells that each produce a partial softmax ``(acc, m, l)``; partials are
  combined outside the kernel with the standard log-sum-exp merge. The
  combine reassociates the softmax sums, so parity with the sequential scan
  is up to float rounding (~1e-6), not bitwise — long-context throughput at
  the cost of the strict determinism contract.

Unlike the gather path (a fixed-trip `lax.scan` over every table chunk), the
KV loop bound here is *dynamic per batch row*: chunks past
``ceil(kv_valid_len / chunk)`` (and, causally, past the row's last query
position) are never visited. Skipped chunks are fully masked in the
reference — an exact bitwise no-op (``corr = exp(0)``, ``p = exp(-inf)``) —
so early exit is free, and decode work scales with each slot's *live* length
instead of the table width. Rows with ``kv_valid_len == 0`` return zeros
(the reference emits a masked-garbage mean over V; no caller reads either).

Pool payloads may be int8 (`layers.cache_store`): blocks are dequantized
in-kernel after the load, so no full-pool dequant copy is ever materialized.

Written for Mosaic; validated in interpret mode against the gather path
(tests/test_paged.py) like the other kernels in this package.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG_NEG = -2.3819763e38  # min bf16 (matches layers.BIG_NEG)


def tpu_contract(*, batch: int, q_len: int, kv_heads: int, q_per_kv: int,
                 head_dim: int, n_pool: int, block_size: int,
                 table_width: int, chunk: int = 1024, q_chunk: int = 1024,
                 n_splits: int = 1, kv_dtype: str = "float32",
                 q_dtype: str = "float32"):
    """Static lowering contract mirroring `paged_attention`'s pallas_call.

    Shape/dtype geometry only (no tracing, no jax). Mirrors the wrapper's
    chunk narrowing / table+query padding arithmetic exactly, so
    `autotune.paged_kernel_plan` can pre-prune (kv_chunk, n_splits) plans
    that cannot lower. The pools ride in ANY space and are DMA-staged chunk
    by chunk, so VMEM scales with ``chunk`` and not with ``n_pool``.
    """
    from repro.analysis import contracts as C
    b, kh, g, d = batch, kv_heads, q_per_kv, head_dim
    skv = table_width * block_size
    chunk = min(chunk, skv)
    if chunk % block_size:
        raise ValueError(f"attention chunk {chunk} must be a multiple of "
                         f"the KV block size {block_size}")
    nbpc = chunk // block_size
    nk = -(-skv // chunk)
    n_splits = max(1, min(int(n_splits), nk))
    width_p = nk * nbpc                     # table padded with the dump row
    qc = min(q_chunk, q_len)
    nq = -(-q_len // qc)
    sq_p = nq * qc
    pool_shape = (n_pool, block_size, kh, d)
    q_map = lambda bi, qi, si, *_: (bi, qi, 0, 0, 0, 0)
    operands = [
        C.OperandSpec("q", (b, nq, kh, g, qc, d), q_dtype,
                      (1, 1, kh, g, qc, d), q_map),
        C.OperandSpec("k_pool", pool_shape, kv_dtype, memory_space="any"),
        C.OperandSpec("v_pool", pool_shape, kv_dtype, memory_space="any"),
    ]
    if n_splits == 1:
        operands.append(C.OperandSpec(
            "o", (b, nq, kh, g, qc, d), q_dtype, (1, 1, kh, g, qc, d), q_map))
    else:
        s_map = lambda bi, qi, si, *_: (bi, qi, si, 0, 0, 0, 0)
        r_map = lambda bi, qi, si, *_: (bi, qi, si, 0, 0, 0)
        operands += [
            C.OperandSpec("acc", (b, nq, n_splits, kh, g, qc, d), "float32",
                          (1, 1, 1, kh, g, qc, d), s_map),
            C.OperandSpec("m", (b, nq, n_splits, kh, g, qc), "float32",
                          (1, 1, 1, kh, g, qc), r_map),
            C.OperandSpec("l", (b, nq, n_splits, kh, g, qc), "float32",
                          (1, 1, 1, kh, g, qc), r_map),
        ]
    return C.KernelGeometry(
        kernel="kernels.paged_attention.paged_attention",
        grid=(b, nq, n_splits),
        operands=tuple(operands),
        scalar_prefetch=(
            C.ScalarSpec("block_tables", (b, width_p), "int32"),
            C.ScalarSpec("kv_valid_len", (b,), "int32"),
            C.ScalarSpec("q_positions", (b, sq_p), "int32"),
            C.ScalarSpec("window", (1,), "int32"),
        ),
        scratch_bytes=C.scratch_bytes(
            ((nbpc, block_size, kh, d), kv_dtype),
            ((nbpc, block_size, kh, d), kv_dtype)),
        tag=(f"b{b}q{q_len}kh{kh}g{g}d{d}pool{n_pool}x{block_size}"
             f"w{table_width}c{chunk}s{n_splits}{kv_dtype}"),
    )


def _kernel(tables_ref, kvlen_ref, qpos_ref, win_ref,      # scalar prefetch
            q_ref, k_ref, v_ref, *rest,
            kh: int, g: int, qc: int, chunk: int, blk_sz: int, nk: int,
            n_splits: int, causal: bool, softcap: float, int8_scale: float,
            quant: bool):
    # rest = out refs (1 or 3 depending on n_splits) + VMEM staging scratch
    # for one K chunk and one V chunk + the DMA semaphore
    out_refs, (k_scr, v_scr, dma_sem) = rest[:-3], rest[-3:]
    b = pl.program_id(0)
    qi = pl.program_id(1)
    si = pl.program_id(2)
    nbpc = chunk // blk_sz
    d = q_ref.shape[-1]

    q = q_ref[0, 0].astype(jnp.float32)                     # (KH, G, qc, D)
    kvl = kvlen_ref[b]
    win = win_ref[0]
    window_eff = jnp.where(win > 0, win,
                           jnp.iinfo(jnp.int32).max).astype(jnp.int32)

    def gather(ref, scr, ci):
        # in-kernel table walk: one pool-block DMA per table entry, HBM (ANY
        # space) -> VMEM scratch — the chunk's contiguous layout is assembled
        # in VMEM, never in HBM, and the pools themselves are never blocked
        # into VMEM (a pool is 10-100x the VMEM budget at production sizes)
        copies = []
        for j in range(nbpc):
            blk = tables_ref[b, ci * nbpc + j]
            cp = pltpu.make_async_copy(ref.at[pl.dslice(blk, 1)],
                                       scr.at[pl.dslice(j, 1)], dma_sem)
            cp.start()
            copies.append(cp)
        for cp in copies:
            cp.wait()
        # (nbpc, blk_sz, kh, d) -> (chunk, kh, d): identical element order to
        # concatenating the per-block loads, so bits match the gather path
        blk_v = scr[...].reshape(nbpc * blk_sz, kh, d)
        blk_f = blk_v.astype(jnp.float32).swapaxes(0, 1)    # (KH, chunk, D)
        return blk_f / int8_scale if quant else blk_f

    def body(ci, state):
        acc, m, l = state
        k_blk = gather(k_ref, k_scr, ci)
        v_blk = gather(v_ref, v_scr, ci)
        # (KH, G*qc, D) x (KH, chunk, D), batched over the head dim: the
        # per-(b, kh) contraction is bit-identical to the reference batched
        # einsum (tests pin this)
        s = jax.lax.dot_general(q.reshape(kh, g * qc, d), k_blk,
                                (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        s = s.reshape(kh, g, qc, chunk)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (qc, chunk), 1)
        valid = kpos < kvl
        if causal:
            qp = pl.load(qpos_ref, (b, pl.dslice(qi * qc, qc)))
            delta = qp[:, None] - kpos
            valid = valid & (delta >= 0) & (delta < window_eff)
        s = jnp.where(valid[None, None], s, BIG_NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jax.lax.dot_general(
            p.reshape(kh, g * qc, chunk), v_blk,
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32).reshape(kh, g, qc, d)
        return acc_new, m_new, l_new

    init = (jnp.zeros((kh, g, qc, d), jnp.float32),
            jnp.full((kh, g, qc), BIG_NEG, jnp.float32),
            jnp.zeros((kh, g, qc), jnp.float32))
    cps = -(-nk // n_splits)                     # chunks per split
    lo = si * cps
    hi = jnp.minimum(lo + cps, nk)
    # dynamic per-row early exit: chunks past the live KV length (and, for
    # causal attention, past the block's last query position) are exact
    # bitwise no-ops in the reference scan — skip them
    hi = jnp.minimum(hi, (kvl + chunk - 1) // chunk)
    if causal:
        qp_all = pl.load(qpos_ref, (b, pl.dslice(qi * qc, qc)))
        hi = jnp.minimum(hi, (jnp.max(qp_all) + chunk) // chunk)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, init)

    if n_splits == 1:
        o_ref, = out_refs
        o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[..., None]
                       ).astype(o_ref.dtype)
    else:
        acc_ref, m_ref, l_ref = out_refs
        acc_ref[0, 0, 0] = acc
        m_ref[0, 0, 0] = m
        l_ref[0, 0, 0] = l


def paged_attention(q, k_pool, v_pool, block_tables, kv_valid_len,
                    q_positions, *, causal: bool = True, window=0,
                    softcap: float = 0.0, chunk: int = 64,
                    q_chunk: int = 1024, n_splits: int = 1,
                    int8_scale: float = 32.0, interpret=None):
    """Fused paged attention over a shared block pool.

    q: (B, Sq, H, D) — *unscaled* queries (the kernel applies D**-0.5 in the
    query dtype, exactly like `chunked_attention`). k_pool/v_pool:
    ``(n_blocks + 1, block_size, KH, D)`` shared pools, float or int8
    payload (int8 is dequantized in-kernel with ``int8_scale``).
    block_tables: (B, max_blocks) int32 per-slot map, dump row = pool row
    ``n_blocks`` for unused entries. kv_valid_len: scalar or (B,);
    q_positions: (Sq,) or (B, Sq). ``window`` may be a traced per-layer
    scalar (it rides the layer scan); 0/negative disables windowing.
    ``n_splits > 1`` enables flash-decoding (see module docstring — parity
    becomes tolerance-level, not bitwise). Returns (B, Sq, H, D) in q.dtype.
    """
    b, sq, h, d = q.shape
    n_pool, blk_sz, kh, _ = k_pool.shape
    g = h // kh
    width = block_tables.shape[1]
    skv = width * blk_sz
    # Narrow the chunk grid to the logical cache. When the table fits one
    # chunk the reference also runs a single (zero-padded) chunk pass, and a
    # single narrow pass is bitwise-identical to a single wide one — every
    # extra reference column is masked to an exact-zero contribution. The
    # serving win: a 64-token table scans 64 wide, not attn_chunk (1024)
    # wide. (Never changes the chunk *count*, so multi-chunk grids still
    # match the reference exactly.)
    chunk = min(chunk, skv)
    if chunk % blk_sz:
        raise ValueError(f"attention chunk {chunk} must be a multiple of "
                         f"the KV block size {blk_sz}")
    nbpc = chunk // blk_sz
    nk = -(-skv // chunk)
    n_splits = max(1, min(int(n_splits), nk))
    pad_b = nk * nbpc - width
    bt = block_tables.astype(jnp.int32)
    if pad_b:       # pad with the dump row — masked exactly like zero-pad
        bt = jnp.pad(bt, ((0, 0), (0, pad_b)), constant_values=n_pool - 1)

    qc = min(q_chunk, sq)
    nq = -(-sq // qc)
    qpad = nq * qc - sq
    scale = d ** -0.5
    qh = (q * scale).reshape(b, sq, kh, g, d).transpose(0, 2, 3, 1, 4)
    qpos = jnp.asarray(q_positions, jnp.int32)
    qpos = jnp.broadcast_to(qpos[None] if qpos.ndim == 1 else qpos, (b, sq))
    if qpad:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, 0), (0, qpad), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, qpad)))
    # (B, NQ, KH, G, qc, D): one grid row per batch row — every KV head of a
    # row is batched inside its cell (fewer grid cells, wider dots)
    q_in = qh.reshape(b, kh, g, nq, qc, d).transpose(0, 3, 1, 2, 4, 5)

    kvl = jnp.broadcast_to(
        jnp.asarray(kv_valid_len, jnp.int32).reshape(-1), (b,))
    win = jnp.asarray(window, jnp.int32).reshape(-1)[:1]

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kern = functools.partial(
        _kernel, kh=kh, g=g, qc=qc, chunk=chunk, blk_sz=blk_sz, nk=nk,
        n_splits=n_splits, causal=causal, softcap=float(softcap),
        int8_scale=float(int8_scale), quant=k_pool.dtype == jnp.int8)
    # pools stay in ANY space (HBM): the kernel DMAs table blocks into the
    # chunk-sized VMEM scratch itself, so the VMEM footprint is O(chunk) and
    # independent of the pool size — blocking a whole pool into VMEM cannot
    # lower at production pool sizes (the kernel auditor pins this)
    pool_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, nq, n_splits),
        in_specs=[
            pl.BlockSpec((1, 1, kh, g, qc, d),
                         lambda bi, qi, si, *_: (bi, qi, 0, 0, 0, 0)),
            pool_spec,
            pool_spec,
        ],
        scratch_shapes=[
            pltpu.VMEM((nbpc, blk_sz, kh, d), k_pool.dtype),
            pltpu.VMEM((nbpc, blk_sz, kh, d), v_pool.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        out_specs=(
            pl.BlockSpec((1, 1, kh, g, qc, d),
                         lambda bi, qi, si, *_: (bi, qi, 0, 0, 0, 0))
            if n_splits == 1 else [
                pl.BlockSpec((1, 1, 1, kh, g, qc, d),
                             lambda bi, qi, si, *_: (bi, qi, si, 0, 0, 0, 0)),
                pl.BlockSpec((1, 1, 1, kh, g, qc),
                             lambda bi, qi, si, *_: (bi, qi, si, 0, 0, 0)),
                pl.BlockSpec((1, 1, 1, kh, g, qc),
                             lambda bi, qi, si, *_: (bi, qi, si, 0, 0, 0)),
            ]),
    )
    out_shape = (
        jax.ShapeDtypeStruct((b, nq, kh, g, qc, d), q.dtype)
        if n_splits == 1 else [
            jax.ShapeDtypeStruct((b, nq, n_splits, kh, g, qc, d),
                                 jnp.float32),
            jax.ShapeDtypeStruct((b, nq, n_splits, kh, g, qc), jnp.float32),
            jax.ShapeDtypeStruct((b, nq, n_splits, kh, g, qc), jnp.float32),
        ])
    res = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(bt, kvl, qpos, win, q_in, k_pool, v_pool)

    if n_splits == 1:
        out = res
    else:
        acc, m, l = res                      # (B, NQ, NS, KH, G, qc[, D])
        m_tot = m.max(axis=2)
        w = jnp.exp(m - m_tot[:, :, None])
        l_tot = (l * w).sum(axis=2)
        acc_tot = (acc * w[..., None]).sum(axis=2)
        out = (acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]).astype(q.dtype)
    out = (out.transpose(0, 1, 4, 2, 3, 5)   # (B, NQ, qc, KH, G, D)
           .reshape(b, nq * qc, h, d))
    return out[:, :sq]
