"""Pure-jnp oracles for the Pallas kernels.

* `systolic_matmul_ref` — exact int8 -> int32 GEMM (what the exact PE array / MXU
  computes).
* `approx_matmul_ref`   — approximate GEMM under the multiplier-approx model:
  product-table lookups + exact int32 accumulation (see core/lut.py). This is the
  semantic contract of the Pallas approx kernel; the *fused* bit-level oracle lives
  in core/emulate.matmul_oracle and differs only by the accumulator's low-column
  error component (quantified in tests/test_kernels.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import lut


def systolic_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(M, K) x (K, N) exact integer GEMM with int32 accumulation."""
    return jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32))


def approx_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, *, k: int = 4,
                      n_bits: int = 8, acc_bits: int = 24,
                      signed: bool = True) -> jnp.ndarray:
    """(M, K) x (K, N) approximate GEMM at approximation factor k."""
    return lut.lut_matmul(a, b, n_bits=n_bits, k=k, signed=signed,
                          acc_bits=acc_bits)
