"""Exact int8 systolic GEMM as a Pallas TPU kernel.

This is the TPU-native form of the paper's *exact* PE array: the MXU is a 128x128
weight-stationary systolic array of exact MACs, so the exact design maps onto it
directly. The kernel tiles (M, N, K) into VMEM-resident blocks; the K grid axis is
innermost ("arbitrary" semantics) and accumulates into the output block, mirroring
the partial-sum chaining of the paper's array.

Block sizes default to MXU-aligned (multiples of 128 in M/N, 256 in K for int8
packing); the wrapper in ops.py pads arbitrary shapes up to block multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 256


def tpu_contract(m: int, n: int, k: int, *, bm: int = DEFAULT_BM,
                 bn: int = DEFAULT_BN, bk: int = DEFAULT_BK):
    """Static lowering contract mirroring `systolic_matmul`'s pallas_call.

    Shape/dtype geometry only (no tracing, no jax) — evaluated by
    `repro.analysis.kernel_audit` over the autotune-reachable grid.
    """
    from repro.analysis import contracts as C
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (-(-m // bm), -(-n // bn), -(-k // bk))
    return C.KernelGeometry(
        kernel="kernels.systolic_gemm.systolic_matmul",
        grid=grid,
        operands=(
            C.OperandSpec("a", (m, k), "int8", (bm, bk),
                          lambda i, j, kk: (i, kk)),
            C.OperandSpec("b", (k, n), "int8", (bk, bn),
                          lambda i, j, kk: (kk, j)),
            C.OperandSpec("o", (m, n), "int32", (bm, bn),
                          lambda i, j, kk: (i, j)),
        ),
        tag=f"m{m}n{n}k{k}bm{bm}bn{bn}bk{bk}",
    )


def _kernel(a_ref, b_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_blk = a_ref[...]
    b_blk = b_ref[...]
    # int8 x int8 -> int32 on the MXU (exact PE array)
    o_ref[...] += jax.lax.dot_general(
        a_blk, b_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def systolic_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = DEFAULT_BM,
                    bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jnp.ndarray:
    """(M, K) int8 x (K, N) int8 -> (M, N) int32. Shapes must be block multiples
    (ops.systolic_matmul pads arbitrary shapes)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k})x({k},{n}) not multiples of blocks ({bm},{bn},{bk})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a.astype(jnp.int8), b.astype(jnp.int8))
