# NOTE: dryrun is intentionally not imported here — it sets XLA_FLAGS at import.
from . import mesh, roofline  # noqa: F401
