"""Analytic roofline model — exact napkin math for the framework's own loop
structure.

Why this exists: XLA's `cost_analysis()` on the partitioned module counts each
`while`-loop body ONCE, and all of this framework's compute lives inside scans
(microbatch scan x layer scan x attention chunk scans), so raw HLO numbers
undercount FLOPs/collective bytes by the product of trip counts. Rather than
heuristically re-scaling the HLO, this module computes the three roofline terms
from the architecture and the known execution structure; the dry-run reports
both (raw HLO as evidence of the compiled schedule, analytic for the roofline
fractions). All quantities are per device per step.

Mesh/parallelism model (parameters are the hillclimb knobs):
  * `tp`        — tensor-parallel ways on the `model` axis (the rest of that
                  axis, model_axis/tp, acts as extra FSDP/data ways)
  * `n_micro`   — microbatch count (activation memory vs. weight re-gather)
  * chips = 256 x pods; batch is sharded over all non-TP ways.

Traffic model (conservative single-link ICI, ring factor 2):
  * TP: 2 activation all-reduces per transformer layer (attn out, mlp out)
  * FSDP: one weight all-gather per microbatch (bf16), grad reduce-scatter +
    all-gather in f32 once per step
  * pods: cross-pod gradient all-reduce (f32; /4 when int8 compression is on)
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeSpec
from . import mesh as mesh_mod


@dataclasses.dataclass(frozen=True)
class PerfKnobs:
    tp: int = 16               # TP ways (<= model axis size)
    n_micro: int = 1
    remat: bool = True
    compress_grads: bool = False
    act_accesses_per_layer: float = 6.0   # residual-stream R/W per layer pass
    ring_factor: float = 2.0


def _attn_layers(cfg: ModelConfig) -> float:
    if cfg.family == "hybrid":
        return cfg.n_layers / max(cfg.attn_every, 1)
    if cfg.family == "ssm":
        return 0.0
    return float(cfg.n_layers)


def _mean_attn_span(cfg: ModelConfig, s: int, *, decode: bool = False) -> float:
    """Mean attention span per query (accounts for sliding-window patterns)."""
    full = float(s) if decode or not cfg.causal else (s + 1) / 2.0
    if not cfg.window_size:
        return full
    local = float(min(cfg.window_size, s))
    if cfg.global_every:
        fg = 1.0 / cfg.global_every
        return fg * full + (1 - fg) * local
    return local


def flops_per_device(cfg: ModelConfig, shape: ShapeSpec, n_chips: int,
                     k: PerfKnobs) -> float:
    s = shape.seq_len
    p_act = cfg.active_param_count()
    attn_tok = (4.0 * _mean_attn_span(cfg, s, decode=(shape.kind == "decode"))
                * cfg.n_heads * cfg.hd * _attn_layers(cfg))
    fwd_tok = 2.0 * p_act + attn_tok
    if shape.kind == "decode":
        return shape.global_batch * fwd_tok / n_chips
    tokens = float(shape.global_batch) * s
    if shape.kind == "prefill":
        return tokens * fwd_tok / n_chips
    # train: fwd(1) + bwd(2) + full remat recompute(1)
    passes = 4.0 if k.remat else 3.0
    ce_tok = 2.0 * cfg.d_model * cfg.vocab_size * 3.0       # logits matmul f+b
    return tokens * (passes * fwd_tok + ce_tok) / n_chips


def _kv_cache_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    s = shape.seq_len
    b = shape.global_batch
    total = 0.0
    n_attn = _attn_layers(cfg)
    if n_attn:
        if cfg.window_size and cfg.global_every:
            fg = 1.0 / cfg.global_every
            eff = fg * s + (1 - fg) * min(cfg.window_size, s)
        else:
            eff = float(s)
        total += 2.0 * 2.0 * b * eff * cfg.n_kv_heads * cfg.hd * n_attn
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_expand * cfg.d_model
        total += 4.0 * b * cfg.n_layers * (di // 64) * 64 * max(cfg.ssm_state, 64)
    return total


def hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeSpec, n_chips: int,
                         k: PerfKnobs) -> float:
    p_tot = cfg.param_count()
    p_act = cfg.active_param_count()
    d = cfg.d_model
    if shape.kind == "decode":
        w = 2.0 * p_act / n_chips                       # bf16 weight shard read
        return w + _kv_cache_bytes(cfg, shape) / n_chips
    tokens_loc = shape.global_batch * shape.seq_len * k.tp / n_chips
    w = 2.0 * p_act / k.tp * k.n_micro                  # TP slice per microbatch
    acts = tokens_loc * d * 2.0 * k.act_accesses_per_layer * cfg.n_layers
    if shape.kind == "train":
        opt = 12.0 * p_tot / n_chips * 2.0              # adam m/v/grad R+W (f32)
        return w + acts * 3.0 + opt
    return w / max(k.n_micro, 1) + acts


def collective_bytes_per_device(cfg: ModelConfig, shape: ShapeSpec,
                                n_chips: int, k: PerfKnobs,
                                pods: int = 1) -> float:
    p_tot = cfg.param_count()
    p_act = cfg.active_param_count()
    d = cfg.d_model
    if cfg.family == "ssm":
        n_red = 1.0 * cfg.n_layers                      # block down-proj reduce
    elif cfg.family == "hybrid":
        n_red = 1.0 * cfg.n_layers + 2.0 * _attn_layers(cfg)
    else:
        n_red = 2.0 * cfg.n_layers                      # attn out + mlp out
    if shape.kind == "decode":
        tokens_loc = shape.global_batch * k.tp / n_chips
        tp_b = (k.ring_factor * tokens_loc * d * 2.0 * n_red) if k.tp > 1 else 0.0
        return tp_b
    tokens_loc = shape.global_batch * shape.seq_len * k.tp / n_chips
    tp_b = (k.ring_factor * tokens_loc * d * 2.0 * n_red) if k.tp > 1 else 0.0
    if shape.kind == "train":
        tp_b *= 2.0                                     # bwd re-reduces
        fsdp_ways = n_chips // k.tp
        gbytes = 1.0 if k.compress_grads else 4.0       # int8 error-feedback
        fsdp = 2.0 * p_act / k.tp * k.n_micro if fsdp_ways > 1 else 0.0
        grad = gbytes * p_tot / k.tp * k.ring_factor if fsdp_ways > 1 else 0.0
        pod_b = gbytes * p_tot / (n_chips / pods) * k.ring_factor * (pods - 1)
        return tp_b + fsdp + grad + pod_b
    return tp_b


def analytic_terms(cfg: ModelConfig, shape: ShapeSpec, n_chips: int,
                   k: PerfKnobs = PerfKnobs(), pods: int = 1) -> Dict[str, float]:
    fl = flops_per_device(cfg, shape, n_chips, k)
    hb = hbm_bytes_per_device(cfg, shape, n_chips, k)
    cl = collective_bytes_per_device(cfg, shape, n_chips, k, pods)
    t_c = fl / mesh_mod.PEAK_FLOPS
    t_m = hb / mesh_mod.HBM_BW
    t_l = cl / mesh_mod.ICI_BW
    dominant = max(("compute", t_c), ("memory", t_m), ("collective", t_l),
                   key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_l)
    from . import roofline as rl
    mf = rl.model_flops(cfg, shape) / n_chips
    return {
        "flops_per_device": fl, "hbm_bytes_per_device": hb,
        "coll_bytes_per_device": cl,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_l,
        "dominant": dominant, "step_time_bound_s": bound,
        "model_flops_per_device": mf,
        "useful_flops_frac": mf / fl if fl else 0.0,
        "roofline_frac": (mf / mesh_mod.PEAK_FLOPS) / bound if bound else 0.0,
        "knobs": dataclasses.asdict(k),
    }
