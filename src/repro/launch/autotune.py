"""Fleet-wide knob autotuning: apply the §Perf lessons to every (arch x shape).

For each cell, grid the analytic roofline model over the TP/FSDP split and the
microbatch count under hard feasibility constraints (batch shardability, HBM
estimate), and return the best knobs. `dryrun --optimized` compiles with them —
the "optimized fleet" table in EXPERIMENTS.md comes from that pass.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.configs.base import ModelConfig, ShapeSpec
from . import analytic
from .analytic import PerfKnobs

HBM_BYTES = 16 * 2 ** 30          # v5e
_TP_CHOICES = (1, 2, 4, 8, 16)
_MXU_LANE = 128                   # MXU tile edge: KV chunks below this waste it


def paged_kernel_plan(max_len: int, block_size: int, *, batch: int = 1,
                      kv_heads: int = 1, attn_chunk: int = 1024,
                      target_cells: int = 8, allow_splits: bool = False,
                      head_dim: Optional[int] = None, q_per_kv: int = 1,
                      n_pool: Optional[int] = None,
                      kv_dtype: str = "float32",
                      vmem_budget: Optional[int] = None) -> Tuple[int, int]:
    """Pick (kv_chunk, n_splits) for `kernels.paged_attention`.

    ``kv_chunk``: the widest multiple of ``block_size`` that is <= the
    logical cache (table width * block) and <= ``attn_chunk`` — matching the
    narrowing the kernel itself applies, so callers can size VMEM/scratch
    against it. Below one MXU lane-width the chunk is left at the cache size
    (splitting a sub-128 scan buys nothing).

    ``n_splits``: 1 unless ``allow_splits`` — split-KV flash decoding
    reassociates the softmax combine, so the bit-exact serving contract
    (engine == solo lockstep) only holds at 1. When allowed (long-context
    throughput mode), split so the grid reaches ~``target_cells`` cells
    (cores / MXU pipelines to fill), bounded by the chunk count — each split
    must keep >= 1 chunk.

    With ``head_dim`` given the plan is additionally pruned through the
    static lowering contract (`analysis.kernel_audit.prune_paged_plan`):
    ``kv_chunk`` shrinks until the decode grid cell fits the TPU's tiling
    and VMEM rules, so the planner never proposes a geometry Mosaic would
    reject — a property test pins this (tests/test_analysis_audit.py).
    """
    width = -(-max_len // block_size)
    skv = width * block_size
    kv_chunk = min(attn_chunk, skv)
    kv_chunk -= kv_chunk % block_size
    kv_chunk = max(kv_chunk, block_size)
    nk = -(-skv // kv_chunk)
    if not allow_splits or skv <= _MXU_LANE:
        n_splits = 1
    else:
        cells = batch * kv_heads                  # decode: nq == 1
        n_splits = max(1, min(nk, -(-target_cells // max(cells, 1))))
    if head_dim is None:
        return kv_chunk, n_splits
    from repro.analysis.kernel_audit import prune_paged_plan
    return prune_paged_plan(kv_chunk, n_splits, max_len=max_len,
                            block_size=block_size, batch=batch,
                            kv_heads=kv_heads, head_dim=head_dim,
                            q_per_kv=q_per_kv, n_pool=n_pool,
                            kv_dtype=kv_dtype, vmem_budget=vmem_budget)


def gemm_block_plan(m: int, n: int, k: int, **kw) -> Tuple[int, int, int]:
    """TPU GEMM block picker, contract-pruned — see
    `analysis.kernel_audit.gemm_block_plan` (re-exported here so launch-side
    callers and `kernels.ops`' TPU path share one planner)."""
    from repro.analysis.kernel_audit import gemm_block_plan as _plan
    return _plan(m, n, k, **kw)


def _mem_estimate(cfg: ModelConfig, shape: ShapeSpec, n_chips: int,
                  k: PerfKnobs) -> float:
    """Per-device HBM residency estimate (params+opt+grads + layer-scan carries
    + attention working set), calibrated against measured dry-runs (~30% margin
    applied by the caller via the 16 GiB limit vs measured 13-14 GiB points)."""
    p_tot = cfg.param_count()
    if shape.kind != "train":
        # weights + cache + activations for one forward
        cache = analytic._kv_cache_bytes(cfg, shape) / n_chips
        act = shape.global_batch * shape.seq_len * cfg.d_model * 2 * 4 / n_chips
        return 2 * p_tot / n_chips * (n_chips / 16) ** 0 + cache + act * 2
    state = 14.0 * p_tot / n_chips                  # bf16 p + f32 g/m/v sharded
    tokens_micro_loc = (shape.global_batch * shape.seq_len * k.tp
                        / n_chips / max(k.n_micro, 1))
    carries = tokens_micro_loc * cfg.d_model * 2 * cfg.n_layers
    if not k.remat:
        carries *= 8.0                              # attention/MLP residuals
    attn_ws = tokens_micro_loc * cfg.n_heads * 1024 * 4 * 2
    # 1.8x: calibration factor vs measured dry-runs (qwen tp=16/nm=8 measured
    # 13.7 GiB vs 7.4 GiB raw estimate — CE/f32 promotions/fragmentation)
    return (state + carries + attn_ws) * 1.8


def best_knobs(cfg: ModelConfig, shape: ShapeSpec, n_chips: int = 256,
               pods: int = 1) -> Tuple[Optional[Tuple[int, ...]], PerfKnobs, dict]:
    """Returns (mesh_shape, knobs, analytic terms) maximizing roofline_frac."""
    best = None
    for tp in _TP_CHOICES:
        data_ways = n_chips // tp
        # the batch must fully shard over the data ways (b=1 long-context cells
        # shard the sequence/cache instead and are exempt)
        if shape.global_batch > 1 and shape.global_batch % data_ways != 0:
            continue
        if shape.kind == "train":
            micro_opts = sorted({1, 2, 4, 8, 16})
        else:
            micro_opts = [1]
        for nm in micro_opts:
            if shape.global_batch % nm or (shape.global_batch // nm) % 1:
                continue
            if shape.global_batch // nm < 1:
                continue
            # microbatch must stay shardable over the data ways
            if nm > 1 and (shape.global_batch // nm) % min(
                    data_ways, shape.global_batch // nm) != 0:
                continue
            k = PerfKnobs(tp=tp, n_micro=nm)
            if _mem_estimate(cfg, shape, n_chips, k) > HBM_BYTES:
                continue
            t = analytic.analytic_terms(cfg, shape, n_chips, k, pods=pods)
            # decode ties: prefer larger tp — it shards the KV cache (the
            # analytic memory *time* term is per-device-traffic-invariant in
            # tp, but residency is not)
            score = (t["roofline_frac"], tp if shape.kind == "decode" else -nm)
            if best is None or score > best[0]:
                best = (score, tp, nm, t)
    if best is None:   # fall back to baseline
        k = PerfKnobs(tp=16, n_micro=1)
        return None, k, analytic.analytic_terms(cfg, shape, n_chips, k, pods)
    _, tp, nm, t = best
    per_pod = n_chips // pods
    mesh_shape = (per_pod // tp, tp) if pods == 1 else (pods, per_pod // tp, tp)
    return mesh_shape, PerfKnobs(tp=tp, n_micro=nm), t
