import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device count
at first init). 512 host placeholder devices back both the 16x16 single-pod and
the (2,16,16) multi-pod production meshes; lowering uses ShapeDtypeStruct
stand-ins so no real allocation happens.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out FILE]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS
from repro.launch import analytic, roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (TrainHParams, assemble_decode, assemble_prefill,
                                assemble_train, default_micro)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             n_micro: int | None = None, mesh_shape=None, cache_dtype=None,
             verbose: bool = True) -> dict:
    cfg = ARCHS[arch]
    shape = cfg.shape(shape_name)
    mesh_label = "x".join(map(str, mesh_shape)) if mesh_shape else (
        "2x16x16" if multi_pod else "16x16")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_label,
           "kind": shape.kind}
    if shape.skip:
        rec.update(status="skipped", reason=shape.skip_reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        if shape.kind == "train":
            hp = TrainHParams(n_micro=n_micro or default_micro(cfg, shape))
            step, arg_specs, in_sh, out_sh, hp = assemble_train(cfg, shape, mesh,
                                                                hp)
            rec["n_micro"] = hp.n_micro
        elif shape.kind == "prefill":
            step, arg_specs, in_sh, out_sh = assemble_prefill(cfg, shape, mesh)
        else:
            step, arg_specs, in_sh, out_sh = assemble_decode(
                cfg, shape, mesh, cache_dtype=cache_dtype)
            if cache_dtype is not None:
                rec["cache_dtype"] = str(cache_dtype.__name__) \
                    if hasattr(cache_dtype, "__name__") else str(cache_dtype)
        with mesh:
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = roofline.collective_bytes(hlo)
        terms = roofline.roofline_terms(cost, coll["total"], n_chips)
        mf = roofline.model_flops(cfg, shape)
        hlo_flops_global = terms["flops_per_device"] * n_chips
        tp = mesh.shape["model"]
        knobs = analytic.PerfKnobs(tp=tp, n_micro=rec.get("n_micro", 1))
        ana = analytic.analytic_terms(cfg, shape, n_chips, knobs,
                                      pods=mesh.shape.get("pod", 1))
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            bytes_per_device=int(getattr(mem, "temp_size_in_bytes", 0)
                                 + getattr(mem, "argument_size_in_bytes", 0)
                                 + getattr(mem, "output_size_in_bytes", 0)
                                 - getattr(mem, "alias_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
            arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            collectives=coll,
            roofline_hlo_raw=terms,
            analytic=ana,
            model_flops=mf,
            hlo_flops_note="while bodies counted once; see analytic",
        )
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: OK  "
                  f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
                  f"mem/dev {rec['bytes_per_device']/2**30:.2f} GiB  "
                  f"analytic: t_comp {ana['t_compute_s']:.4f}s "
                  f"t_mem {ana['t_memory_s']:.4f}s "
                  f"t_coll {ana['t_collective_s']:.4f}s -> {ana['dominant']}  "
                  f"roofline {ana['roofline_frac']:.1%}")
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug to report
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: FAIL {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--optimized", action="store_true",
                    help="autotune mesh/knobs per cell (launch/autotune.py)")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    for a in archs:
        shapes = [s.name for s in ARCHS[a].shapes] if (args.all or not args.shape) \
            else [args.shape]
        for s in shapes:
            cells.append((a, s))
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for mp in meshes:
        for a, s in cells:
            mesh_shape, n_micro = None, args.n_micro
            if args.optimized:
                from repro.launch import autotune
                cfg = ARCHS[a]
                sp = cfg.shape(s)
                if not sp.skip:
                    mesh_shape, knobs, _ = autotune.best_knobs(
                        cfg, sp, 512 if mp else 256, pods=2 if mp else 1)
                    n_micro = knobs.n_micro
            rec = run_cell(a, s, multi_pod=mp, n_micro=n_micro,
                           mesh_shape=mesh_shape)
            # measured-feedback retry: the analytic memory estimate can
            # undershoot (SSM chunk residuals, MoE capacity buffers) — if the
            # compiled memory exceeds HBM, back off: train -> more micro-
            # batches; prefill/decode -> more TP (shards caches/experts)
            hbm = 16 * 2 ** 30
            attempts = 0
            cache_dtype = None
            while (args.optimized and rec.get("status") == "ok"
                   and rec.get("bytes_per_device", 0) > hbm and attempts < 4):
                attempts += 1
                cfg = ARCHS[a]
                sp = cfg.shape(s)
                if sp.kind == "train":
                    nm = (n_micro or 1) * 2
                    while sp.global_batch % nm and nm < sp.global_batch:
                        nm += 1
                    if sp.global_batch % nm:
                        break
                    n_micro = nm
                else:
                    cur_tp = mesh_shape[-1] if mesh_shape else 16
                    if cur_tp < 16 and mesh_shape is not None:
                        tp = cur_tp * 2
                        chips = 1
                        for d in mesh_shape:
                            chips *= d
                        mesh_shape = (chips // tp, tp) if len(mesh_shape) == 2 \
                            else (mesh_shape[0], chips // mesh_shape[0] // tp, tp)
                    elif sp.kind == "decode" and cache_dtype is None:
                        import jax.numpy as _jnp
                        cache_dtype = _jnp.int8   # validated quality trade
                    else:
                        break
                print(f"  [retry {attempts}] {a} x {s}: over HBM "
                      f"({rec['bytes_per_device']/2**30:.1f} GiB) -> "
                      f"mesh={mesh_shape} n_micro={n_micro} "
                      f"cache={cache_dtype}")
                rec = run_cell(a, s, multi_pod=mp, n_micro=n_micro,
                               mesh_shape=mesh_shape, cache_dtype=cache_dtype)
            results.append(rec)
            if args.out:
                with open(args.out, "a") as f:
                    slim = {k: v for k, v in rec.items() if k != "trace"}
                    f.write(json.dumps(slim) + "\n")
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run summary: {n_ok} ok / {n_skip} skipped / {n_err} failed ==")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
