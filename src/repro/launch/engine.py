"""Continuous-batching serve engine: slot scheduler over one batched decode.

The lockstep serving loop pads every request to the batch's slowest one — a
whole batch stalls on its longest generation and re-fills only between
batches. This engine instead keeps a fixed pool of **slots** (the batch rows
of one jit'd decode step) continuously busy under ragged real-world traffic:

* **admission queue** — submitted requests wait FIFO; a request is admitted
  as soon as a slot is free (and, in trace replay, its arrival step has
  passed — full-queue backpressure is just the queue outlasting the pool).
* **paged KV cache** (default) — full-attention KV lives in a shared block
  pool (`launch.paged.BlockPool`): admission reserves a request's worst-case
  block footprint (backpressuring on the *pool*, not on `slots x max_len`
  contiguous regions), blocks are allocated on first write and freed at
  retirement. At a fixed HBM budget concurrency is bounded by the tokens
  requests actually hold, not by the per-slot maximum.
* **chunked prefill** — prompts stream through the *same* jit'd batched step
  as decode, in chunks of `prefill_chunk` tokens: a step's batch mixes
  prompt chunks and single decode tokens (per-slot `q_len`), so admission
  never dispatches a one-request prefill and bursty arrivals batch their
  prompt work. The final chunk samples the request's first token with the
  same RNG stream the fused admit used to.
* **per-slot ragged decode** — one jit'd step decodes all slots at their own
  `positions: (B,)`, writes each slot's KV/SSM state at its own offset, and
  samples each slot under its own parameters and RNG stream
  (`launch.sampling`). Inactive slots ride along as masked garbage: their
  writes are redirected to the pool's dump block and their state is wiped at
  the next admit (`models.api.reset_slot`).
* **retirement & slot reuse** — a slot retires on EOS or on its request's
  token budget, returns its blocks to the pool, and is immediately
  available to the admission loop.

``paged=False`` keeps the PR-4 contiguous engine: per-slot `max_len` cache
regions, fused whole-prompt prefill-on-admit — the baseline the capacity
benchmark compares against, bit-identical streams to the paged engine.

**Request lifecycle hardening** (see docs/serving.md "Reliability"):
bounded admission queue with an explicit ``rejected_queue_full`` status,
per-request TTFT / total-latency deadlines in engine steps (deterministic —
no wall clocks in scheduling decisions), client cancellation that frees the
slot and its blocks immediately, and priority admission with
preempt-and-requeue under block-pool exhaustion: a higher-priority arrival
may evict the most-recently-admitted lower-priority slot, whose request is
requeued and later **replayed from its prompt bit-identically** (the
determinism contract above makes preemption invisible in the stream). A
preempted request's effective priority is aged up by one per preemption, so
sustained high-priority pressure cannot starve it forever. With
``policy.guard != 'none'`` the paged engine additionally scrubs its bound
params and KV pool between steps (bit-level fingerprints, core/abft.py),
drains the ABFT fault ledger after every step, and recovers: params faults
restore from the init-time pristine snapshot and re-dispatch (bounded
retries), cache faults quarantine the pool — every active request is
requeued and the pool reinitialized, streams again bit-identical on replay.

Per-request determinism: activations are quantized per-row (`core.gemm.dot`),
attention/caches are per-slot, MoE serving dispatch runs at full capacity,
recurrent and ring state advances per token under a validity mask (so prompt
chunking cannot move a bit), and sampling keys are per-request — so each
request's token stream is bit-identical to running it alone through the
lockstep loop (`launch.serve.lockstep_generate`), for every GEMM backend,
with raw or `gemm.bind`-bound params. See docs/serving.md.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import abft
from repro.core.gemm import EXACT, GemmPolicy
from repro.models import api as model_api
from repro.train.fault import TransientError
from . import paged as paged_mod
from . import sampling
from . import steps as steps_mod

PyTree = Any

# Retirement status code for requests bounced by a full admission queue.
REJECTED_QUEUE_FULL = "rejected_queue_full"

# Engine default for the retirement-time BlockPool.check() invariant sweep.
# Off in production (O(pool) asserts per retirement); the test suite turns it
# on globally via conftest so every engine test doubles as a leak detector.
VALIDATE_POOL_DEFAULT = False


def _build_steps(cfg: ModelConfig, policy: GemmPolicy):
    """Jitted engine steps: fused admit (prefill + slot scatter + first-token
    sample + slot-state writes — one dispatch per admission) and fused decode
    (batched ragged decode + per-slot sample + device-side position/counter
    advance — one dispatch per token). All slot state stays device-resident;
    the scheduler only syncs the sampled tokens back each step."""
    model = model_api.get_model(cfg)

    def admit(params, batch, big_cache, zero_cache1, slot, start_pos, state,
              new_temp, new_topk, new_topp, new_key, new_eos, new_budget):
        logits, cache1 = model.prefill(params, batch, zero_cache1,
                                       policy=policy)
        axes = model_api.cache_batch_axes(big_cache)
        big_cache = {
            key: jax.lax.dynamic_update_slice_in_dim(
                big_cache[key], cache1[key].astype(big_cache[key].dtype),
                slot, axis=axes[key])
            for key in big_cache
        }
        # token i of a request always samples with fold_in(base_key, i):
        # the first (prefill) token is i=0, decode tokens fold the counter
        first = sampling.sample_tokens(
            logits[:, -1].astype(jnp.float32), new_temp[None],
            new_topk[None], new_topp[None],
            jax.random.fold_in(new_key, 0)[None])[0]
        state = dict(
            state,
            positions=state["positions"].at[slot].set(start_pos),
            counters=state["counters"].at[slot].set(1),
            last_tok=state["last_tok"].at[slot, 0].set(first),
            active=state["active"].at[slot].set(True),
            temperature=state["temperature"].at[slot].set(new_temp),
            top_k=state["top_k"].at[slot].set(new_topk),
            top_p=state["top_p"].at[slot].set(new_topp),
            keys=state["keys"].at[slot].set(new_key),
            eos=state["eos"].at[slot].set(new_eos),
            budget=state["budget"].at[slot].set(new_budget))
        return first, big_cache, state

    def decode(params, cache, state):
        logits, cache = model.decode_step(params, state["last_tok"], cache,
                                          state["positions"], policy=policy)
        keys = jax.vmap(jax.random.fold_in)(state["keys"], state["counters"])
        next_tok = sampling.sample_tokens(logits[:, 0].astype(jnp.float32),
                                          state["temperature"],
                                          state["top_k"], state["top_p"],
                                          keys)
        inc = state["active"].astype(jnp.int32)
        state = dict(state,
                     positions=state["positions"] + inc,
                     counters=state["counters"] + inc,
                     last_tok=next_tok[:, None])
        return next_tok, cache, state

    def retire(state, slot):
        return dict(state, active=state["active"].at[slot].set(False))

    return jax.jit(admit), jax.jit(decode), jax.jit(retire)


def _build_paged_steps(cfg: ModelConfig, policy: GemmPolicy,
                       paged_kernel=None):
    """Jitted paged-engine steps: one fused **chunk step** (mixed
    prefill+decode batch -> per-slot sample + device-side state advance; jit
    specializes per chunk width T, bounded by `prefill_chunk` distinct
    widths — the step narrows to the widest live chunk), a fused **admit** (slot state + per-slot cache
    wipe), and the retire flag-flip. The scheduler syncs one sampled-token
    vector per step, exactly like the contiguous engine.

    ``paged_kernel`` routes the step's paged-attention reads through the
    fused Pallas kernel (`kernels.paged_attention`) instead of the
    block-table gather path — bit-identical streams at n_splits == 1."""
    step_fn = steps_mod.make_chunk_step(cfg, policy,
                                        paged_kernel=paged_kernel)

    def chunk(params, tokens, cache, state, q_len, emit, input_embeds=None,
              embed_mask=None):
        logits, cache = step_fn(params, tokens, cache, state["positions"],
                                q_len, input_embeds, embed_mask)
        # token i of a request samples with fold_in(base_key, i): the final
        # prefill chunk emits token 0, decode steps fold the counter
        keys = jax.vmap(jax.random.fold_in)(state["keys"], state["counters"])
        tok = sampling.sample_tokens(logits[:, 0].astype(jnp.float32),
                                     state["temperature"], state["top_k"],
                                     state["top_p"], keys)
        state = dict(
            state,
            positions=state["positions"] + q_len,
            counters=state["counters"] + emit.astype(jnp.int32),
            last_tok=jnp.where(emit, tok, state["last_tok"][:, 0])[:, None])
        return tok, cache, state

    def admit(cache, state, slot, start_pos, new_temp, new_topk, new_topp,
              new_key, new_eos, new_budget):
        # start_pos > 0 resumes a cached prefix: the slot's table already
        # maps the shared blocks, so prefill picks up at the boundary
        cache = model_api.reset_slot(cache, slot)
        state = dict(
            state,
            positions=state["positions"].at[slot].set(start_pos),
            counters=state["counters"].at[slot].set(0),
            active=state["active"].at[slot].set(True),
            temperature=state["temperature"].at[slot].set(new_temp),
            top_k=state["top_k"].at[slot].set(new_topk),
            top_p=state["top_p"].at[slot].set(new_topp),
            keys=state["keys"].at[slot].set(new_key),
            eos=state["eos"].at[slot].set(new_eos),
            budget=state["budget"].at[slot].set(new_budget))
        return cache, state

    def retire(state, slot):
        return dict(state, active=state["active"].at[slot].set(False))

    return jax.jit(chunk), jax.jit(admit), jax.jit(retire)


def _build_multi_step(cfg: ModelConfig, policy: GemmPolicy, n: int,
                      paged_kernel=None):
    """Jitted fixed-horizon dispatcher (`steps.make_multi_step`): one scan
    covers ``n`` decode sub-steps with device-resident EOS/budget
    retirement; the scheduler syncs one ``(n, B)`` token block per horizon
    instead of one token vector per step."""
    return jax.jit(steps_mod.make_multi_step(cfg, policy, n,
                                             paged_kernel=paged_kernel))


_cached_build_steps = functools.lru_cache(maxsize=64)(_build_steps)
_cached_build_paged = functools.lru_cache(maxsize=64)(_build_paged_steps)
_cached_build_multi = functools.lru_cache(maxsize=64)(_build_multi_step)


def cached_multi_step(cfg: ModelConfig, policy: GemmPolicy, n: int,
                      paged_kernel=None):
    """`_build_multi_step` memoized by (cfg, policy, n, paged_kernel) — same
    executable-sharing contract as `cached_steps`."""
    try:
        return _cached_build_multi(cfg, policy, n, paged_kernel=paged_kernel)
    except TypeError:
        return _build_multi_step(cfg, policy, n, paged_kernel=paged_kernel)


def cached_steps(cfg: ModelConfig, policy: GemmPolicy, paged: bool = False,
                 paged_kernel=None):
    """`_build_steps` memoized by (cfg, policy[, paged_kernel]) so every
    engine instance (and benchmark rep) reuses the compiled executables.
    Policies with dict overrides are unhashable and fall back to a fresh
    build."""
    kw = {"paged_kernel": paged_kernel} if paged else {}
    build = _cached_build_paged if paged else _cached_build_steps
    try:
        return build(cfg, policy, **kw)
    except TypeError:
        return (_build_paged_steps if paged else _build_steps)(cfg, policy,
                                                               **kw)


@dataclasses.dataclass
class Request:
    """One generation request.

    `arrival` is in engine *steps* (trace replay): the request becomes
    admissible once the engine has taken that many steps. `eos_id` overrides
    the engine-level EOS token for this request (None = engine default).

    `priority` orders admission (higher wins; equal priorities keep exact
    FIFO order) and qualifies the request to preempt strictly-lower-priority
    slots when the block pool is exhausted. `ttft_deadline` /
    `total_deadline` are budgets in engine *steps from arrival*: a request
    that has not emitted its first token (resp. retired) within the budget
    is retired with status ``deadline_ttft`` / ``deadline_total``.
    `preempt_count` is engine-maintained aging state: each preemption raises
    the request's effective priority by one, so it cannot starve.
    """
    rid: int
    prompt: np.ndarray                      # (P,) int32 prompt tokens
    max_new_tokens: int
    params: sampling.SamplingParams = sampling.GREEDY
    arrival: int = 0
    eos_id: Optional[int] = None
    input_embeds: Optional[np.ndarray] = None   # vlm: (S_img, d) patch embeds
    priority: int = 0
    ttft_deadline: Optional[int] = None
    total_deadline: Optional[int] = None
    preempt_count: int = 0                  # engine-maintained (aging)


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    tokens: np.ndarray                      # (n,) int32 generated tokens
    prompt_len: int                         # incl. vlm patch positions
    admitted_step: int                      # -1 if never admitted
    finished_step: int
    finish_reason: str    # "eos" | "length" | "deadline_ttft" |
    #                       "deadline_total" | "cancelled" |
    #                       "rejected_queue_full"
    preemptions: int = 0                    # times preempted before finishing


class ServeEngine:
    """Slot-based continuous batching for any decode-capable model family.

    ``paged=True`` (default) serves from a paged KV cache with chunked
    prefill: ``block_size`` tokens per block, ``n_blocks`` pool blocks
    (default: the contiguous budget, ``max_slots * ceil(max_len /
    block_size)`` — shrink it, or raise ``max_slots`` at the same pool, to
    trade per-slot headroom for concurrency), ``prefill_chunk`` prompt
    tokens admitted per step. ``paged=False`` is the PR-4 contiguous
    engine; both produce bit-identical per-request streams.

    ``paged_kernel`` (paged mode only) serves attention reads through the
    fused Pallas paged-attention kernel — the block table is walked *inside*
    the kernel, so no gather materializes KV in HBM and each slot's scan
    stops at its live length. ``True``/``1`` keeps the sequential KV scan
    (streams stay bit-identical to the gather path and to solo lockstep);
    an int > 1 enables split-KV flash decoding with that many splits
    (log-sum-exp combine — tolerance-level parity, long contexts only).
    See `launch.autotune.paged_kernel_plan` for picking the split count.

    ``multi_step=n`` (n > 1) fuses ``n`` decode sub-steps into one
    device-resident ``lax.scan`` horizon (`steps.make_multi_step`): EOS and
    budget retirement run on device, the host syncs one ``(n, B)`` token
    block per horizon instead of one vector per token, and scheduler
    bookkeeping (admission, deadlines, retirement) runs at horizon
    boundaries only. Streams stay bit-identical to ``multi_step=1`` and to
    solo lockstep; mixed prefill/decode steps fall back to the per-step
    path automatically. See docs/serving.md "Multi-step dispatch".

    ``prefix_cache`` (paged mode, default on) shares KV blocks across
    requests with equal prompt prefixes: admission matches a rolling-hash
    key chain against resident blocks, attaches every leading hit to the
    new slot's table, and prefills only the uncached tail; retirement
    parks unreferenced cached blocks in an LRU evicted only under pool
    pressure, and writes into shared blocks copy-on-write. Streams stay
    bit-identical to an uncached run (the resumed prefill recomputes the
    last prompt position, and block contents are a pure function of the
    chain key). Automatically disabled for families with per-slot cache
    state outside the pool (gemma3 ring buffers, hybrid SSM, xLSTM); VLM
    requests carrying ``input_embeds`` are skipped per-request. See
    docs/serving.md "Prefix caching".
    """

    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 policy: GemmPolicy = EXACT, max_slots: int = 4,
                 max_len: int = 64, eos_id: Optional[int] = None,
                 paged: bool = True, block_size: int = 8,
                 n_blocks: Optional[int] = None, prefill_chunk: int = 8,
                 paged_kernel=None, queue_limit: Optional[int] = None,
                 validate_pool: Optional[bool] = None,
                 max_step_retries: int = 2, retry_backoff_s: float = 0.0,
                 retry_backoff_cap_s: float = 1.0, multi_step: int = 1,
                 prefix_cache: bool = True):
        if cfg.family == "audio":
            raise ValueError("encoder-only arch has no decode step")
        if paged_kernel and not paged:
            raise ValueError("paged_kernel requires paged=True (the fused "
                             "kernel reads through block tables)")
        if multi_step < 1:
            raise ValueError(f"multi_step must be >= 1, got {multi_step}")
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.queue_limit = queue_limit
        self.validate_pool = (VALIDATE_POOL_DEFAULT if validate_pool is None
                              else validate_pool)
        self.max_step_retries = max_step_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_backoff_cap_s = retry_backoff_cap_s
        self.multi_step = multi_step
        self.model = model_api.get_model(cfg)
        self.n_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.paged = paged
        self.paged_kernel = paged_kernel

        if paged:
            spec = (paged_mod.PagedSpec(n_blocks, block_size)
                    if n_blocks is not None
                    else paged_mod.default_spec(max_slots, max_len, block_size))
            self.pool = paged_mod.BlockPool(spec, max_slots, max_len)
            self.cache = self.model.init_paged_cache(
                max_slots, max_len, spec.n_blocks, spec.block_size)
            self.prefill_chunk = max(1, prefill_chunk)
            # per-slot prefill cursor (None once the slot is decoding) and
            # host mirror of the device-side write position
            self.slot_prefill_off: List[Optional[int]] = [None] * max_slots
            self.slot_pos = np.zeros(max_slots, np.int64)
            self._tables_dev = None          # device mirror, rebuilt on change
            self._dev_cache = {}             # step-input mirrors, see _dev_cached
            self.occ = {"slot_steps": 0, "slot_active_steps": 0,
                        "block_steps": 0, "block_alloc_steps": 0,
                        "prefill_tokens": 0, "decode_tokens": 0}
            # prefix caching is sound only when every cache leaf lives in
            # the shared pool: families with per-slot state outside it (ring
            # buffers, SSM/xLSTM recurrent state) can't resume mid-prompt
            # from shared blocks alone, so the cache degrades to off
            pool_pure = isinstance(self.cache, dict) and all(
                key == "block_tables" or key in model_api.PAGED_POOL_LEAVES
                for key in self.cache)
            self.prefix_cache = bool(prefix_cache and pool_pure)
            self._prefix_seed = paged_mod.cache_seed(cfg, policy)
            self._copy_blocks = steps_mod.make_copy_blocks_step()
            self.slot_chain: List[Sequence[bytes]] = [()] * max_slots
            self.slot_cacheable = [False] * max_slots
            self.prefix_events = {"prefix_hits": 0,
                                  "prefix_tokens_skipped": 0,
                                  "prefix_invalidations": 0}
        else:
            self.prefix_cache = False
            self.cache = self.model.init_cache(max_slots, max_len)
            # a pristine single-slot cache reused (never mutated) by every admit
            self._zero_cache1 = self.model.init_cache(1, max_len)

        b = max_slots
        # device-resident per-slot state, touched only inside the jitted
        # admit/decode/retire steps — the scheduler syncs one token vector
        # per step and keeps small host mirrors for its own bookkeeping
        self.state = {
            "positions": jnp.zeros(b, jnp.int32),  # next cache write offset
            "counters": jnp.zeros(b, jnp.int32),   # sampled tokens per slot
            "last_tok": jnp.zeros((b, 1), jnp.int32),
            "active": jnp.zeros(b, bool),
            "temperature": jnp.zeros(b, jnp.float32),
            "top_k": jnp.zeros(b, jnp.int32),
            "top_p": jnp.ones(b, jnp.float32),
            "keys": jnp.zeros((b, 2), jnp.uint32),
            # device-resident retirement (multi-step horizons): per-slot EOS
            # id (-1 = none) and clamped token budget — the scan flips
            # `active` itself when a slot finishes mid-horizon
            "eos": jnp.full(b, -1, jnp.int32),
            "budget": jnp.zeros(b, jnp.int32),
        }
        self.active = np.zeros(b, bool)            # host mirror
        self.slot_req: List[Optional[Request]] = [None] * b
        self.slot_out: List[List[int]] = [[] for _ in range(b)]
        self.slot_admitted = np.zeros(b, np.int32)

        self.queue: "collections.deque[Request]" = collections.deque()
        self.finished: Dict[int, FinishedRequest] = {}
        self.step_count = 0
        self.decode_steps = 0
        self.peak_active = 0                 # measured, both engine modes
        self.host_syncs = 0                  # token-block device->host syncs
        self.backoff_s_total = 0.0           # measured retry wait (stats)
        # reliability counters, surfaced through `stats` and serve.py
        self.events = {REJECTED_QUEUE_FULL: 0, "cancelled": 0,
                       "deadline_ttft": 0, "deadline_total": 0,
                       "preemptions": 0, "faults_detected": 0,
                       "step_retries": 0, "quarantines": 0}

        if paged:
            self._chunk, self._admit_paged_step, self._retire = cached_steps(
                cfg, policy, paged=True, paged_kernel=paged_kernel)
        else:
            self._admit_step, self._decode, self._retire = cached_steps(cfg,
                                                                        policy)
        if multi_step > 1:
            self._multi = cached_multi_step(
                cfg, policy, multi_step,
                paged_kernel=paged_kernel if paged else None)

        # ABFT scrub state: pristine params reference (JAX arrays are
        # immutable, so an injected flip *replaces* leaves on self.params and
        # this snapshot stays clean — restore is a reference swap) plus
        # bit-level fingerprints of params and the KV cache, re-verified
        # before every step
        self._guard = policy.guard != "none"
        if self._guard:
            self._pristine_params = params
            self._params_fp = abft.tree_fingerprint(params)
            self._cache_fp = abft.tree_fingerprint(self._scrub_view())

    # --- scheduler ----------------------------------------------------------

    def submit(self, request: Request) -> bool:
        """Queue a request. With `queue_limit` set, a full queue rejects it
        immediately with status ``rejected_queue_full`` (visible in
        `finished` and the `events` counters) instead of blocking silently.
        Returns False iff rejected."""
        if (self.queue_limit is not None
                and len(self.queue) >= self.queue_limit):
            self._finish_unstarted(request, REJECTED_QUEUE_FULL)
            return False
        self.queue.append(request)
        return True

    def cancel(self, rid: int) -> bool:
        """Client cancellation: retire the request now with status
        ``cancelled``, freeing its slot and blocks immediately (queued
        requests are simply removed). Tokens generated so far are kept in
        the `FinishedRequest`. Returns False if `rid` is not live."""
        for slot in np.flatnonzero(self.active):
            req = self.slot_req[slot]
            if req is not None and req.rid == rid:
                self._retire_slot(slot, "cancelled")
                return True
        for req in self.queue:
            if req.rid == rid:
                self.queue.remove(req)
                self._finish_unstarted(req, "cancelled")
                return True
        return False

    def _finish_unstarted(self, req: Request, reason: str) -> None:
        self.finished[req.rid] = FinishedRequest(
            req.rid, np.zeros(0, np.int32), self._start_len(req), -1,
            self.step_count, reason, preemptions=req.preempt_count)
        if reason in self.events:
            self.events[reason] += 1

    def _start_len(self, req: Request) -> int:
        n = len(req.prompt)
        if req.input_embeds is not None:
            n += req.input_embeds.shape[0]
        return n

    def _eos_of(self, req: Request) -> int:
        """Effective EOS token id, ``-1`` = none. The host-side retirement
        check and the device-resident retirement mask (multi-step horizons)
        are both driven by this value, so their decisions provably agree."""
        eos = req.eos_id if req.eos_id is not None else self.eos_id
        return -1 if eos is None else int(eos)

    def _reserved_blocks(self, req: Request) -> int:
        """Worst-case block footprint: prompt + clamped budget, minus the
        final token whose KV is never written."""
        return self.pool.spec.blocks_for(self._start_len(req)
                                         + self._budget(req) - 1)

    def _cacheable(self, req: Request) -> bool:
        """Per-request prefix-cache eligibility: VLM requests with patch
        embeds have non-token prompt content the key chain can't identify."""
        return self.prefix_cache and req.input_embeds is None

    def _prefix_plan(self, req: Request):
        """(chain keys, hit blocks, extra COW budget, resume offset).

        Resuming at ``min(cached, start - 1)`` — never ``start`` — keeps the
        first sampled token bit-identical to a cold prefill: the final chunk
        recomputes at least the last prompt position's logits under the
        exact per-request stream. When the whole prompt is cached that one
        recomputed position rewrites the final attached block, the one
        deterministic COW site admission budgets an extra fresh block for.
        """
        if not self._cacheable(req):
            return (), [], 0, 0
        start = self._start_len(req)
        bs = self.pool.spec.block_size
        keys = paged_mod.chain_keys(self._prefix_seed, req.prompt, bs,
                                    start // bs)
        hits = self.pool.match_prefix(keys)
        cached = len(hits) * bs
        resume = min(cached, start - 1)
        extra_cow = 1 if cached >= start else 0
        return keys, hits, extra_cow, resume

    def _admit_paged(self, slot: int, req: Request, plan=None) -> None:
        start = self._start_len(req)
        if start > self.max_len:
            raise ValueError(f"request {req.rid}: prompt length {start} "
                             f"exceeds max_len {self.max_len}")
        keys, hits, extra_cow, resume = (self._prefix_plan(req)
                                         if plan is None else plan)
        self.pool.reserve(slot, self._reserved_blocks(req), hits=hits,
                          extra_cow=extra_cow, written=resume)
        if hits:
            self._tables_dev = None          # attach rewrote the table row
            self.prefix_events["prefix_hits"] += 1
            self.prefix_events["prefix_tokens_skipped"] += resume
        sp = req.params
        self.cache, self.state = self._admit_paged_step(
            self.cache, self.state, slot, jnp.int32(resume),
            jnp.float32(sp.temperature),
            jnp.int32(sp.top_k), jnp.float32(sp.top_p),
            sampling.request_key(sp.seed, req.rid),
            jnp.int32(self._eos_of(req)), jnp.int32(self._budget(req)))
        self.active[slot] = True
        self.slot_req[slot] = req
        self.slot_out[slot] = []
        self.slot_admitted[slot] = self.step_count
        self.slot_prefill_off[slot] = resume
        self.slot_pos[slot] = resume
        self.slot_chain[slot] = keys
        self.slot_cacheable[slot] = self._cacheable(req)
        if self._guard:                      # admit wiped the slot's cache
            self._cache_fp = abft.tree_fingerprint(self._scrub_view())

    def _admit(self, slot: int, req: Request) -> None:
        start = self._start_len(req)
        if start > self.max_len:
            raise ValueError(f"request {req.rid}: prompt length {start} "
                             f"exceeds max_len {self.max_len}")
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        if req.input_embeds is not None:
            batch["input_embeds"] = jnp.asarray(req.input_embeds[None],
                                                jnp.float32)
        sp = req.params
        first, self.cache, self.state = self._admit_step(
            self.params, batch, self.cache, self._zero_cache1, slot, start,
            self.state, jnp.float32(sp.temperature), jnp.int32(sp.top_k),
            jnp.float32(sp.top_p), sampling.request_key(sp.seed, req.rid),
            jnp.int32(self._eos_of(req)), jnp.int32(self._budget(req)))
        self.active[slot] = True
        self.slot_req[slot] = req
        self.slot_out[slot] = [int(first)]
        self.host_syncs += 1                 # the fused admit syncs `first`
        self.slot_admitted[slot] = self.step_count
        if self._guard:                      # admit wrote the slot's cache
            self._cache_fp = abft.tree_fingerprint(self._scrub_view())
        self._maybe_retire(slot)

    def _budget(self, req: Request) -> int:
        # token n's producing decode writes its KV at cache offset
        # start + n - 2 (token 1 comes from prefill; the final token's own KV
        # is never written), so n tokens need start + n - 1 <= max_len; clamp
        # the request budget to what its slot can hold
        return max(1, min(req.max_new_tokens,
                          self.max_len - self._start_len(req) + 1))

    def _release_keys(self, slot: int) -> Sequence[bytes]:
        """Content keys for every block the retiring slot fully wrote —
        prompt *and* generated tokens, so a multi-turn follow-up whose
        prompt extends this conversation matches the decode-produced blocks
        too. KV position ``p`` always holds token ``p`` of the full
        sequence, so the chain over ``prompt ++ out`` identifies them."""
        req = self.slot_req[slot]
        bs = self.pool.spec.block_size
        full = int(self.slot_pos[slot]) // bs
        if full == 0:
            return ()
        toks = np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(self.slot_out[slot], np.int32)])
        full = min(full, len(toks) // bs)
        return paged_mod.chain_keys(self._prefix_seed, toks[:full * bs], bs)

    def _free_slot(self, slot: int) -> None:
        """Clear a slot's device flag, host mirrors, and (paged) blocks."""
        if self.paged:
            keys = (self._release_keys(slot)
                    if self.slot_cacheable[slot] else ())
        self.active[slot] = False
        self.state = self._retire(self.state, slot)
        self.slot_req[slot] = None
        self.slot_out[slot] = []
        if self.paged:
            self.pool.release(slot, keys=keys)   # free-on-retire (or cache)
            self.slot_prefill_off[slot] = None
            self.slot_chain[slot] = ()
            self.slot_cacheable[slot] = False
            self._tables_dev = None          # force re-upload of the tables
            if self.validate_pool:
                self.pool.check()            # leaks surface at retire time

    def _retire_slot(self, slot: int, reason: str) -> None:
        req = self.slot_req[slot]
        self.finished[req.rid] = FinishedRequest(
            req.rid, np.asarray(self.slot_out[slot], np.int32),
            self._start_len(req), int(self.slot_admitted[slot]),
            self.step_count, reason, preemptions=req.preempt_count)
        self._free_slot(slot)
        if reason in self.events:
            self.events[reason] += 1

    def _maybe_retire(self, slot: int) -> None:
        req = self.slot_req[slot]
        out = self.slot_out[slot]
        eos = self._eos_of(req)
        if eos >= 0 and out and out[-1] == eos:
            self._retire_slot(slot, "eos")
        elif len(out) >= self._budget(req):
            self._retire_slot(slot, "length")

    def _preempt_slot(self, slot: int) -> Request:
        """Evict a live request: free its slot/blocks, discard its partial
        stream, and return it for requeueing. Replay is bit-identical to an
        uninterrupted run (per-request determinism), so preemption is
        invisible in the stream. Ages the request's effective priority."""
        req = self.slot_req[slot]
        req.preempt_count += 1               # aging: no starvation
        self._free_slot(slot)
        self.events["preemptions"] += 1
        return req

    def _eff_priority(self, req: Request) -> int:
        return req.priority + req.preempt_count

    def _next_candidate(self) -> Optional[int]:
        """Queue index of the next request to admit: highest effective
        priority among arrived requests; equal priorities keep FIFO order."""
        best = None
        for i, req in enumerate(self.queue):
            if req.arrival > self.step_count:
                continue                     # trace replay: not yet arrived
            if (best is None or self._eff_priority(req)
                    > self._eff_priority(self.queue[best])):
                best = i
        return best

    def _plan_preemption(self, req: Request, fresh: int,
                         hits: Sequence[int]) -> Optional[List[int]]:
        """Victim slots to evict so `req` can reserve ``fresh`` new blocks
        (on top of attaching the ``hits`` prefix blocks), or None.

        Only strictly-lower-effective-priority slots qualify; victims are
        taken most-recently-admitted first (least progress lost). Pure
        planning — no side effects until the caller commits. A victim's
        blocks that the new request's prefix hits cover are *not* counted as
        gain (`BlockPool.can_admit` pins them right back), and a preempted
        victim's own cached prefix survives in the index, so its replay
        resumes from the shared blocks instead of re-prefilling."""
        pri = self._eff_priority(req)
        victims = sorted(
            (s for s in np.flatnonzero(self.active)
             if self._eff_priority(self.slot_req[s]) < pri),
            key=lambda s: (-int(self.slot_admitted[s]), -s))
        chosen: List[int] = []
        for s in victims:
            if self.pool.can_admit(fresh, hits, exclude=chosen):
                break
            chosen.append(s)
        if not self.pool.can_admit(fresh, hits, exclude=chosen):
            return None
        return chosen

    def _enforce_deadlines(self) -> None:
        """Retire every live/queued request past its step budget (budgets
        are measured from `arrival` in engine steps — deterministic)."""
        for slot in np.flatnonzero(self.active):
            req = self.slot_req[slot]
            age = self.step_count - req.arrival
            if (req.ttft_deadline is not None and not self.slot_out[slot]
                    and age >= req.ttft_deadline):
                self._retire_slot(slot, "deadline_ttft")
            elif req.total_deadline is not None and age >= req.total_deadline:
                self._retire_slot(slot, "deadline_total")
        if any(r.ttft_deadline is not None or r.total_deadline is not None
               for r in self.queue):
            keep = collections.deque()
            for req in self.queue:
                age = self.step_count - req.arrival
                reason = None
                if req.arrival <= self.step_count:
                    if (req.ttft_deadline is not None
                            and age >= req.ttft_deadline):
                        reason = "deadline_ttft"
                    elif (req.total_deadline is not None
                          and age >= req.total_deadline):
                        reason = "deadline_total"
                if reason:
                    self._finish_unstarted(req, reason)
                else:
                    keep.append(req)
            self.queue = keep

    def _admit_ready(self) -> None:
        for slot in range(self.n_slots):
            if not self.queue:
                return
            if self.active[slot]:
                continue
            idx = self._next_candidate()
            if idx is None:
                return                       # nothing has arrived yet
            req = self.queue[idx]
            if self.paged:
                need = self._reserved_blocks(req)
                if need > self.pool.spec.n_blocks:
                    raise ValueError(
                        f"request {req.rid} needs {need} blocks "
                        f"but the pool holds {self.pool.spec.n_blocks} — "
                        "raise n_blocks or lower max_new_tokens")
                # the prefix plan is committed here: preempting victims may
                # surface new cached blocks, but re-matching after eviction
                # could pin more residents than the feasibility check saw
                plan = self._prefix_plan(req)
                keys, hits, extra_cow, resume = plan
                fresh = need - len(hits) + extra_cow
                if not self.pool.can_admit(fresh, hits):
                    victims = self._plan_preemption(req, fresh, hits)
                    if victims is None:
                        return               # out of blocks: backpressure
                    del self.queue[idx]
                    # evicted requests go back to the queue front (oldest
                    # first among themselves); aging already bumped their
                    # effective priority for the next admission pass
                    for s in victims:
                        self.queue.appendleft(self._preempt_slot(s))
                    self._admit_paged(slot, req, plan)
                    continue
                del self.queue[idx]
                self._admit_paged(slot, req, plan)
            else:
                del self.queue[idx]
                self._admit(slot, req)

    def _dev_cached(self, name: str, arr: np.ndarray):
        """Device copy of a small per-step host array, reused while the host
        bytes are unchanged. In the pure-decode steady state ``q_len`` (all
        ones) and ``emit`` (all True) repeat every step, and host->device
        uploads of even tiny arrays are a measurable slice of a small-model
        step — nothing donates its inputs, so reuse is safe."""
        key = (arr.shape, arr.tobytes())
        hit = self._dev_cache.get(name)
        if hit is not None and hit[0] == key:
            return hit[1]
        dev = jax.device_put(arr)
        self._dev_cache[name] = (key, dev)
        return dev

    def _paged_step(self) -> None:
        """One mixed prefill+decode chunk step over all slots."""
        live = np.flatnonzero(self.active)
        prefilling = [s for s in live if self.slot_prefill_off[s] is not None]
        # step width: the widest remaining chunk this step actually needs
        # (bounded by prefill_chunk, so at most prefill_chunk distinct
        # compiled widths) — decode rows in a mixed step pay for the width,
        # so never pad the step beyond the largest live chunk
        t = max((min(self.prefill_chunk,
                     self._start_len(self.slot_req[s])
                     - self.slot_prefill_off[s]) for s in prefilling),
                default=1)
        b = self.n_slots
        q_len = np.zeros(b, np.int32)
        emit = np.zeros(b, bool)
        tokens = np.zeros((b, t), np.int32)
        # VLM embeds ride the step only while some chunk actually covers
        # patch positions — pure-decode steps skip the patch_proj GEMM the
        # embed-select path would otherwise pay every token
        vlm = self.cfg.family == "vlm" and any(
            self.slot_prefill_off[s] is not None
            and self.slot_req[s].input_embeds is not None
            and self.slot_prefill_off[s] < self.slot_req[s].input_embeds.shape[0]
            for s in live)
        embeds = np.zeros((b, t, self.cfg.d_model), np.float32) if vlm else None
        emask = np.zeros((b, t), bool) if vlm else None
        clens = {}
        tables_dirty = self._tables_dev is None
        for s in live:
            req = self.slot_req[s]
            off = self.slot_prefill_off[s]
            if off is not None:              # prompt chunk
                start = self._start_len(req)
                clen = min(t, start - off)
                clens[s] = clen
                q_len[s] = clen
                emit[s] = off + clen == start
                s_img = (req.input_embeds.shape[0]
                         if req.input_embeds is not None else 0)
                for j in range(clen):
                    pos = off + j
                    if pos < s_img:
                        embeds[s, j] = req.input_embeds[pos]
                        emask[s, j] = True
                    else:
                        tokens[s, j] = req.prompt[pos - s_img]
                tables_dirty |= self.pool.ensure(s, off + clen)
            else:                            # decode row
                q_len[s] = 1
                emit[s] = True
                tokens[s, 0] = self.slot_out[s][-1]
                tables_dirty |= self.pool.ensure(s, int(self.slot_pos[s]) + 1)
        self._apply_cow()
        if tables_dirty:
            self._tables_dev = jnp.asarray(self.pool.tables)
        self.cache = dict(self.cache, block_tables=self._tables_dev)
        if prefilling or vlm:
            tok_dev = jax.device_put(tokens)
        else:
            # pure-decode step: every live row's token is the one the device
            # sampled last step (``state["last_tok"]`` — the host mirrors it
            # into slot_out before building ``tokens``), and q_len == 0 rows
            # only ever write to the dump block, so the device copy already
            # holds this step's tokens — skip the upload
            tok_dev = self.state["last_tok"]
        args = [tok_dev, self.cache, self.state,
                self._dev_cached("q_len", q_len),
                self._dev_cached("emit", emit)]
        if vlm:
            args += [jnp.asarray(embeds), jnp.asarray(emask)]
        # dispatch with recovery: params are read at call time (a retry after
        # restore-from-pristine must not replay the poisoned leaves) and
        # nothing below mutates scheduler state, so a retried or quarantined
        # step cannot double-commit (pool.ensure above is idempotent)
        dispatched = self._dispatch(lambda: self._chunk(self.params, *args))
        if dispatched is None:               # quarantined: step consumed
            self.step_count += 1
            return
        tok, self.cache, self.state = dispatched
        tok_np = np.asarray(tok)             # the one per-step device sync
        self.host_syncs += 1
        if self._guard:
            self._cache_fp = abft.tree_fingerprint(self._scrub_view())
        self.step_count += 1
        if len(prefilling) < len(live):
            self.decode_steps += 1
        self.occ["slot_steps"] += b
        self.occ["slot_active_steps"] += len(live)
        self.occ["block_steps"] += self.pool.spec.n_blocks
        self.occ["block_alloc_steps"] += self.pool.allocated_blocks
        for s in live:
            if s in clens:
                clen = clens[s]
                self.slot_prefill_off[s] += clen
                self.slot_pos[s] += clen
                self.occ["prefill_tokens"] += clen
                if self.slot_prefill_off[s] == self._start_len(self.slot_req[s]):
                    self.slot_prefill_off[s] = None
                    if self.slot_cacheable[s]:
                        # prompt fully resident: publish its full blocks so
                        # concurrent same-prefix admissions share them now,
                        # not only after this request retires
                        self.pool.publish(s, self.slot_chain[s])
            else:
                self.slot_pos[s] += 1
                self.occ["decode_tokens"] += 1
            if emit[s]:
                self.slot_out[s].append(int(tok_np[s]))
                self._maybe_retire(s)

    def _apply_cow(self) -> None:
        """Apply pending copy-on-write block clones on device (one fused
        call over every pool leaf), then refresh the scrub fingerprint —
        the clone is a legitimate cache rewrite, exactly like admit's slot
        wipe, and must not read as corruption. Because the pool is
        physically shared, the fingerprint covers each block once however
        many tables map it."""
        copies = self.pool.drain_copies()
        if not copies:
            return
        src = jnp.asarray([c[0] for c in copies], jnp.int32)
        dst = jnp.asarray([c[1] for c in copies], jnp.int32)
        self.cache = self._copy_blocks(self.cache, src, dst)
        if self._guard:
            self._cache_fp = abft.tree_fingerprint(self._scrub_view())

    def _multi_horizon(self) -> None:
        """One fused ``multi_step``-sub-step decode horizon (single dispatch).

        The device runs ``n`` chained decode sub-steps under ``lax.scan``
        (`steps.make_multi_step`): sampling streams fold per-token inside the
        scan, and the device-resident retirement mask (EOS / budget) freezes
        a slot that finishes mid-horizon so its cache and position stop
        advancing with no host involvement. The host syncs exactly one
        ``(n, B)`` token block per horizon, then replays its per-sub-step
        bookkeeping from it — ``-1`` marks sub-steps on which a slot emitted
        nothing, so trim-past-EOS holds by construction. Admission,
        deadlines, and retirement run at horizon boundaries only
        (``step_count`` advances by ``n``; see docs/serving.md for the
        retirement-lag semantics).
        """
        n = self.multi_step
        live = np.flatnonzero(self.active)
        if self.paged:
            # horizon-aware alloc-on-write: cover the worst case (all n
            # sub-steps live) up front; ensure_horizon clamps to the
            # admit-time reservation, which the device-side budget mask
            # provably never writes past
            tables_dirty = self._tables_dev is None
            for s in live:
                tables_dirty |= self.pool.ensure_horizon(
                    s, int(self.slot_pos[s]) + n)
            self._apply_cow()
            if tables_dirty:
                self._tables_dev = jnp.asarray(self.pool.tables)
            self.cache = dict(self.cache, block_tables=self._tables_dev)
        # recovery composes unchanged: cache/state are only assigned on
        # success, so a retry replays the whole horizon from the pre-horizon
        # snapshot and the replay is bit-identical
        dispatched = self._dispatch(
            lambda: self._multi(self.params, self.cache, self.state))
        if dispatched is None:               # quarantined: horizon consumed
            self.step_count += n
            return
        toks, self.cache, self.state = dispatched
        tok_np = np.asarray(toks)            # the one per-*horizon* sync
        self.host_syncs += 1
        if self._guard:
            self._cache_fp = abft.tree_fingerprint(self._scrub_view())
        self.step_count += n
        b = self.n_slots
        for j in range(n):
            live_j = int((tok_np[j] >= 0).sum())
            if live_j:
                self.decode_steps += 1
            if self.paged:
                self.occ["slot_steps"] += b
                self.occ["slot_active_steps"] += live_j
                self.occ["block_steps"] += self.pool.spec.n_blocks
                self.occ["block_alloc_steps"] += self.pool.allocated_blocks
                self.occ["decode_tokens"] += live_j
        for s in live:
            emitted = tok_np[:, s]
            emitted = emitted[emitted >= 0]
            self.slot_out[s].extend(int(t) for t in emitted)
            if self.paged:
                self.slot_pos[s] += len(emitted)
            self._maybe_retire(s)

    # --- fault detection & recovery (policy.guard != "none") ----------------

    def _scrub_view(self):
        """The cache leaves the integrity scrub covers. `block_tables` is
        host-authoritative (re-pushed every step) and excluded."""
        if isinstance(self.cache, dict):
            return {k: v for k, v in self.cache.items()
                    if k != "block_tables"}
        return self.cache

    def _scrub(self) -> None:
        """Bit-level integrity sweep before a step: bound params against the
        init-time fingerprints, KV cache against the post-commit
        fingerprints, device tables against host golden rebuilds. Raises
        ``AbftFaultError`` naming the corrupted leaves."""
        bad = [("params", p) for p in
               abft.verify_fingerprint(self.params, self._params_fp)]
        if self._cache_fp is not None:
            bad += [("cache", p) for p in
                    abft.verify_fingerprint(self._scrub_view(),
                                            self._cache_fp)]
        if bad:
            raise abft.AbftFaultError(
                [abft.Fault(f"{dom}:{path}", "memory", 1.0, 0.0)
                 for dom, path in bad])
        backends = ({self.policy.backend}
                    | set((self.policy.overrides or {}).values()))
        for be in sorted(backends):
            abft.verify_tables(self.policy, be, layer="<serve>")

    def _restore_known_good(self, kinds) -> None:
        """Swap the (possibly poisoned) params back to the pristine init
        reference; a table fault additionally clears the device table caches
        so the next trace re-uploads from the host golden copies."""
        self.params = self._pristine_params
        self._params_fp = abft.tree_fingerprint(self.params)
        if "table" in kinds:
            from repro.core import emulate, error_delta
            for fn in (emulate.product_table_jnp,
                       error_delta.factor_tables_jnp):
                # an active fault-injection patch is a plain function
                if hasattr(fn, "cache_clear"):
                    fn.cache_clear()

    def _quarantine(self) -> None:
        """KV corruption recovery: requeue every active request (replay from
        the prompt is bit-identical, so the corruption never reaches a
        stream) and rebuild the block pool and paged cache from scratch."""
        self.events["quarantines"] += 1
        # invalidate the prefix index FIRST and mark every live slot
        # non-cacheable: the preemption releases below must not (re)index
        # blocks whose contents are suspect — a corrupted shared block
        # served to a later same-prefix request would defeat the whole
        # quarantine. Requeued victims re-prefill cold against the fresh
        # pool's empty index.
        self.pool.invalidate()
        self.prefix_events["prefix_invalidations"] += 1
        order = sorted(np.flatnonzero(self.active),
                       key=lambda s: (-int(self.slot_admitted[s]), -s))
        for s in order:
            self.slot_cacheable[s] = False
            self.queue.appendleft(self._preempt_slot(s))
        spec = self.pool.spec
        self.pool = paged_mod.BlockPool(spec, self.n_slots, self.max_len)
        self.cache = self.model.init_paged_cache(
            self.n_slots, self.max_len, spec.n_blocks, spec.block_size)
        self._tables_dev = None
        self._cache_fp = abft.tree_fingerprint(self._scrub_view())

    def _backoff_wait(self, attempts: int) -> None:
        """Retry backoff as a monotonic-deadline wait.

        The old implementation blocked in one uncapped ``time.sleep`` — a
        high attempt count (or a large ``retry_backoff_s``) could stall the
        scheduler far past the step budget. The wait is now capped by
        ``retry_backoff_cap_s``, sleeps in short slices against a
        ``time.monotonic`` deadline (immune to wall-clock jumps), and the
        time actually spent is surfaced in ``stats["backoff_s_total"]``.
        """
        if not self.retry_backoff_s:
            return
        want = self.retry_backoff_s * attempts
        if self.retry_backoff_cap_s is not None:
            want = min(want, self.retry_backoff_cap_s)
        t0 = time.monotonic()
        deadline = t0 + want
        remaining = want
        while remaining > 0:
            time.sleep(min(remaining, 0.02))
            remaining = deadline - time.monotonic()
        self.backoff_s_total += time.monotonic() - t0

    def _dispatch(self, step_fn):
        """Run one jitted step under the recovery protocol.

        * ``TransientError`` (preemption notice, flaky interconnect — or the
          fault injector) -> bounded retry with linear backoff.
        * ABFT fault in **params / weights / tables** -> restore from the
          pristine snapshot and re-dispatch (bounded by `max_step_retries`).
        * ABFT fault in the **KV cache** -> quarantine: requeue all active
          requests, rebuild pool + cache; returns None (step consumed).
        * The contiguous engine fails fast on any ABFT fault (its fused
          admit emits tokens inside jit, so there is no safe replay point).

        Exhausted retries re-raise to the caller.
        """
        attempts = 0
        while True:
            try:
                if self._guard:
                    self._scrub()
                out = step_fn()
                if self._guard:
                    jax.block_until_ready(out)
                    faults = abft.drain_faults()
                    if faults:
                        raise abft.AbftFaultError(faults)
                return out
            except TransientError:
                attempts += 1
                self.events["step_retries"] += 1
                if attempts > self.max_step_retries:
                    raise
                self._backoff_wait(attempts)
            except abft.AbftFaultError as e:
                self.events["faults_detected"] += len(e.faults)
                if not self.paged:
                    raise                    # contiguous: fail fast
                if any(f.kind == "memory" and f.layer.startswith("cache:")
                       for f in e.faults):
                    self._quarantine()
                    return None
                attempts += 1
                self.events["step_retries"] += 1
                if attempts > self.max_step_retries:
                    raise
                self._restore_known_good({f.kind for f in e.faults})
                self._backoff_wait(attempts)

    def step(self) -> None:
        """Enforce deadlines, admit what fits, run one batched ragged step."""
        # cache scrub FIRST: admission legitimately rewrites a slot's cache
        # and refreshes the fingerprint, so corruption struck between steps
        # must be caught before any admit can launder it into the baseline
        if self._guard and self._cache_fp is not None:
            bad = abft.verify_fingerprint(self._scrub_view(), self._cache_fp)
            if bad:
                self.events["faults_detected"] += len(bad)
                if not self.paged:           # contiguous: fail fast
                    raise abft.AbftFaultError(
                        [abft.Fault(f"cache:{p}", "memory", 1.0, 0.0)
                         for p in bad])
                self._quarantine()
                self.step_count += 1         # step consumed by recovery
                return
        self._enforce_deadlines()
        self._admit_ready()
        self.peak_active = max(self.peak_active, int(self.active.sum()))
        if not self.active.any():
            self.step_count += 1             # idle tick (waiting on arrivals)
            return
        if self.paged:
            # fused horizons only apply while every live slot is decoding:
            # a prefilling slot needs per-chunk host orchestration, and
            # falling back to the per-step path keeps streams bit-identical
            # (token values are batch-composition independent)
            if self.multi_step > 1 and all(
                    self.slot_prefill_off[s] is None
                    for s in np.flatnonzero(self.active)):
                self._multi_horizon()
            else:
                self._paged_step()
            return
        if self.multi_step > 1:
            self._multi_horizon()
            return
        next_tok, cache, state = self._dispatch(
            lambda: self._decode(self.params, self.cache, self.state))
        self.cache, self.state = cache, state
        next_np = np.asarray(next_tok)       # the one per-step device sync
        self.host_syncs += 1
        if self._guard:
            self._cache_fp = abft.tree_fingerprint(self._scrub_view())
        self.step_count += 1
        self.decode_steps += 1
        for slot in np.flatnonzero(self.active):
            self.slot_out[slot].append(int(next_np[slot]))
            self._maybe_retire(slot)

    def run(self, requests: Sequence[Request] = (),
            max_steps: Optional[int] = None) -> Dict[int, FinishedRequest]:
        """Drive the engine until every submitted request has finished."""
        for req in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            self.submit(req)
        limit = max_steps if max_steps is not None else 10 ** 9
        while (self.queue or self.active.any()) and self.step_count < limit:
            self.step()
        return dict(self.finished)

    @property
    def stats(self) -> Dict[str, Any]:
        gen = sum(len(f.tokens) for f in self.finished.values())
        out: Dict[str, Any] = {
            "steps": self.step_count, "decode_steps": self.decode_steps,
            "generated_tokens": gen, "finished": len(self.finished),
            "peak_active_slots": self.peak_active,
            # host-overhead telemetry: device->host token syncs (admit +
            # per-step / per-horizon blocks) and measured retry backoff
            "multi_step": self.multi_step,
            "host_syncs": self.host_syncs,
            "syncs_per_token": round(self.host_syncs / max(1, gen), 4),
            "backoff_s_total": round(self.backoff_s_total, 6)}
        out.update(self.events)              # reliability counters
        if self.paged:
            occ = self.occ
            out.update({
                # occupancy: fraction of slot-steps / pool-block-steps that
                # held live work, plus the prefill-vs-decode token split
                "slot_utilization": round(occ["slot_active_steps"]
                                          / max(1, occ["slot_steps"]), 3),
                "block_utilization": round(occ["block_alloc_steps"]
                                           / max(1, occ["block_steps"]), 3),
                "peak_allocated_blocks": self.pool.peak_allocated,
                "prefill_tokens": occ["prefill_tokens"],
                "decode_tokens": occ["decode_tokens"],
                # prefix-cache counters: engine-side hit accounting plus the
                # pool's sharing/COW/eviction totals (pool counters reset on
                # a quarantine rebuild; hit counters are cumulative)
                "prefix_cache": self.prefix_cache,
                "prefix_shared_blocks": self.pool.shared_attached,
                "prefix_cow_copies": self.pool.cow_copies,
                "prefix_evicted_blocks": self.pool.evicted_blocks,
                "prefix_cached_blocks": self.pool.cached_blocks,
            })
            out.update(self.prefix_events)
        return out


def make_poisson_trace(n_requests: int, *, rate: float, vocab_size: int,
                       prompt_lens: Sequence[int] = (8, 12, 16),
                       gen_lens: Sequence[int] = (4, 8, 12, 16, 24),
                       seed: int = 0,
                       params: sampling.SamplingParams = sampling.GREEDY
                       ) -> List[Request]:
    """Synthetic ragged request trace with Poisson arrivals.

    Inter-arrival gaps are exponential with mean `1/rate` (in engine decode
    steps); prompt and generation lengths are drawn uniformly from the given
    pools — the raggedness a padded lockstep loop pays for and continuous
    batching absorbs.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for rid in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.choice(prompt_lens))
        out.append(Request(
            rid=rid,
            prompt=rng.integers(0, vocab_size, plen).astype(np.int32),
            max_new_tokens=int(rng.choice(gen_lens)),
            params=params,
            arrival=int(t)))
    return out


def elapsed(fn):
    """(result, seconds) of `fn()` — tiny helper for bench instrumentation."""
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0
