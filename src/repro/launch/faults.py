"""Deterministic, scoped fault injection for the serving stack.

The robustness layer (core/abft.py + the hardened engine lifecycle) claims
that any single bit flip in the state the GEMM path consumes — bound weight
leaves, paged KV pool blocks, device-resident product/factor tables — is
detected under ``GemmPolicy.guard`` and recovered from without corrupting a
served stream. This module provides the attacker: a seeded injector whose
every fault is a pure function of ``(seed, call order)``, so an injected
campaign reproduces **bit-for-bit** across runs and a failure found in CI
replays locally from its seed alone.

Targets:

* :meth:`FaultInjector.flip_params` — one bit of one element of one leaf of
  a (bound or raw) parameter pytree. JAX arrays are immutable, so the flip
  *replaces* the leaf: the caller's original pytree reference stays clean,
  which is exactly the property the engine's restore-from-pristine recovery
  relies on.
* :meth:`FaultInjector.flip_cache` — one bit in a paged KV pool leaf (or any
  cache pytree), same replace semantics.
* :meth:`FaultInjector.poisoned_tables` — context manager that monkeypatches
  the device-table constructors (``emulate.product_table_jnp`` — including
  the by-name import in ``core.lut`` — and ``error_delta.factor_tables_jnp``)
  to return a copy with one bit flipped, modelling corrupted on-chip table
  SRAM. Scoped: the originals are always restored on exit.
* :meth:`FaultInjector.failing_steps` — context manager that makes an
  engine's jitted step raise ``train.fault.TransientError`` at chosen step
  counts, exercising the bounded retry-with-backoff path.

Every injection appends a :class:`FaultRecord` to ``injector.records`` — the
campaign log a test asserts detection against.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.fault import TransientError

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FaultRecord:
    """One injected fault, fully reproducible from its fields."""
    target: str                  # "params" | "cache" | "table" | "step"
    path: str                    # pytree keystr / patched function name
    index: int                   # flat element index within the leaf
    bit: int                     # flipped bit position within the element
    note: str = ""

    def __str__(self) -> str:
        return (f"<fault {self.target} {self.path or '(root)'}"
                f"[{self.index}] bit {self.bit}{' ' + self.note if self.note else ''}>")


def _bit_width(dtype) -> int:
    return np.dtype(dtype).itemsize * 8 if np.dtype(dtype) != np.bool_ else 1


def flip_bit(leaf, index: int, bit: int):
    """Return a copy of ``leaf`` with one bit of one element flipped.

    Works for any fixed-width dtype (floats through their bit patterns,
    bf16/f16 through uint16 views, bools by negation). The input is never
    mutated — JAX arrays are immutable and the host copy is fresh.
    """
    x = np.array(np.asarray(leaf))           # host copy, owns its memory
    flat = x.reshape(-1)
    index %= max(1, flat.size)
    dt = flat.dtype
    if dt == np.bool_:
        flat[index] = not flat[index]
    else:
        view = flat.view(np.dtype(f"u{dt.itemsize}"))
        view[index] ^= np.dtype(f"u{dt.itemsize}").type(1) << (
            bit % _bit_width(dt))
    return jnp.asarray(x, dtype=jnp.asarray(leaf).dtype)


def _array_leaves(tree) -> List[Tuple[str, Any]]:
    """(keystr, leaf) for every fixed-width array leaf, in path-sorted order
    (the deterministic target universe — tree_flatten order is already
    deterministic, sorting makes it robust to registration changes too)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        if hasattr(leaf, "dtype") and hasattr(leaf, "ndim") and np.asarray(
                leaf).size:
            out.append((jax.tree_util.keystr(path), leaf))
    out.sort(key=lambda kv: kv[0])
    return out


class FaultInjector:
    """Seeded bit-flip / step-failure injector (see module docstring).

    Each injection draws from one ``numpy`` Generator seeded at construction,
    so a campaign is a deterministic function of ``(seed, sequence of calls)``.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.records: List[FaultRecord] = []

    # -- pytree targets ------------------------------------------------------

    def _pick(self, leaves, path: Optional[str]):
        if path is not None:
            match = [kv for kv in leaves if path in kv[0]]
            if not match:
                raise ValueError(f"no array leaf matching {path!r}")
            leaves = match
        key, leaf = leaves[self.rng.integers(len(leaves))]
        arr = np.asarray(leaf)
        index = int(self.rng.integers(arr.size))
        bit = int(self.rng.integers(_bit_width(arr.dtype)))
        return key, leaf, index, bit

    def _flip_tree(self, tree, target: str, path: Optional[str],
                   note: str) -> Tuple[PyTree, FaultRecord]:
        leaves = _array_leaves(tree)
        key, _, index, bit = self._pick(leaves, path)
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        new_leaves = [flip_bit(leaf, index, bit)
                      if jax.tree_util.keystr(p) == key else leaf
                      for p, leaf in flat]
        rec = FaultRecord(target, key, index, bit, note)
        self.records.append(rec)
        return jax.tree_util.tree_unflatten(treedef, new_leaves), rec

    def flip_params(self, params: PyTree, *, path: Optional[str] = None
                    ) -> Tuple[PyTree, FaultRecord]:
        """Flip one bit in one (optionally path-filtered) parameter leaf.

        Returns the poisoned pytree; the input pytree is untouched.
        """
        return self._flip_tree(params, "params", path, "")

    def flip_cache(self, cache: PyTree, *, path: Optional[str] = None
                   ) -> Tuple[PyTree, FaultRecord]:
        """Flip one bit in a KV-cache leaf (paged pool block or contiguous
        region). ``block_tables`` is host-authoritative and excluded."""
        view = ({k: v for k, v in cache.items() if k != "block_tables"}
                if isinstance(cache, dict) else cache)
        poisoned, rec = self._flip_tree(view, "cache", path, "")
        if isinstance(cache, dict) and "block_tables" in cache:
            poisoned = dict(poisoned, block_tables=cache["block_tables"])
        return poisoned, rec

    def flip_cache_block(self, cache: PyTree, block: int, *,
                         path: Optional[str] = None
                         ) -> Tuple[PyTree, FaultRecord]:
        """Flip one bit inside pool row ``block`` of one paged pool leaf —
        the targeted form of :meth:`flip_cache` for attacking a *shared*
        prefix block (pool leaves are ``(L, n_blocks + 1, block_size, ...)``
        with the block axis at 1). The quarantine contract this arms the
        test for: a corrupted block that several slot tables map must be
        detected once and never re-served through the prefix index."""
        from repro.models.api import PAGED_POOL_LEAVES
        view = ({k: v for k, v in cache.items() if k in PAGED_POOL_LEAVES}
                if isinstance(cache, dict) else cache)
        leaves = _array_leaves(view)
        if path is not None:
            leaves = [kv for kv in leaves if path in kv[0]]
            if not leaves:
                raise ValueError(f"no pool leaf matching {path!r}")
        key, leaf = leaves[self.rng.integers(len(leaves))]
        arr = np.asarray(leaf)
        layer = int(self.rng.integers(arr.shape[0]))
        inner = int(np.prod(arr.shape[2:]))
        off = int(self.rng.integers(inner))
        index = (layer * arr.shape[1] + int(block)) * inner + off
        bit = int(self.rng.integers(_bit_width(arr.dtype)))
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
        new_leaves = [flip_bit(lf, index, bit)
                      if jax.tree_util.keystr(p) == key else lf
                      for p, lf in flat]
        rec = FaultRecord("cache", key, index, bit, f"block={int(block)}")
        self.records.append(rec)
        return jax.tree_util.tree_unflatten(treedef, new_leaves), rec

    def strike_engine(self, engine, *, target: str = "params",
                      path: Optional[str] = None) -> FaultRecord:
        """Inject into a live ``ServeEngine`` between steps: replaces
        ``engine.params`` or ``engine.cache`` with a poisoned copy (the
        engine's own pristine snapshot is untouched — JAX immutability)."""
        if target == "params":
            engine.params, rec = self.flip_params(engine.params, path=path)
        elif target == "cache":
            engine.cache, rec = self.flip_cache(engine.cache, path=path)
        else:
            raise ValueError(f"unknown engine target {target!r}")
        return rec

    # -- device-table targets ------------------------------------------------

    @contextlib.contextmanager
    def poisoned_tables(self, *, which: str = "product") -> Iterator[FaultRecord]:
        """Scope in which the device table constructors return a copy with
        one bit flipped — corrupted table SRAM. ``which`` is ``"product"``
        (``product_table_jnp``, consumed by approx_lut/approx_onehot and the
        lut module's by-name import) or ``"factors"``
        (``error_delta.factor_tables_jnp``, consumed by approx_delta).

        Note: jitted programs bake these tables in as compile-time constants,
        so poisoning is visible to *newly traced* or eager calls — the model
        for faults present at upload time, which is when ABFT's golden-copy
        comparison (``core.abft.verify_tables``) runs.
        """
        from repro.core import emulate, error_delta, lut
        index = int(self.rng.integers(1 << 16))
        bit = int(self.rng.integers(32))
        if which == "product":
            real = emulate.product_table_jnp

            def poisoned(*a, **k):
                return flip_bit(real(*a, **k), index, bit)

            rec = FaultRecord("table", "emulate.product_table_jnp", index,
                              bit, which)
            self.records.append(rec)
            emulate.product_table_jnp = poisoned
            lut.product_table_jnp = poisoned
            try:
                yield rec
            finally:
                emulate.product_table_jnp = real
                lut.product_table_jnp = real
        elif which == "factors":
            real = error_delta.factor_tables_jnp

            def poisoned(*a, **k):
                f, g = real(*a, **k)
                return flip_bit(f, index, bit), g

            rec = FaultRecord("table", "error_delta.factor_tables_jnp",
                              index, bit, which)
            self.records.append(rec)
            error_delta.factor_tables_jnp = poisoned
            try:
                yield rec
            finally:
                error_delta.factor_tables_jnp = real
        else:
            raise ValueError(f"unknown table {which!r}")

    # -- step-level failures -------------------------------------------------

    @contextlib.contextmanager
    def failing_steps(self, engine, fail_at: Sequence[int],
                      times: int = 1) -> Iterator[FaultRecord]:
        """Scope in which the engine's jitted step raises ``TransientError``
        the first ``times`` times it runs at each step count in ``fail_at``
        — a preemption notice / flaky-interconnect stand-in the engine's
        bounded retry must absorb. Deterministic: failures depend only on
        ``engine.step_count``."""
        fail_at = set(int(s) for s in fail_at)
        budget = {s: times for s in fail_at}
        attr = "_chunk" if engine.paged else "_decode"
        real = getattr(engine, attr)

        def flaky(*args, **kwargs):
            if budget.get(engine.step_count, 0) > 0:
                budget[engine.step_count] -= 1
                raise TransientError(
                    f"injected step failure at step {engine.step_count}")
            return real(*args, **kwargs)

        rec = FaultRecord("step", attr, 0, 0,
                          f"fail_at={sorted(fail_at)} x{times}")
        self.records.append(rec)
        setattr(engine, attr, flaky)
        try:
            yield rec
        finally:
            setattr(engine, attr, real)
