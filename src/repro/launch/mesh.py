"""Production meshes. TPU v5e numbers: 256 chips/pod (16x16), 2 pods = 512.

`make_production_mesh` is a function (not a module constant) so importing this
module never touches jax device state; `dryrun.py` sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)

# hardware constants (TPU v5e) for the roofline
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False,
                         shape=None) -> jax.sharding.Mesh:
    """`shape` overrides the (data, model) — or (pod, data, model) — split;
    total chips stay 256/pod. The TP-vs-FSDP balance is a first-class perf knob
    (see EXPERIMENTS.md §Perf)."""
    shape = shape or (MULTI_POD if multi_pod else SINGLE_POD)
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices (set XLA_FLAGS=--xla_force_host_platform_device_count=512), "
            f"have {len(devices)}")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(n_data: int = 1, n_model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over available devices for CPU tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         devices=jax.devices()[: n_data * n_model])
