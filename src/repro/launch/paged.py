"""Paged KV-cache block pool: free-list allocator + per-slot block tables.

The contiguous engine reserves a full ``max_len`` KV region per slot, so HBM
— not compute — caps concurrency. The paged cache splits KV storage into
fixed-size **blocks** shared by all slots: each full-attention cache leaf is a
device-resident pool ``(L, n_blocks + 1, block_size, KH, hd)`` and each slot
owns a **block table** row ``(max_blocks,)`` mapping its logical token
positions to pool blocks (`pos // block_size -> block id`,
`pos % block_size` -> offset within the block). Attention reads gather
through the table (`models.layers.chunked_attention`), writes scatter to
``(block, offset)`` pairs; the table itself is host-authoritative and pushed
into the jit'd step as a small ``(B, max_blocks)`` int32 input.

Allocation protocol (all host-side, O(1) per event):

* **reserve-on-admit** — admission reserves the request's worst-case block
  footprint ``ceil((prompt_len + token_budget - 1) / block_size)``; a request
  is only admitted while ``sum(reserved) <= n_blocks``, so a later
  alloc-on-write can never fail mid-stream (out-of-blocks pressure lands on
  the admission queue, never on a live request).
* **alloc-on-write** — blocks are physically taken from the free list only
  when a chunk/decode write first touches them, so pool-utilization metrics
  reflect tokens actually held, not reservations.
* **free-on-retire** — retirement returns every block the slot owned and
  clears its table row back to the dump block.

Block index ``n_blocks`` (the last pool row) is the **dump block**: masked
writes — padded chunk tokens, inactive slots — are redirected there so they
can never corrupt another slot's blocks. No live table row ever maps to it
for a valid position, and reads mask anything past ``kv_valid_len``.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Pool geometry: ``n_blocks`` usable blocks of ``block_size`` tokens."""
    n_blocks: int
    block_size: int

    @property
    def dump(self) -> int:
        """Pool index of the scratch block masked writes are redirected to."""
        return self.n_blocks

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.block_size)


class BlockPool:
    """Host-side free-list allocator over a paged KV pool (see module docs)."""

    def __init__(self, spec: PagedSpec, n_slots: int, max_len: int):
        if spec.block_size < 1 or spec.n_blocks < 1:
            raise ValueError(f"bad paged spec {spec}")
        self.spec = spec
        self.n_slots = n_slots
        self.max_blocks = spec.blocks_for(max_len)
        # LIFO free list: retired blocks are reused first (cache-friendly)
        self._free: List[int] = list(range(spec.n_blocks - 1, -1, -1))
        self.tables = np.full((n_slots, self.max_blocks), spec.dump, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        self._reserved = np.zeros(n_slots, np.int64)
        self.peak_allocated = 0

    # --- accounting ---------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def allocated_blocks(self) -> int:
        return self.spec.n_blocks - len(self._free)

    @property
    def reserved_blocks(self) -> int:
        return int(self._reserved.sum())

    def can_reserve(self, n_blocks: int) -> bool:
        """Would a request needing ``n_blocks`` fit without overcommitting?"""
        return self.reserved_blocks + n_blocks <= self.spec.n_blocks

    # --- lifecycle ----------------------------------------------------------

    def reserve(self, slot: int, n_blocks: int) -> None:
        if self._reserved[slot] or self._owned[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        if n_blocks > self.max_blocks:
            raise ValueError(f"request needs {n_blocks} blocks but a slot "
                             f"table holds only {self.max_blocks}")
        if not self.can_reserve(n_blocks):
            raise RuntimeError(
                f"out of blocks: need {n_blocks}, "
                f"{self.spec.n_blocks - self.reserved_blocks} unreserved — "
                "admission should have backpressured")
        self._reserved[slot] = n_blocks

    def ensure(self, slot: int, upto_tokens: int) -> bool:
        """Alloc-on-write: own every block covering positions < upto_tokens.

        Returns True when the slot's table row changed (new blocks mapped).
        """
        need = self.spec.blocks_for(upto_tokens)
        if need <= len(self._owned[slot]):
            return False
        if need > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot} writing past its reservation "
                f"({need} > {self._reserved[slot]} blocks)")
        while len(self._owned[slot]) < need:
            blk = self._free.pop()
            self.tables[slot, len(self._owned[slot])] = blk
            self._owned[slot].append(blk)
        self.peak_allocated = max(self.peak_allocated, self.allocated_blocks)
        return True

    def ensure_horizon(self, slot: int, upto_tokens: int) -> bool:
        """Horizon-aware alloc-on-write: like :meth:`ensure`, but clamps the
        target to the slot's admit-time reservation.

        A multi-step horizon conservatively asks for coverage of ``pos + n``
        tokens before dispatch; near the end of a request that overshoots
        the reservation (the final token's KV is never written, and the
        device-side retirement mask stops all writes at the budget), so the
        overshoot is provably never touched and clamping is safe. The
        reserve-on-admit invariant — a live request can never fail
        alloc-on-write — carries over unchanged.
        """
        cap = int(self._reserved[slot]) * self.spec.block_size
        return self.ensure(slot, min(int(upto_tokens), cap))

    def release(self, slot: int) -> None:
        """Free-on-retire: return the slot's blocks, clear its table row."""
        self._free.extend(reversed(self._owned[slot]))
        self._owned[slot] = []
        self._reserved[slot] = 0
        self.tables[slot, :] = self.spec.dump

    # --- invariants (exercised by the property tests) -----------------------

    def check(self) -> None:
        """No leaks, no aliasing, tables consistent with ownership."""
        owned_all = [b for lst in self._owned for b in lst]
        assert len(owned_all) + len(self._free) == self.spec.n_blocks, \
            "block leak: owned + free != pool"
        assert len(set(owned_all)) == len(owned_all), \
            "block aliased across live slots"
        assert not (set(owned_all) & set(self._free)), \
            "block simultaneously owned and free"
        for slot, lst in enumerate(self._owned):
            assert len(lst) <= self._reserved[slot], \
                f"slot {slot} owns more than it reserved"
            row = self.tables[slot]
            assert list(row[:len(lst)]) == lst, f"slot {slot} table mismatch"
            assert (row[len(lst):] == self.spec.dump).all(), \
                f"slot {slot} table maps unowned positions"


def default_spec(n_slots: int, max_len: int, block_size: int) -> PagedSpec:
    """Pool sized to the contiguous engine's budget: every slot can still hold
    ``max_len`` tokens, so admission never backpressures more than the
    contiguous engine would — capacity wins come from setting ``n_blocks``
    below this (or ``n_slots`` above the contiguous count at equal budget)."""
    return PagedSpec(n_blocks=n_slots * (-(-max_len // block_size)),
                     block_size=block_size)
