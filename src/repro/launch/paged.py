"""Paged KV-cache block pool: free-list allocator + per-slot block tables
with refcounted copy-on-write prefix sharing.

The contiguous engine reserves a full ``max_len`` KV region per slot, so HBM
— not compute — caps concurrency. The paged cache splits KV storage into
fixed-size **blocks** shared by all slots: each full-attention cache leaf is a
device-resident pool ``(L, n_blocks + 1, block_size, KH, hd)`` and each slot
owns a **block table** row ``(max_blocks,)`` mapping its logical token
positions to pool blocks (`pos // block_size -> block id`,
`pos % block_size` -> offset within the block). Attention reads gather
through the table (`models.layers.chunked_attention`), writes scatter to
``(block, offset)`` pairs; the table itself is host-authoritative and pushed
into the jit'd step as a small ``(B, max_blocks)`` int32 input.

Allocation protocol (all host-side, O(1) per event):

* **reserve-on-admit** — admission reserves the request's worst-case block
  footprint ``ceil((prompt_len + token_budget - 1) / block_size)``; a request
  is only admitted while every live slot's remaining *fresh* allocations fit
  in ``free + evictable`` blocks, so a later alloc-on-write can never fail
  mid-stream (out-of-blocks pressure lands on the admission queue, never on
  a live request).
* **alloc-on-write** — blocks are physically taken from the free list only
  when a chunk/decode write first touches them, so pool-utilization metrics
  reflect tokens actually held, not reservations.
* **free-on-retire** — retirement returns every block the slot owned and
  clears its table row back to the dump block.

**Prefix caching** (PR 10) layers content identity on top:

* every block has a **refcount** (how many slot tables map it) and may carry
  a **content key** — link ``i`` of a rolling blake2b chain seeded by a
  digest of ``(model config, GEMM policy)`` and folding in each full block's
  token ids (`chain_keys`). Equal key == bit-identical KV contents, because
  per-request streams are deterministic in exactly those inputs.
* the **prefix index** maps keys to resident blocks. Admission matches the
  new prompt's key chain (`match_prefix`), attaches every leading hit to the
  slot's table (``reserve(hits=...)`` — refcount + 1 per hit) and prefills
  only the uncached tail.
* **copy-on-write** — `ensure`/`ensure_horizon` sweep the new write window
  first: a block another slot still references is cloned into a fresh block
  (the device copy is queued in `drain_copies` for the engine to apply
  before dispatch), a block owned exclusively but still index-mapped is
  detached from the index. An index-mapped block is therefore never written.
* **LRU eviction** — `release` decrements refcounts; an unreferenced block
  with a key parks in an LRU (most recently released last) instead of the
  free list, and is evicted — key dropped, block recycled — only when a
  fresh allocation finds the free list empty. `invalidate` drops the whole
  index at once (cache-fault quarantine: a corrupted shared block must
  never be re-served).

Block index ``n_blocks`` (the last pool row) is the **dump block**: masked
writes — padded chunk tokens, inactive slots — are redirected there so they
can never corrupt another slot's blocks. No live table row ever maps to it
for a valid position, and reads mask anything past ``kv_valid_len``.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Pool geometry: ``n_blocks`` usable blocks of ``block_size`` tokens."""
    n_blocks: int
    block_size: int

    @property
    def dump(self) -> int:
        """Pool index of the scratch block masked writes are redirected to."""
        return self.n_blocks

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.block_size)


def cache_seed(cfg, policy) -> bytes:
    """Chain seed digest: everything a block's KV bits depend on besides the
    token ids. Two pools may share a block only under the same model config
    and GEMM policy — a backend or quantization change must miss."""
    return hashlib.blake2b(repr((cfg, policy)).encode(),
                           digest_size=16).digest()


def chain_keys(seed: bytes, tokens, block_size: int,
               n_blocks: Optional[int] = None) -> Tuple[bytes, ...]:
    """Rolling content keys for the leading full blocks of ``tokens``.

    ``key_i`` digests the seed plus tokens ``[0, (i+1) * block_size)`` — a
    chain, so a block key identifies the whole prefix behind it, not just
    the block's own tokens. Keys exist only for *full* blocks; a partial
    trailing block has no identity and is never shared.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    n = len(toks) // block_size if n_blocks is None else int(n_blocks)
    out: List[bytes] = []
    h = seed
    for i in range(n):
        blk = toks[i * block_size:(i + 1) * block_size]
        h = hashlib.blake2b(h + blk.tobytes(), digest_size=16).digest()
        out.append(h)
    return tuple(out)


class BlockPool:
    """Host-side free-list allocator over a paged KV pool (see module docs)."""

    def __init__(self, spec: PagedSpec, n_slots: int, max_len: int):
        if spec.block_size < 1 or spec.n_blocks < 1:
            raise ValueError(f"bad paged spec {spec}")
        self.spec = spec
        self.n_slots = n_slots
        self.max_blocks = spec.blocks_for(max_len)
        # LIFO free list: retired blocks are reused first (cache-friendly)
        self._free: List[int] = list(range(spec.n_blocks - 1, -1, -1))
        self.tables = np.full((n_slots, self.max_blocks), spec.dump, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        self._reserved = np.zeros(n_slots, np.int64)
        # prefix-cache state: per-block refcount (owner tables mapping it),
        # key index (content key -> block), per-block key, and the LRU of
        # unreferenced-but-cached blocks (insertion order == release recency)
        self._ref = np.zeros(spec.n_blocks, np.int64)
        self._index: Dict[bytes, int] = {}
        self._key_of: Dict[int, bytes] = {}
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        # per-slot admit-time budget of *fresh* (non-shared) allocations and
        # the written-token watermark bounding the next COW sweep
        self._fresh = np.zeros(n_slots, np.int64)
        self._written = np.zeros(n_slots, np.int64)
        self._pending_copies: List[Tuple[int, int]] = []
        self.peak_allocated = 0
        self.cow_copies = 0
        self.evicted_blocks = 0
        self.shared_attached = 0
        self.invalidations = 0

    # --- accounting ---------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        """Blocks assignable to a fresh allocation: truly free + evictable
        cached blocks (an LRU resident costs nothing under pressure)."""
        return len(self._free) + len(self._lru)

    @property
    def allocated_blocks(self) -> int:
        """Distinct blocks held by live slots (a shared block counts once)."""
        return self.spec.n_blocks - self.free_blocks

    @property
    def cached_blocks(self) -> int:
        """Blocks the prefix index maps (pinned or evictable)."""
        return len(self._index)

    @property
    def evictable_blocks(self) -> int:
        return len(self._lru)

    @property
    def reserved_blocks(self) -> int:
        return int(self._reserved.sum())

    def can_reserve(self, n_blocks: int) -> bool:
        """Would a request needing ``n_blocks`` fresh blocks fit?"""
        return self.can_admit(n_blocks)

    def can_admit(self, n_fresh: int, hits: Sequence[int] = (),
                  exclude: Sequence[int] = ()) -> bool:
        """Admission feasibility: after attaching ``hits`` and reserving
        ``n_fresh`` fresh blocks, does every live slot's outstanding fresh
        budget still fit in free + evictable blocks?

        ``exclude`` names slots assumed preempted first (planning only):
        their fresh budgets drop out and any block they alone hold returns
        to the assignable set. This is the invariant that makes
        alloc-on-write infallible for live requests.
        """
        excl = {int(s) for s in exclude}
        owners: collections.Counter = collections.Counter()
        for s in range(self.n_slots):
            if s in excl:
                continue
            owners.update(self._owned[s])
        # blocks only the excluded victims hold come back to the pool...
        gain = len({b for s in excl for b in self._owned[s]
                    if owners[b] == 0})
        # ...while every hit with no surviving owner newly pins one resident
        pins = sum(1 for b in set(hits) if owners[b] == 0)
        outstanding = int(sum(self._fresh[s] for s in range(self.n_slots)
                              if s not in excl))
        avail = len(self._free) + len(self._lru) + gain - pins
        return n_fresh + outstanding <= avail

    def match_prefix(self, keys: Sequence[bytes]) -> List[int]:
        """Resident blocks for the longest leading run of ``keys``."""
        out: List[int] = []
        for key in keys:
            blk = self._index.get(key)
            if blk is None:
                break
            out.append(blk)
        return out

    # --- lifecycle ----------------------------------------------------------

    def reserve(self, slot: int, n_blocks: int, *,
                hits: Sequence[int] = (), extra_cow: int = 0,
                written: int = 0) -> None:
        """Reserve-on-admit; ``hits`` (from `match_prefix`) are attached to
        the slot's table immediately (refcount + 1, un-parked from the LRU).

        ``extra_cow`` widens the fresh budget for admissions that must
        copy-on-write into an attached block (whole-prompt-cached resume);
        ``written`` seeds the watermark at the resume offset so the first
        `ensure` sweeps exactly the recomputed window.
        """
        if self._reserved[slot] or self._owned[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        if n_blocks > self.max_blocks:
            raise ValueError(f"request needs {n_blocks} blocks but a slot "
                             f"table holds only {self.max_blocks}")
        hits = list(hits)
        if len(hits) > n_blocks:
            raise ValueError(f"{len(hits)} prefix hits exceed the "
                             f"{n_blocks}-block reservation")
        fresh = n_blocks - len(hits) + int(extra_cow)
        if not self.can_admit(fresh, hits):
            raise RuntimeError(
                f"out of blocks: need {fresh} fresh + {len(hits)} shared, "
                f"{len(self._free)} free + {len(self._lru)} evictable — "
                "admission should have backpressured")
        for i, blk in enumerate(hits):
            assert blk in self._key_of, "prefix hit lost its content key"
            if self._ref[blk] == 0:
                del self._lru[blk]           # pinned: no longer evictable
            self._ref[blk] += 1
            self.tables[slot, i] = blk
            self._owned[slot].append(blk)
        self._reserved[slot] = n_blocks
        self._fresh[slot] = fresh
        self._written[slot] = int(written)
        self.shared_attached += len(hits)
        self.peak_allocated = max(self.peak_allocated, self.allocated_blocks)

    def _take_block(self, slot: int) -> int:
        """One fresh block: free list first, then evict the LRU head.
        Eviction drops the victim's key — it provably has refcount 0."""
        if self._fresh[slot] <= 0:
            raise RuntimeError(
                f"slot {slot} exceeded its admit-time fresh-block budget")
        self._fresh[slot] -= 1
        if self._free:
            return self._free.pop()
        if self._lru:
            blk, _ = self._lru.popitem(last=False)
            assert self._ref[blk] == 0, "evicting a referenced block"
            del self._index[self._key_of.pop(blk)]
            self.evicted_blocks += 1
            return blk
        raise RuntimeError("out of blocks: no free or evictable block for a "
                           "reserved allocation — accounting is broken")

    def ensure(self, slot: int, upto_tokens: int) -> bool:
        """Alloc-on-write: own every block covering positions < upto_tokens,
        copy-on-write first. Returns True when the table row changed.

        The sweep covers only the *new* write window — positions between the
        slot's written watermark and ``upto_tokens``. A window block some
        other table still maps (refcount > 1) is cloned: a fresh block
        replaces it in this slot's table and the device copy is queued for
        `drain_copies`. A window block this slot holds exclusively but the
        prefix index still maps is detached from the index (the rewrite is
        bit-identical, but index entries must never be written). Blocks
        below the watermark — the shared prefix — are never touched.
        """
        upto = int(upto_tokens)
        need = self.spec.blocks_for(upto)
        if need > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot} writing past its reservation "
                f"({need} > {self._reserved[slot]} blocks)")
        bs = self.spec.block_size
        owned = self._owned[slot]
        w = int(self._written[slot])
        changed = False
        if upto > w:
            for i in range(w // bs, min(need, len(owned))):
                blk = owned[i]
                if self._ref[blk] > 1:       # shared: clone before writing
                    dst = self._take_block(slot)
                    self._pending_copies.append((blk, dst))
                    self._ref[blk] -= 1
                    self._ref[dst] = 1
                    owned[i] = dst
                    self.tables[slot, i] = dst
                    self.cow_copies += 1
                    changed = True
                elif blk in self._key_of:    # exclusive but indexed: detach
                    del self._index[self._key_of.pop(blk)]
            self._written[slot] = upto
        while len(owned) < need:
            blk = self._take_block(slot)
            self._ref[blk] = 1
            self.tables[slot, len(owned)] = blk
            owned.append(blk)
            changed = True
        self.peak_allocated = max(self.peak_allocated, self.allocated_blocks)
        return changed

    def ensure_horizon(self, slot: int, upto_tokens: int) -> bool:
        """Horizon-aware alloc-on-write: like :meth:`ensure` (including the
        copy-on-write sweep), but clamps the target to the slot's admit-time
        reservation.

        A multi-step horizon conservatively asks for coverage of ``pos + n``
        tokens before dispatch; near the end of a request that overshoots
        the reservation (the final token's KV is never written, and the
        device-side retirement mask stops all writes at the budget), so the
        overshoot is provably never touched and clamping is safe. The
        reserve-on-admit invariant — a live request can never fail
        alloc-on-write — carries over unchanged.
        """
        cap = int(self._reserved[slot]) * self.spec.block_size
        return self.ensure(slot, min(int(upto_tokens), cap))

    def drain_copies(self) -> List[Tuple[int, int]]:
        """Pending COW ``(src, dst)`` block copies; the engine applies them
        on device before the next dispatch. Draining transfers ownership."""
        out, self._pending_copies = self._pending_copies, []
        return out

    def publish(self, slot: int, keys: Sequence[bytes]) -> None:
        """Insert the slot's leading fully-written blocks into the prefix
        index (key ``i`` for owned block ``i``) so concurrent admissions can
        share them while the slot is still live. Blocks already keyed, or
        keys already mapped, are left alone."""
        owned = self._owned[slot]
        for i, key in enumerate(keys):
            if i >= len(owned):
                break
            blk = owned[i]
            if blk not in self._key_of and key not in self._index:
                self._index[key] = blk
                self._key_of[blk] = key

    def release(self, slot: int, keys: Sequence[bytes] = ()) -> None:
        """Free-on-retire: drop the slot's references, clear its table row.

        ``keys`` (one per leading fully-written block) index the released
        blocks for future prefix matches; an unreferenced block parks in the
        LRU if it carries a key and returns to the free list otherwise.
        """
        frees: List[int] = []
        parked: List[int] = []
        for i, blk in enumerate(self._owned[slot]):
            if (i < len(keys) and blk not in self._key_of
                    and keys[i] not in self._index):
                self._index[keys[i]] = blk
                self._key_of[blk] = keys[i]
            self._ref[blk] -= 1
            assert self._ref[blk] >= 0, f"double free of block {blk}"
            if self._ref[blk] == 0:
                if blk in self._key_of:
                    parked.append(blk)
                else:
                    frees.append(blk)
        # park chain-deepest first: a match needs an unbroken *leading* run,
        # so the LRU head (evicted first) must be the tail of a released
        # chain — eviction then shortens cached prefixes from the back
        # instead of beheading them
        for blk in reversed(parked):
            self._lru[blk] = None            # most recently released = MRU
        self._free.extend(reversed(frees))
        self._owned[slot] = []
        self._reserved[slot] = 0
        self._fresh[slot] = 0
        self._written[slot] = 0
        self.tables[slot, :] = self.spec.dump

    def invalidate(self) -> None:
        """Drop the whole prefix index (cache-fault quarantine): evictable
        cached blocks return to the free list, pinned blocks stay owned but
        can never be matched again."""
        self._free.extend(self._lru)
        self._lru.clear()
        self._index.clear()
        self._key_of.clear()
        self.invalidations += 1

    # --- invariants (exercised by the property tests) -----------------------

    def check(self) -> None:
        """No leaks or double-frees, refcounts match the tables, the LRU is
        exactly the ref-0 cached set, shared blocks are position-aligned."""
        owners: collections.Counter = collections.Counter()
        for lst in self._owned:
            assert len(set(lst)) == len(lst), "block aliased within a slot"
            owners.update(lst)
        uniq, free, lru = set(owners), set(self._free), set(self._lru)
        assert len(self._free) == len(free), "free-list double entry"
        assert not (uniq & free), "block simultaneously owned and free"
        assert not (lru & free), "cached block also on the free list"
        assert not (lru & uniq), "cached-unreferenced block still owned"
        assert len(uniq) + len(free) + len(lru) == self.spec.n_blocks, \
            "block leak: owned + free + cached != pool"
        for blk in range(self.spec.n_blocks):
            assert self._ref[blk] == owners.get(blk, 0), \
                f"refcount leak on block {blk}"
        assert lru == {b for b in self._key_of if self._ref[b] == 0}, \
            "LRU out of sync with the ref-0 cached set"
        assert len(self._index) == len(self._key_of), \
            "index/key_of size mismatch"
        for key, blk in self._index.items():
            assert self._key_of.get(blk) == key, "index/key bijection broken"
        cols: Dict[int, set] = {}
        for slot, lst in enumerate(self._owned):
            assert len(lst) <= self._reserved[slot], \
                f"slot {slot} owns more than it reserved"
            assert self._fresh[slot] >= 0, \
                f"slot {slot} fresh budget went negative"
            row = self.tables[slot]
            assert list(row[:len(lst)]) == lst, f"slot {slot} table mismatch"
            assert (row[len(lst):] == self.spec.dump).all(), \
                f"slot {slot} table maps unowned positions"
            for i, blk in enumerate(lst):
                cols.setdefault(blk, set()).add(i)
        for blk, cs in cols.items():
            if owners[blk] > 1:
                assert len(cs) == 1, \
                    f"shared block {blk} mapped at different table columns"
        assert int(self._fresh.sum()) <= len(self._free) + len(self._lru), \
            "outstanding fresh budgets exceed assignable blocks"


def default_spec(n_slots: int, max_len: int, block_size: int) -> PagedSpec:
    """Pool sized to the contiguous engine's budget: every slot can still hold
    ``max_len`` tokens, so admission never backpressures more than the
    contiguous engine would — capacity wins come from setting ``n_blocks``
    below this (or ``n_slots`` above the contiguous count at equal budget)."""
    return PagedSpec(n_blocks=n_slots * (-(-max_len // block_size)),
                     block_size=block_size)
