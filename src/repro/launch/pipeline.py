"""Pipeline parallelism over the `pod` axis (GPipe-style).

The multi-pod mesh's `pod` axis defaults to data-parallel; this module provides
the alternative: layer-stage parallelism. Stacked layer params (L, ...) are
split into (n_stages, L/n_stages, ...) and sharded over `pod`; the step runs
under `shard_map`, streaming M microbatches through the stages with
`ppermute` hops between neighbours — a scan over M + S - 1 pipeline ticks, so
each pod computes its stage's layers only, with the classic (S-1)/(M+S-1)
bubble. Because `ppermute` is differentiable (its transpose is the reverse
permutation), `jax.grad` through this forward yields the pipelined backward
automatically, with GPipe's O(M) activation stash.

This is the scale-out path for models too deep for one pod's HBM at 1000+
nodes; elastic restart reshards the (S, L/S, ...) split to any stage count
that divides L (the checkpoint layout stays stage-agnostic: plain (L, ...)).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def split_stages(stacked, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...)."""
    def one(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree.map(one, stacked)


def merge_stages(staged):
    return jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), staged)


def make_pipelined_apply(stage_fn: Callable, n_stages: int, mesh: Mesh,
                         axis: str = "pod"):
    """Build `apply(staged_params, x_micro) -> y_micro`.

    stage_fn(stage_params, x): one stage's layers, (b, s, d) -> (b, s, d).
    x_micro: (M, b, s, d) microbatches, replicated over `axis`. The returned
    apply runs the GPipe schedule and returns (M, b, s, d) final activations.
    """
    def pipelined(staged_params, x_micro):
        sp = jax.tree.map(lambda a: a[0], staged_params)   # my stage's params
        m = x_micro.shape[0]
        ticks = m + n_stages - 1
        idx = jax.lax.axis_index(axis)
        fwd = [(i, i + 1) for i in range(n_stages - 1)]

        def step(carry, t):
            buf, outs = carry                      # buf: activation entering me
            feed = jnp.clip(t, 0, m - 1)
            my_in = jnp.where(idx == 0, x_micro[feed], buf)
            active = (t - idx >= 0) & (t - idx < m)
            out = stage_fn(sp, my_in)
            out = jnp.where(active, out, jnp.zeros_like(out))
            done = jnp.clip(t - (n_stages - 1), 0, m - 1)
            is_done = (idx == n_stages - 1) & (t >= n_stages - 1)
            outs = jax.lax.cond(is_done,
                                lambda o: o.at[done].set(out),
                                lambda o: o, outs)
            nxt = (jax.lax.ppermute(out, axis, fwd)
                   if n_stages > 1 else jnp.zeros_like(out))
            return (nxt, outs), None

        init = (jnp.zeros_like(x_micro[0]), jnp.zeros_like(x_micro))
        (_, outs), _ = jax.lax.scan(step, init,
                                    jnp.arange(ticks, dtype=jnp.int32))
        if n_stages > 1:   # only the last stage wrote -> psum broadcasts it
            outs = jax.lax.psum(outs, axis)
        return outs

    def apply(staged_params, x_micro):
        in_specs = (jax.tree.map(lambda _: P(axis), staged_params), P())
        return shard_map(pipelined, mesh=mesh, in_specs=in_specs,
                         out_specs=P(), check_rep=False)(staged_params, x_micro)
    return apply
