"""Roofline-term extraction from compiled dry-run artifacts.

compute  = HLO_FLOPs_per_device / peak_FLOP/s
memory   = HLO_bytes_per_device / HBM_bw
collective = collective_bytes_per_device / ICI link bw

cost_analysis() of the SPMD-partitioned module is per-device; collective bytes
are parsed from the partitioned HLO text (sum over all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute of max(operand, result) bytes —
a single-link, no-overlap, conservative traffic proxy).
"""
from __future__ import annotations

import re
from typing import Dict

from . import mesh as mesh_mod

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape in `text` (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind byte totals from (partitioned) HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, _, rhs = s.partition("=")
        rhs = rhs.strip()
        for kind in _COLLECTIVES:
            # match op invocation e.g. "bf16[2048,128] all-gather(...)"; async
            # ops are counted at -start only so -start/-done pairs aren't doubled
            m2 = re.search(r"\b" + kind + r"(-start|-done)?\(", rhs)
            if m2:
                if m2.group(1) == "-done":
                    break  # counted at -start
                result_bytes = _shape_bytes(rhs[:m2.start()])
                # operands: inside the call parens
                call = rhs[m2.end():]
                depth = 1
                i = 0
                for i, ch in enumerate(call):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                arg_bytes = _shape_bytes(call[:i])
                out[kind] += max(result_bytes, arg_bytes)
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(cost: Dict[str, float], coll_bytes: int,
                   n_chips: int) -> Dict[str, float]:
    if isinstance(cost, (list, tuple)):
        # some jax versions wrap Compiled.cost_analysis() in a 1-element list
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    mem_bytes = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / mesh_mod.PEAK_FLOPS
    t_memory = mem_bytes / mesh_mod.HBM_BW
    t_coll = coll_bytes / mesh_mod.ICI_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": flops,
        "bytes_per_device": mem_bytes,
        "coll_bytes_per_device": float(coll_bytes),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "n_chips": n_chips,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for train; 2·N·D per decoded/prefilled token."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
