"""Per-slot token sampling for the continuous-batching serve engine.

Every slot of the batched decode step carries its own sampling parameters and
its own RNG stream, so a request's sampled tokens are a function of (request
seed, request id, token index) only — never of which other requests happen to
share its batch. `sample_tokens` is vmapped over slots and jit-friendly; the
engine folds a per-request base key with a per-slot token counter each step.

Knobs (all per slot):

* ``temperature`` — 0 selects greedy argmax (the bit-parity reference path);
  > 0 divides logits before sampling.
* ``top_k``       — keep only the k highest logits (0 disables).
* ``top_p``       — nucleus sampling: keep the smallest set of tokens whose
  probability mass reaches p (1.0 disables). Applied after top-k, matching
  the usual serving convention.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration (see module docstring)."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0


GREEDY = SamplingParams()


def request_key(seed: int, rid: int) -> jnp.ndarray:
    """Base RNG key for one request: seed stream folded with the request id."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), rid)


def _sample_one(logits, temperature, top_k, top_p, key):
    """Sample one token from one slot's (V,) logits row."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits).astype(jnp.int32)
    l = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    # top-k: keep the k highest logits (ties at the threshold all survive)
    kth = jnp.sort(l)[::-1][jnp.clip(top_k, 1, v) - 1]
    l = jnp.where((top_k > 0) & (l < kth), -jnp.inf, l)
    # top-p: smallest prefix of the sorted distribution with mass >= p
    probs = jax.nn.softmax(l)
    sorted_p = jnp.sort(probs)[::-1]
    thr = sorted_p[jnp.argmax(jnp.cumsum(sorted_p) >= top_p)]
    l = jnp.where((top_p < 1.0) & (probs < thr), -jnp.inf, l)
    sampled = jax.random.categorical(key, l).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


def sample_tokens(logits, temperature, top_k, top_p, keys) -> jnp.ndarray:
    """Sample one token per slot.

    logits: (B, V) float; temperature (B,), top_k (B,) int32, top_p (B,);
    keys: (B, 2) uint32 per-slot RNG keys. Returns (B,) int32. Slots with
    temperature == 0 take the greedy argmax (and ignore their key).

    The top-k/top-p machinery costs two full V-wide sorts per slot; a batch
    where every slot is greedy (the bit-parity serving default) skips them
    at runtime via `lax.cond` — slots still get exactly the value the
    sampled branch would have produced for them (greedy is the
    temperature == 0 case of `_sample_one`), so outputs are unchanged.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.lax.cond(
        jnp.any(temperature > 0.0),
        lambda _: jax.vmap(_sample_one)(logits, temperature, top_k, top_p,
                                        keys),
        lambda _: greedy, None)
