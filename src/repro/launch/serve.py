"""Serving driver: lockstep reference loop + continuous-batching engine CLI.

Two modes:

* **lockstep** (default) — batched prefill + greedy decode with one shared
  position: every request padded to the same prompt/gen length. This is the
  bit-parity *reference* for the engine (`lockstep_generate`) and the
  baseline the engine's throughput is measured against.
* **``--engine``** — the continuous-batching engine (`launch.engine`):
  admission queue, prefill-on-admit, per-slot ragged decode, EOS/max-len
  retirement, slot reuse, per-slot sampling. Give it a ragged workload with
  ``--requests/--poisson-rate`` (synthetic Poisson trace) or replay a
  recorded trace with ``--trace FILE`` (JSON lines:
  ``{"arrival": 3, "prompt_len": 12, "gen_len": 16, "temperature": 0.7}``;
  unknown lengths fall back to --prompt-len/--gen-len).

``--backend`` routes every model GEMM through that `GemmPolicy` backend;
``--bind`` (the default for non-exact backends) binds the parameter pytree
first (`core.gemm.bind`) so decode runs weight-stationary — weights are
quantized and backend-prepared once instead of every token.

Run:  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --debug \
          --engine --requests 8 --poisson-rate 2 --backend mxu_int8 --bind
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import gemm
from repro.models import get_model

from . import engine as engine_mod
from . import sampling


def _build_lockstep_steps(cfg, policy):
    model = get_model(cfg)
    prefill = jax.jit(
        lambda p, bt, c: model.prefill(p, bt, c, policy=policy))
    decode = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, t, c, pos, policy=policy))
    return prefill, decode


_cached_lockstep_steps = functools.lru_cache(maxsize=64)(_build_lockstep_steps)


def _lockstep_steps(cfg, policy):
    try:
        return _cached_lockstep_steps(cfg, policy)
    except TypeError:    # unhashable policy (dict overrides): fresh build
        return _build_lockstep_steps(cfg, policy)


def lockstep_generate(cfg, model, params, prompts, gen_len, *,
                      policy=gemm.EXACT, input_embeds=None):
    """The lockstep reference: batched prefill + greedy decode, one scalar
    position shared by the whole batch. Returns (B, gen_len) int32 tokens.

    Per-request bit-parity contract: running a request alone here (batch 1)
    produces exactly the tokens the continuous-batching engine produces for
    it under greedy sampling, whatever else shares the engine's batch.
    """
    b, pl = prompts.shape
    start = pl + (input_embeds.shape[1] if input_embeds is not None else 0)
    cache = model.init_cache(b, start + gen_len)
    batch = {"tokens": prompts}
    if input_embeds is not None:
        batch["input_embeds"] = input_embeds
    # module-level jit cache: repeated calls (bench reps, per-request parity
    # references) hit compiled executables
    prefill_j, decode_j = _lockstep_steps(cfg, policy)
    logits, cache = prefill_j(params, batch, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    for i in range(gen_len - 1):
        logits, cache = decode_j(params, tok, cache, jnp.int32(start + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    return np.concatenate(out_tokens, axis=1)


def load_trace(path, vocab_size, default_prompt_len, default_gen_len, *,
               seed=0):
    """Replay a recorded request trace (JSON lines) as engine Requests."""
    rng = np.random.default_rng(seed)
    requests = []
    with open(path) as f:
        for rid, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            plen = int(rec.get("prompt_len", default_prompt_len))
            requests.append(engine_mod.Request(
                rid=rid,
                prompt=rng.integers(0, vocab_size, plen).astype(np.int32),
                max_new_tokens=int(rec.get("gen_len", default_gen_len)),
                params=sampling.SamplingParams(
                    temperature=float(rec.get("temperature", 0.0)),
                    top_k=int(rec.get("top_k", 0)),
                    top_p=float(rec.get("top_p", 1.0)),
                    seed=int(rec.get("seed", 0))),
                arrival=int(rec.get("arrival", 0))))
    return requests


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--debug", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="lockstep batch size / engine slot count")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--backend", default="exact", choices=gemm.BACKENDS,
                    help="GemmPolicy backend for every model GEMM")
    ap.add_argument("--k", type=int, default=4, help="approximation factor")
    ap.add_argument("--guard", default="none", choices=gemm.GUARDS,
                    help="ABFT integrity checking on every GEMM: 'detect' "
                         "flags faults (the engine restores/quarantines), "
                         "'recompute' additionally re-executes flagged tiles")
    ap.add_argument("--bind", action="store_true",
                    help="bind params to the policy (weight-stationary decode)")
    ap.add_argument("--no-bind", dest="bind", action="store_false")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine instead of lockstep")
    ap.add_argument("--requests", type=int, default=0,
                    help="engine: synthetic Poisson trace of N requests")
    ap.add_argument("--poisson-rate", type=float, default=2.0,
                    help="engine: mean arrivals per decode step")
    ap.add_argument("--trace", default=None,
                    help="engine: replay a JSONL request trace")
    ap.add_argument("--max-len", type=int, default=0,
                    help="engine: per-slot cache length (default: "
                         "prompt-len + gen-len)")
    ap.add_argument("--paged", dest="paged", action="store_true", default=True,
                    help="engine: paged KV cache + chunked prefill (default)")
    ap.add_argument("--contiguous", dest="paged", action="store_false",
                    help="engine: PR-4 contiguous per-slot caches with "
                         "whole-prompt prefill-on-admit")
    ap.add_argument("--block-size", type=int, default=8,
                    help="engine: paged KV block size in tokens")
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="engine: KV pool size in blocks (default: the "
                         "contiguous budget, slots * ceil(max_len / block))")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="engine: prompt tokens admitted per chunked-prefill "
                         "step")
    ap.add_argument("--paged-kernel", type=int, default=0, metavar="N",
                    help="engine: serve attention through the fused Pallas "
                         "paged-attention kernel; N=1 keeps the bit-exact "
                         "sequential KV scan, N>1 enables split-KV flash "
                         "decoding with N splits (0 = gather path)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="engine: share KV blocks across requests with equal "
                         "prompt prefixes — hash-keyed block index, "
                         "copy-on-write, LRU eviction (default; paged only)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="engine: disable prefix caching (every admission "
                         "re-prefills from token zero)")
    ap.add_argument("--multi-step", type=int, default=1, metavar="N",
                    help="engine: fuse N decode sub-steps into one "
                         "device-resident lax.scan horizon (on-device "
                         "EOS/budget retirement, one host sync per horizon; "
                         "1 = per-step dispatch)")
    ap.add_argument("--queue-limit", type=int, default=0,
                    help="engine: bound the admission queue — overflow is "
                         "rejected with status 'rejected_queue_full' "
                         "(0 = unbounded)")
    ap.add_argument("--ttft-deadline", type=int, default=0,
                    help="engine: retire requests that have not emitted a "
                         "first token within N steps of arrival (0 = off)")
    ap.add_argument("--total-deadline", type=int, default=0,
                    help="engine: retire requests not finished within N "
                         "steps of arrival (0 = off)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.set_defaults(bind=None)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.debug:
        cfg = reduced(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode step")
    policy = gemm.GemmPolicy(backend=args.backend, k=args.k, guard=args.guard)
    do_bind = (args.backend != "exact") if args.bind is None else args.bind
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    if do_bind:
        t0 = time.time()
        params = model.bind_params(params, policy)
        print(f"bound params to backend={args.backend} in "
              f"{time.time() - t0:.2f}s (weight-stationary decode)")
    rng = np.random.default_rng(args.seed)

    if args.engine:
        sp = sampling.SamplingParams(temperature=args.temperature,
                                     top_k=args.top_k, top_p=args.top_p,
                                     seed=args.seed)
        if args.trace:
            requests = load_trace(args.trace, cfg.vocab_size, args.prompt_len,
                                  args.gen_len, seed=args.seed)
        else:
            n = args.requests or 2 * args.batch
            requests = engine_mod.make_poisson_trace(
                n, rate=args.poisson_rate, vocab_size=cfg.vocab_size,
                prompt_lens=(args.prompt_len,), gen_lens=(args.gen_len,),
                seed=args.seed, params=sp)
        max_len = args.max_len or (args.prompt_len + args.gen_len)
        kw = {}
        if args.paged:
            kw = {"block_size": args.block_size,
                  "n_blocks": args.n_blocks or None,
                  "prefill_chunk": args.prefill_chunk,
                  "paged_kernel": args.paged_kernel or None,
                  "prefix_cache": args.prefix_cache}
        if args.ttft_deadline or args.total_deadline:
            for r in requests:
                r.ttft_deadline = args.ttft_deadline or None
                r.total_deadline = args.total_deadline or None
        eng = engine_mod.ServeEngine(cfg, params, policy=policy,
                                     max_slots=args.batch, max_len=max_len,
                                     eos_id=args.eos_id, paged=args.paged,
                                     queue_limit=args.queue_limit or None,
                                     multi_step=args.multi_step,
                                     **kw)
        t0 = time.time()
        finished = eng.run(requests)
        dt = time.time() - t0
        st = eng.stats
        print(f"engine: {st['finished']} requests, "
              f"{st['generated_tokens']} tokens in {dt:.2f}s "
              f"({st['generated_tokens'] / dt:.1f} tok/s) over "
              f"{st['decode_steps']} decode steps")
        print(f"host syncs: {st['host_syncs']} "
              f"({st['syncs_per_token']:.3f}/token, "
              f"multi_step={st['multi_step']})")
        if args.paged:
            tok_total = max(1, st["prefill_tokens"] + st["decode_tokens"])
            print(f"occupancy: slots {st['slot_utilization']:.1%} "
                  f"(peak {st['peak_active_slots']}/{args.batch}), "
                  f"cache blocks {st['block_utilization']:.1%} "
                  f"(peak {st['peak_allocated_blocks']}/"
                  f"{eng.pool.spec.n_blocks}), "
                  f"token split {st['prefill_tokens']}/{st['decode_tokens']} "
                  f"prefill/decode "
                  f"({st['prefill_tokens'] / tok_total:.0%} prefill)")
            if eng.prefix_cache:
                print(f"prefix cache: {st['prefix_hits']} hits, "
                      f"{st['prefix_tokens_skipped']} prompt tokens skipped, "
                      f"{st['prefix_shared_blocks']} blocks shared, "
                      f"{st['prefix_cow_copies']} COW copies, "
                      f"{st['prefix_evicted_blocks']} evicted, "
                      f"{st['prefix_cached_blocks']} cached now")
        rel = {k: st[k] for k in (engine_mod.REJECTED_QUEUE_FULL, "cancelled",
                                  "deadline_ttft", "deadline_total",
                                  "preemptions", "faults_detected",
                                  "step_retries", "quarantines")}
        if args.guard != "none" or any(rel.values()):
            print("reliability: " + ", ".join(f"{k}={v}"
                                              for k, v in rel.items()))
        for rid in sorted(finished)[:4]:
            f = finished[rid]
            print(f"  rid={rid} [{f.finish_reason}] "
                  f"tokens={f.tokens[:8].tolist()}...")
        return finished

    b, pl, gl = args.batch, args.prompt_len, args.gen_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, pl)), jnp.int32)
    input_embeds = None
    if cfg.family == "vlm":
        input_embeds = jnp.asarray(
            rng.normal(size=(b, max(2, pl // 4), cfg.d_model)), jnp.float32)
    t0 = time.time()
    gen = lockstep_generate(cfg, model, params, prompts, gl, policy=policy,
                            input_embeds=input_embeds)
    dt = time.time() - t0
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({b * gl / dt:.1f} tok/s); first row: {gen[0][:12]}")
    return gen


if __name__ == "__main__":
    main()
