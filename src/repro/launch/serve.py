"""Serving driver: batched prefill + decode with a KV cache.

Debug mode (CPU container): reduced config, greedy-decodes a batch of prompts
end-to-end — the serving example. Production mode lowers the same step
functions onto the mesh.

``--backend`` routes every model GEMM through that `GemmPolicy` backend;
``--bind`` (the default for non-exact backends) binds the parameter pytree
first (`core.gemm.bind`) so decode runs weight-stationary — weights are
quantized and backend-prepared once instead of every token.

Run:  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --debug \
          --prompt-len 16 --gen-len 16 --batch 4 --backend mxu_int8 --bind
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core import gemm
from repro.models import get_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--debug", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--backend", default="exact", choices=gemm.BACKENDS,
                    help="GemmPolicy backend for every model GEMM")
    ap.add_argument("--k", type=int, default=4, help="approximation factor")
    ap.add_argument("--bind", action="store_true",
                    help="bind params to the policy (weight-stationary decode)")
    ap.add_argument("--no-bind", dest="bind", action="store_false")
    ap.set_defaults(bind=None)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.debug:
        cfg = reduced(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode step")
    policy = gemm.GemmPolicy(backend=args.backend, k=args.k)
    do_bind = (args.backend != "exact") if args.bind is None else args.bind
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    if do_bind:
        t0 = time.time()
        params = model.bind_params(params, policy)
        print(f"bound params to backend={args.backend} in "
              f"{time.time() - t0:.2f}s (weight-stationary decode)")
    rng = np.random.default_rng(0)
    b, pl, gl = args.batch, args.prompt_len, args.gen_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, pl)), jnp.int32)
    cache = model.init_cache(b, pl + gl)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["input_embeds"] = jnp.asarray(
            rng.normal(size=(b, max(2, pl // 4), cfg.d_model)), jnp.float32)

    prefill_j = jax.jit(lambda p, bt, c: model.prefill(p, bt, c, policy=policy))
    decode_j = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, t, c, pos, policy=policy))

    t0 = time.time()
    logits, cache = prefill_j(params, batch, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    pos = pl + (batch["input_embeds"].shape[1] if cfg.family == "vlm" else 0)
    for i in range(gl - 1):
        logits, cache = decode_j(params, tok, cache, jnp.int32(pos + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({b * gl / dt:.1f} tok/s); first row: {gen[0][:12]}")
    return gen


if __name__ == "__main__":
    main()
