"""Step builders: jit-able train / prefill / decode steps with shardings.

Microbatching: when n_micro > 1 the batch carries a leading micro dim
(n_micro, b_micro, ...) — sharded on dim 1 — and the step scans over it
accumulating gradients (keeps 32k-token activations within HBM; the scan also
lets XLA overlap each microbatch's FSDP all-gathers with the previous one's
compute). Optional int8 error-feedback gradient compression is applied to the
data/pod-axis gradient reduction via a quantize->psum-int32->dequantize rewrite.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import abft
from repro.core.gemm import EXACT, GemmPolicy
from repro.models import api as model_api
from repro.optim import adamw, schedule
from repro.sharding import specs as sh

from . import sampling

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    n_micro: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    remat: bool = True
    remat_save_attn: bool = False   # selective remat: keep attn outputs resident
    compress_grads: bool = False


def default_micro(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Pick a microbatch count that bounds activation memory: target <= 2**17
    tokens per microbatch globally (~8k tokens per data shard -> layer-scan
    residuals of a 48L/5k-wide model stay ~4 GiB/device)."""
    tokens = shape.global_batch * shape.seq_len
    target = 2 ** 17
    n = max(1, tokens // target)
    while shape.global_batch % n:
        n -= 1
    return n


def make_train_step(cfg: ModelConfig, hp: TrainHParams,
                    policy: GemmPolicy = EXACT, batch_axes=()):
    model = model_api.get_model(cfg)

    def loss_fn(params, mb):
        kw = {}
        if hp.remat_save_attn and cfg.family in ("dense", "moe", "audio", "vlm"):
            kw["remat_save_attn"] = True
        return model.lm_loss(params, mb, policy=policy, remat=hp.remat,
                             batch_axes=batch_axes, **kw)

    def train_step(params, opt_state, batch):
        if hp.n_micro > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (gzero, jnp.zeros(())), batch)
            grads = jax.tree.map(lambda g: g / hp.n_micro, gsum)
            loss = lsum / hp.n_micro
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = schedule.warmup_cosine(opt_state.step, peak_lr=hp.peak_lr,
                                    warmup_steps=hp.warmup_steps,
                                    total_steps=hp.total_steps)
        new_params, new_opt = adamw.update(grads, opt_state, params, lr=lr,
                                           weight_decay=hp.weight_decay)
        return new_params, new_opt, {"loss": loss, "lr": lr}

    return train_step


def make_prefill_step(cfg: ModelConfig, policy: GemmPolicy = EXACT,
                      batch_axes=()):
    """`params` may be raw or a `gemm.BoundParams` from `bind_serving_params`."""
    model = model_api.get_model(cfg)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache, policy=policy,
                             batch_axes=batch_axes)

    return prefill_step


def make_decode_step(cfg: ModelConfig, policy: GemmPolicy = EXACT,
                     batch_axes=(), paged_kernel=None):
    """Decode step builder. Pass `bind_serving_params(cfg, params, policy)`
    instead of raw params to serve weight-stationary: every weight leaf is
    quantized + backend-prepared once at bind time, so the per-token step
    performs zero weight quantization / delta-factor construction.

    `pos` may be a scalar (lockstep decode) or a per-slot `(B,)` position
    vector — the ragged form the continuous-batching engine
    (`launch.engine.ServeEngine`) drives this step with.

    ``paged_kernel`` (paged caches only): route block-table attention reads
    through the fused Pallas kernel (`kernels.paged_attention`) instead of
    the gather path — see `make_chunk_step`."""
    model = model_api.get_model(cfg)

    def serve_step(params, token, cache, pos):
        kw = {"paged_kernel": paged_kernel} if paged_kernel else {}
        return model.decode_step(params, token, cache, pos, policy=policy,
                                 batch_axes=batch_axes, **kw)

    return serve_step


def make_chunk_step(cfg: ModelConfig, policy: GemmPolicy = EXACT,
                    batch_axes=(), paged_kernel=None):
    """The unified serving step behind the paged engine: one jit-able function
    covering decode (T == 1, q_len == 1) and chunked prefill (T = chunk
    budget, per-slot q_len <= T, trailing padding masked) — a mixed
    prefill+decode batch is just rows with different q_len. `cache` may be
    contiguous or paged (``block_tables`` leaf); `pos` is the per-slot (B,)
    write position of each row's first token. Returns each slot's
    last-valid-token logits (B, 1, V) plus the updated cache.

    ``paged_kernel``: truthy routes paged-cache attention reads through the
    fused Pallas kernel (in-kernel block-table walk, no HBM gather); the
    integer value is the flash-decoding split count (1 = sequential scan,
    bit-identical to the gather path). Ignored by families without
    attention pools (pure-recurrent xLSTM)."""
    model = model_api.get_model(cfg)

    def chunk_step(params, tokens, cache, pos, q_len, input_embeds=None,
                   embed_mask=None):
        kw = {}
        if input_embeds is not None:
            kw = {"input_embeds": input_embeds, "embed_mask": embed_mask}
        if paged_kernel:
            kw["paged_kernel"] = paged_kernel
        return model.chunk_step(params, tokens, cache, pos, q_len,
                                policy=policy, batch_axes=batch_axes, **kw)

    return chunk_step


def make_copy_blocks_step():
    """Jitted pool-block clone for copy-on-write prefix sharing.

    ``(cache, src, dst) -> cache`` with pool rows ``dst`` overwritten by
    ``src`` on every paged pool leaf (`models.api.copy_pool_blocks`). The
    engine dispatches this between the host allocator's COW decision
    (`paged.BlockPool.drain_copies`) and the next chunk/horizon step, so a
    retargeted table row always reads an exact clone of the block it
    shared — resumed prefill from a cached prefix stays bit-identical to a
    cold one. One fused device call regardless of how many copies a step
    queued (``src``/``dst`` are ``(n,) int32``)."""

    def copy_blocks_step(cache, src, dst):
        return model_api.copy_pool_blocks(cache, src, dst)

    return jax.jit(copy_blocks_step)


def make_multi_step(cfg: ModelConfig, policy: GemmPolicy = EXACT, n: int = 8,
                    batch_axes=(), paged_kernel=None):
    """Device-resident multi-step decode: a fixed-``n`` ``lax.scan`` over the
    unified chunk step, so one dispatch covers ``n`` decode sub-steps and the
    host syncs a single ``(n, B)`` token block per horizon instead of one
    token vector per step.

    Everything the per-step scheduler used to do between decode dispatches
    moves inside the scan:

    * **sampling streams** — each sub-step folds the per-slot counters into
      the request keys (``fold_in(base_key, i)`` for token ``i``), exactly
      the per-step engine's stream.
    * **positions / paged write cursors** — advance by the per-slot active
      mask; paged writes land through the block tables the engine ensured to
      cover the whole horizon before dispatch.
    * **retirement** — EOS detection (per-slot id, ``-1`` = none) and
      max-new-tokens accounting run on device: a slot that finishes
      mid-horizon flips its own ``active`` bit, and its remaining sub-steps
      are ``q_len == 0`` no-ops (dump-block / where-frozen writes, no
      position advance) — tokens past an in-horizon EOS are reported as
      ``-1`` and can never reach a served stream.
    * **early exit** — an ``n_splits``-style mask: once every slot has
      retired, the remaining sub-steps skip the model entirely via
      ``lax.cond``.

    ABFT integration: each sub-step's traced fault records are tagged with
    the scan index (``core.abft.substep``), so a fault detected inside the
    fused horizon is attributed to the exact sub-step that produced it; the
    engine scrubs fingerprints at horizon boundaries (around the dispatch).

    Requires ``state`` to carry the device-retirement leaves ``eos`` and
    ``budget`` (``(B,) int32``) alongside the per-step engine state. Returns
    ``(tok_block, cache, state)`` with ``tok_block: (n, B) int32`` where
    ``-1`` marks sub-steps on which a slot emitted nothing."""
    if n < 1:
        raise ValueError(f"multi-step horizon must be >= 1, got {n}")
    step_fn = make_chunk_step(cfg, policy, batch_axes=batch_axes,
                              paged_kernel=paged_kernel)

    def multi_step(params, cache, state):
        def sub_step(carry, i):
            cache, state = carry

            def live(cache, state):
                active = state["active"]
                q_len = active.astype(jnp.int32)
                with abft.substep(i):
                    logits, cache = step_fn(params, state["last_tok"], cache,
                                            state["positions"], q_len)
                # token i of a request samples with fold_in(base_key, i) —
                # bit-identical to the per-step engine's stream
                keys = jax.vmap(jax.random.fold_in)(state["keys"],
                                                    state["counters"])
                tok = sampling.sample_tokens(logits[:, 0].astype(jnp.float32),
                                             state["temperature"],
                                             state["top_k"], state["top_p"],
                                             keys)
                # device-resident retirement: the EOS-producing sub-step is
                # the slot's last (its input token's KV is already written);
                # later sub-steps freeze it via q_len == 0
                done = (tok == state["eos"]) | (state["counters"] + 1
                                                >= state["budget"])
                state = dict(
                    state,
                    positions=state["positions"] + q_len,
                    counters=state["counters"] + q_len,
                    last_tok=jnp.where(active, tok,
                                       state["last_tok"][:, 0])[:, None],
                    active=active & ~done)
                return (cache, state), jnp.where(active, tok, -1)

            def idle(cache, state):
                return (cache, state), jnp.full_like(state["counters"], -1)

            return jax.lax.cond(jnp.any(state["active"]), live, idle,
                                cache, state)

        (cache, state), toks = jax.lax.scan(sub_step, (cache, state),
                                            jnp.arange(n))
        return toks, cache, state

    return multi_step


def bind_serving_params(cfg: ModelConfig, params, policy: GemmPolicy, **kw):
    """Bind a param pytree to the serving policy (see `core.gemm.bind`).

    The returned `BoundParams` drops into the same jit'd prefill/decode steps
    as raw params. Note: binding is a serving-local transform — the sharded
    `assemble_*` helpers lower against *raw* param shapes; bind on the loaded
    (already sharded) params right before entering the serve loop."""
    return model_api.get_model(cfg).bind_params(params, policy, **kw)


# ---------------------------------------------------------------------------
# Sharding assembly for a (cfg x shape x mesh) cell
# ---------------------------------------------------------------------------

def train_input_specs(cfg: ModelConfig, shape: ShapeSpec, n_micro: int):
    specs = model_api.input_specs(cfg, shape)
    if n_micro > 1:
        def split(s):
            b = s.shape[0]
            assert b % n_micro == 0, (b, n_micro)
            return jax.ShapeDtypeStruct((n_micro, b // n_micro) + s.shape[1:],
                                        s.dtype)
        specs = jax.tree.map(split, specs)
    return specs


def micro_input_shardings(specs: PyTree, mesh: Mesh, n_micro: int):
    baxes = sh.batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]

    def one(leaf):
        bdim = 1 if n_micro > 1 else 0
        if leaf.ndim > bdim and leaf.shape[bdim] % bsize == 0 and leaf.shape[bdim] > 1:
            spec = [None] * leaf.ndim
            spec[bdim] = baxes
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, specs)


def assemble_train(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                   hp: Optional[TrainHParams] = None,
                   policy: GemmPolicy = EXACT):
    """Returns (step_fn, arg_specs, in_shardings, out_shardings) ready to lower."""
    hp = hp or TrainHParams(n_micro=default_micro(cfg, shape))
    model = model_api.get_model(cfg)
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(adamw.init, params_shape)
    p_shard = sh.param_shardings(params_shape, mesh)
    o_shard = adamw.AdamWState(NamedSharding(mesh, P()),
                               p_shard_as_f32(p_shard), p_shard_as_f32(p_shard))
    in_specs = train_input_specs(cfg, shape, hp.n_micro)
    b_shard = micro_input_shardings(in_specs, mesh, hp.n_micro)
    step = make_train_step(cfg, hp, policy, batch_axes=sh.batch_axes(mesh))
    metrics_shard = {"loss": NamedSharding(mesh, P()),
                     "lr": NamedSharding(mesh, P())}
    return (step, (params_shape, opt_shape, in_specs),
            (p_shard, o_shard, b_shard),
            (p_shard, o_shard, metrics_shard), hp)


def p_shard_as_f32(p_shard):
    return jax.tree.map(lambda s: s, p_shard)


def assemble_decode(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    policy: GemmPolicy = EXACT, cache_dtype=None):
    model = model_api.get_model(cfg)
    b = shape.global_batch
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    kw = {"dtype": cache_dtype} if cache_dtype is not None else {}
    try:
        cache_shape = model_api.cache_specs(cfg, b, shape.seq_len, **kw)
    except TypeError:   # families without a dtype knob
        cache_shape = model_api.cache_specs(cfg, b, shape.seq_len)
    p_shard = sh.param_shardings(params_shape, mesh)
    c_shard = sh.cache_shardings(cache_shape, mesh, batch=b)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_shard = sh.input_shardings({"t": tok}, mesh)["t"]
    # per-slot position vector: the production decode cell lowers the ragged
    # continuous-batching form (lockstep is its all-equal special case)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    step = make_decode_step(cfg, policy, batch_axes=sh.batch_axes(mesh))
    logits_shard = NamedSharding(mesh, P())
    return (step, (params_shape, tok, cache_shape, pos),
            (p_shard, tok_shard, c_shard, NamedSharding(mesh, P())),
            (logits_shard, c_shard))


def assemble_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                     policy: GemmPolicy = EXACT):
    model = model_api.get_model(cfg)
    b = shape.global_batch
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    in_specs = model_api.input_specs(cfg, shape)
    cache_shape = model_api.cache_specs(cfg, b, shape.seq_len) \
        if cfg.family != "audio" else None
    p_shard = sh.param_shardings(params_shape, mesh)
    b_shard = sh.input_shardings(in_specs, mesh)
    step = make_prefill_step(cfg, policy, batch_axes=sh.batch_axes(mesh))
    if cfg.family == "audio":
        # encoder: "prefill" = full forward producing per-frame hidden states
        model_ = model_api.get_model(cfg)

        def enc_step(params, batch):
            from repro.models import transformer
            hidden, _, _ = transformer.forward(
                params, cfg, input_embeds=batch["input_embeds"], policy=policy)
            return transformer.logits_from_hidden(params, cfg, hidden[:, -1:],
                                                  policy)

        return (enc_step, (params_shape, in_specs), (p_shard, b_shard),
                NamedSharding(mesh, P()))
    c_shard = sh.cache_shardings(cache_shape, mesh, batch=b)
    logits_shard = NamedSharding(mesh, P())
    return (step, (params_shape, in_specs, cache_shape),
            (p_shard, b_shard, c_shard), (logits_shard, c_shard))
