"""Training driver: --arch <id> [--shape train_4k] with the full production
stack (mesh, shardings, microbatching, AdamW, checkpointing, fault tolerance).

On CPU (this container) use --debug to train a reduced config on a 1x1 mesh —
that is the end-to-end example path. On a real TPU slice the same driver runs
the full config on the production mesh.

Run:  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --debug \
          --steps 30 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeSpec
from repro.launch import mesh as mesh_mod
from repro.launch.steps import TrainHParams, assemble_train, default_micro
from repro.models import get_model
from repro.train.loop import LoopConfig, train
from repro.data.pipeline import DataConfig, SyntheticLM


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--debug", action="store_true",
                    help="reduced config + tiny shape on local devices")
    ap.add_argument("--seq-len", type=int, default=64, help="debug seq len")
    ap.add_argument("--batch", type=int, default=4, help="debug global batch")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.debug:
        cfg = reduced(cfg)
        shape = ShapeSpec("debug", "train", args.seq_len, args.batch)
        mesh = mesh_mod.make_debug_mesh(1, 1)
    else:
        shape = cfg.shape(args.shape)
        mesh = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
    n_micro = args.n_micro or (1 if args.debug else default_micro(cfg, shape))
    hp = TrainHParams(n_micro=n_micro, peak_lr=args.lr,
                      total_steps=args.steps)
    step, arg_specs, in_sh, out_sh, hp = assemble_train(cfg, shape, mesh, hp)
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(0, 1))
        model = get_model(cfg)
        lc = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every)
        data = SyntheticLM(cfg, shape, DataConfig(n_micro=n_micro))
        stats = train(cfg, shape, jitted, model.init_params, lc,
                      n_micro=n_micro, data=data)
    print(f"done: {stats}")
    return stats


if __name__ == "__main__":
    main()
