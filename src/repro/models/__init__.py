from .api import Model, cache_specs, get_model, input_specs  # noqa: F401
