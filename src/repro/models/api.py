"""Unified model API: one entry point per architecture family.

`get_model(cfg)` returns a `Model` with init/loss/prefill/decode functions;
`input_specs(cfg, shape)` builds ShapeDtypeStruct stand-ins for every input of
the step the shape-cell exercises (train_step / prefill / decode) — the dry-run
lowers against these, so no real allocation ever happens for the full configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import gemm
from repro.core.gemm import EXACT, GemmPolicy
from . import hybrid, transformer, xlstm_model


@dataclasses.dataclass(frozen=True)
class Model:
    """Family-agnostic model handle.

    Every step function accepts either raw params or a ``gemm.BoundParams``
    pytree from ``bind_params`` — binding quantizes + backend-prepares each
    weight leaf once under the policy, so prefill/decode run weight-stationary
    (zero per-call weight quantization or delta-factor construction).
    """
    cfg: ModelConfig
    init_params: Callable
    lm_loss: Callable            # (params, batch, policy) -> scalar
    prefill: Callable            # (params, batch, cache, policy) -> (logits, cache)
    decode_step: Callable        # (params, token, cache, positions, policy)
    #                              -> (logits, cache); `positions` is a scalar
    #                              (lockstep) or a (B,) per-slot vector
    #                              (ragged continuous batching — the scalar
    #                              form is the all-equal degenerate case)
    init_cache: Optional[Callable]
    chunk_step: Optional[Callable] = None
    #   (params, tokens (B, T), cache, positions (B,), q_len (B,), policy,
    #    [input_embeds (B, T, d), embed_mask (B, T)]) -> (logits (B, 1, V),
    #   cache) — the unified serving step: decode is T == 1 / q_len == 1,
    #   chunked prefill is T = chunk budget with per-slot q_len <= T, and a
    #   mixed prefill+decode batch is just rows with different q_len.
    init_paged_cache: Optional[Callable] = None
    #   (batch, max_len, n_blocks, block_size) -> cache whose full-attention
    #   leaves are block pools + a per-slot ``block_tables`` leaf
    #   (launch.paged); recurrent / ring leaves stay per-slot.

    def bind_params(self, params, policy: GemmPolicy,
                    **kw) -> "gemm.BoundParams":
        """Prepare every policy-routed weight leaf once (see ``gemm.bind``)."""
        return gemm.bind(params, policy, **kw)


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        def loss(params, batch, policy=EXACT, remat=True, batch_axes=(),
                 remat_save_attn=False):
            return transformer.lm_loss(
                params, cfg, batch["tokens"],
                input_embeds=batch.get("input_embeds"),
                loss_mask=batch.get("loss_mask"), policy=policy, remat=remat,
                remat_save_attn=remat_save_attn, batch_axes=batch_axes)

        def prefill(params, batch, cache, policy=EXACT, batch_axes=()):
            return transformer.prefill(params, cfg, batch["tokens"], cache,
                                       input_embeds=batch.get("input_embeds"),
                                       policy=policy, batch_axes=batch_axes)

        def decode(params, token, cache, pos, policy=EXACT, batch_axes=(),
                   paged_kernel=None):
            return transformer.decode_step(params, cfg, token, cache, pos,
                                           policy=policy, batch_axes=batch_axes,
                                           paged_kernel=paged_kernel)

        def chunk(params, tokens, cache, pos, q_len, policy=EXACT,
                  batch_axes=(), input_embeds=None, embed_mask=None,
                  paged_kernel=None):
            return transformer.chunk_step(
                params, cfg, tokens, cache, pos, q_len, policy=policy,
                batch_axes=batch_axes, input_embeds=input_embeds,
                embed_mask=embed_mask, paged_kernel=paged_kernel)

        return Model(cfg, lambda key: transformer.init_params(cfg, key),
                     loss, prefill, decode,
                     lambda b, s, **kw: transformer.init_cache(cfg, b, s, **kw),
                     chunk_step=chunk,
                     init_paged_cache=lambda b, s, nb, bs, **kw:
                     transformer.init_cache(cfg, b, s, paged=(nb, bs), **kw))
    if cfg.family == "hybrid":
        def loss(params, batch, policy=EXACT, remat=True, batch_axes=()):
            return hybrid.lm_loss(params, cfg, batch["tokens"], policy=policy,
                                  batch_axes=batch_axes)

        def prefill(params, batch, cache, policy=EXACT, batch_axes=()):
            return hybrid.prefill(params, cfg, batch["tokens"], cache,
                                  policy=policy, batch_axes=batch_axes)

        def decode(params, token, cache, pos, policy=EXACT, batch_axes=(),
                   paged_kernel=None):
            return hybrid.decode_step(params, cfg, token, cache, pos,
                                      policy=policy, batch_axes=batch_axes,
                                      paged_kernel=paged_kernel)

        def chunk(params, tokens, cache, pos, q_len, policy=EXACT,
                  batch_axes=(), paged_kernel=None, **_):
            return hybrid.chunk_step(params, cfg, tokens, cache, pos, q_len,
                                     policy=policy, batch_axes=batch_axes,
                                     paged_kernel=paged_kernel)

        return Model(cfg, lambda key: hybrid.init_params(cfg, key),
                     loss, prefill, decode,
                     lambda b, s: hybrid.init_cache(cfg, b, s),
                     chunk_step=chunk,
                     init_paged_cache=lambda b, s, nb, bs:
                     hybrid.init_cache(cfg, b, s, paged=(nb, bs)))
    if cfg.family == "ssm":
        def loss(params, batch, policy=EXACT, remat=True, batch_axes=()):
            return xlstm_model.lm_loss(params, cfg, batch["tokens"],
                                       policy=policy, batch_axes=batch_axes)

        def prefill(params, batch, cache, policy=EXACT, batch_axes=()):
            return xlstm_model.prefill(params, cfg, batch["tokens"], cache,
                                       policy=policy, batch_axes=batch_axes)

        def decode(params, token, cache, pos, policy=EXACT, batch_axes=()):
            return xlstm_model.decode_step(params, cfg, token, cache, pos,
                                           policy=policy, batch_axes=batch_axes)

        def chunk(params, tokens, cache, pos, q_len, policy=EXACT,
                  batch_axes=(), **_):
            return xlstm_model.chunk_step(params, cfg, tokens, cache, pos,
                                          q_len, policy=policy,
                                          batch_axes=batch_axes)

        return Model(cfg, lambda key: xlstm_model.init_params(cfg, key),
                     loss, prefill, decode,
                     lambda b, s: xlstm_model.init_cache(cfg, b, s),
                     chunk_step=chunk,
                     init_paged_cache=lambda b, s, nb, bs:
                     xlstm_model.init_cache(cfg, b, s, paged=(nb, bs)))
    raise ValueError(f"unknown family {cfg.family}")


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                batch_override: Optional[int] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step inputs of this (arch x shape) cell."""
    b = batch_override or shape.global_batch
    s = shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.family == "audio":
            return {"input_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "loss_mask": jax.ShapeDtypeStruct((b, s), f32)}
        if cfg.family == "vlm":
            s_img = int(s * cfg.prefix_len_frac)
            return {"input_embeds": jax.ShapeDtypeStruct((b, s_img, cfg.d_model), f32),
                    "tokens": jax.ShapeDtypeStruct((b, s - s_img), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"input_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)}
        if cfg.family == "vlm":
            s_img = int(s * cfg.prefix_len_frac)
            return {"input_embeds": jax.ShapeDtypeStruct((b, s_img, cfg.d_model), f32),
                    "tokens": jax.ShapeDtypeStruct((b, s - s_img), i32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a cache of seq_len
    return {"token": jax.ShapeDtypeStruct((b, 1), i32)}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, **kw):
    """ShapeDtypeStructs of the KV/SSM cache for decode dry-runs."""
    model = get_model(cfg)
    if model.init_cache is None:
        return None
    return jax.eval_shape(lambda: model.init_cache(batch, max_len, **kw))


# Batch-dimension index of every cache leaf, by top-level key — uniform and
# windowed transformer caches, hybrid SSM+KV caches, xLSTM recurrent states.
# The serve engine uses this to scatter a freshly prefilled single-request
# cache into its slot of the batched cache (and to gather one slot back out).
CACHE_BATCH_AXIS = {
    "k": 1, "v": 1,
    "k_loc": 2, "v_loc": 2, "kpos_loc": 2, "k_glob": 1, "v_glob": 1,
    "ssm_s": 2, "ssm_conv": 2, "tail_s": 1, "tail_conv": 1,
    "m_c": 2, "m_n": 2, "m_m": 2, "s_c": 1, "s_n": 1, "s_h": 1, "s_m": 1,
}


def cache_batch_axes(cache) -> Dict[str, int]:
    """Per-leaf batch axis for a concrete cache dict (see CACHE_BATCH_AXIS)."""
    try:
        return {key: CACHE_BATCH_AXIS[key] for key in cache}
    except KeyError as err:
        raise KeyError(f"cache leaf {err.args[0]!r} has no registered batch "
                       "axis — extend models.api.CACHE_BATCH_AXIS") from None


# Leaves that become shared block pools under a paged cache — they carry no
# batch axis; everything else (ring buffers, SSM/xLSTM recurrent state) stays
# per-slot and is wiped by `reset_slot` when a slot changes owner.
PAGED_POOL_LEAVES = frozenset({"k", "v", "k_glob", "v_glob"})

# Per-slot fill values used when wiping a slot (default 0): ring position
# maps must read "empty", not "position 0".
CACHE_SLOT_FILL = {"kpos_loc": -(2 ** 30)}


def reset_slot(cache, slot):
    """Wipe one slot's per-slot state leaves (jit-traceable, `slot` dynamic).

    The paged engine calls this at admission instead of the contiguous
    engine's scatter-a-fresh-prefill: chunked prefill rebuilds the slot's
    state incrementally, so the only requirement is that no stale ring
    position or recurrent state from the previous occupant survives. Pool
    leaves and ``block_tables`` are left alone — the host-side allocator
    owns the tables, and pool blocks are only ever read through them.
    """
    out = {}
    for key, leaf in cache.items():
        if key == "block_tables" or key in PAGED_POOL_LEAVES:
            out[key] = leaf
            continue
        ax = CACHE_BATCH_AXIS[key]
        slab = jnp.full(leaf.shape[:ax] + (1,) + leaf.shape[ax + 1:],
                        CACHE_SLOT_FILL.get(key, 0), leaf.dtype)
        out[key] = jax.lax.dynamic_update_slice_in_dim(leaf, slab, slot,
                                                       axis=ax)
    return out


def copy_pool_blocks(cache, src, dst):
    """Clone pool block rows ``src`` into ``dst`` on every paged pool leaf
    (jit-traceable; ``src``/``dst`` are ``(n,) int32`` block ids).

    The copy-on-write half of prefix caching: the host allocator
    (`launch.paged.BlockPool.ensure`) retargets a writing slot's table at
    fresh blocks and queues these copies so the new blocks start as exact
    clones of the shared ones. Pool leaves put the block axis at 1 —
    ``(L, n_blocks + 1, block_size, KH, hd)`` — and every non-pool leaf
    (including ``block_tables``) passes through untouched.
    """
    out = {}
    for key, leaf in cache.items():
        if key in PAGED_POOL_LEAVES:
            out[key] = leaf.at[:, dst].set(leaf[:, src])
        else:
            out[key] = leaf
    return out
