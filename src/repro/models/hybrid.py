"""Zamba2-style hybrid: Mamba2 backbone with a shared attention block inserted
after every `attn_every` Mamba layers (weights shared across insertions).

Mamba layers are scanned in groups of `attn_every` (stacked params -> O(1) HLO in
depth); the shared-attn insertions are unrolled (there are only L/attn_every of
them). Decode carries SSM states + per-insertion KV caches.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.gemm import EXACT, GemmPolicy, dot
from . import layers as L
from . import ssm


def _group_structure(cfg: ModelConfig):
    g = cfg.attn_every
    n_full = cfg.n_layers // g
    rem = cfg.n_layers - n_full * g
    return g, n_full, rem


def init_params(cfg: ModelConfig, key):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    g, n_full, rem = _group_structure(cfg)
    ke, km, kr, ka, kf, kn = jax.random.split(key, 6)

    def init_one(k):
        kl, kb = jax.random.split(k)
        return {"ln": jnp.zeros((cfg.d_model,), dt),
                "mamba": ssm.init_mamba(kb, cfg, dt)}

    mkeys = jax.random.split(km, n_full * g).reshape(n_full, g, 2)
    grouped = jax.vmap(jax.vmap(lambda k: init_one(k)))(mkeys)
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab_size, cfg.d_model)) *
                  cfg.d_model ** -0.5).astype(dt),
        "groups": grouped,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "shared_attn": {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.hd, False, dt),
            "mlp": L.init_mlp(kf, cfg.d_model, cfg.d_ff, dt),
        },
        "lm_head": (jax.random.normal(kn, (cfg.d_model, cfg.vocab_size)) *
                    cfg.d_model ** -0.5).astype(dt),
    }
    if rem:
        rkeys = jax.random.split(kr, rem).reshape(rem, 2)
        params["tail"] = jax.vmap(lambda k: init_one(k))(rkeys)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               paged=None):
    """``paged=(n_blocks, block_size)`` turns the shared-attention KV leaves
    into block pools + a ``block_tables`` leaf (see `transformer.init_cache`);
    SSM conv/state leaves stay per-slot — recurrent state is O(1) per slot."""
    g, n_full, rem = _group_structure(cfg)
    n_attn = n_full + (1 if rem else 0)
    di = cfg.ssm_expand * cfg.d_model
    heads = di // 64
    cache = {
        "ssm_s": jnp.zeros((n_full, g, batch, heads, 64, cfg.ssm_state), jnp.float32),
        "ssm_conv": jnp.zeros((n_full, g, batch, cfg.ssm_conv - 1, di), dtype),
        "tail_s": jnp.zeros((max(rem, 1), batch, heads, 64, cfg.ssm_state), jnp.float32),
        "tail_conv": jnp.zeros((max(rem, 1), batch, cfg.ssm_conv - 1, di), dtype),
    }
    if paged is not None:
        n_blocks, blk = paged
        cache["k"] = jnp.zeros((n_attn, n_blocks + 1, blk, cfg.n_kv_heads,
                                cfg.hd), dtype)
        cache["v"] = jnp.zeros((n_attn, n_blocks + 1, blk, cfg.n_kv_heads,
                                cfg.hd), dtype)
        cache["block_tables"] = L.init_block_tables(batch, max_len, n_blocks,
                                                    blk)
    else:
        cache["k"] = jnp.zeros((n_attn, batch, max_len, cfg.n_kv_heads,
                                cfg.hd), dtype)
        cache["v"] = jnp.zeros((n_attn, batch, max_len, cfg.n_kv_heads,
                                cfg.hd), dtype)
    return cache


def _mamba_group_scan(group_params, x, cfg, policy, states, token_valid=None):
    """Scan over the `g` stacked mamba layers of one group. Training (no
    incoming state) checkpoints each layer: the SSD chunk quadratics are the
    memory hot-spot (unrematted zamba2 train measured >100 GiB/device)."""
    use_state = states is not None

    def body(x, xs):
        lp, st = xs

        def layer(lp_, x_):
            h = L.rms_norm(x_, lp_["ln"], cfg.norm_eps)
            out, new_state = ssm.mamba_block(
                lp_["mamba"], h, cfg,
                state=ssm.SSMState(st[0], st[1]) if use_state else None,
                policy=policy, layer="mamba", token_valid=token_valid)
            return x_ + out, (new_state.s, new_state.conv)

        if not use_state:
            layer = jax.checkpoint(layer)
        return layer(lp, x)

    if use_state:
        xs = (group_params, states)
    else:
        bsz, t, d = x.shape
        di = cfg.ssm_expand * d
        heads = di // 64
        g = jax.tree_util.tree_leaves(group_params)[0].shape[0]
        dummy_s = jnp.zeros((g, bsz, heads, 64, cfg.ssm_state), jnp.float32)
        dummy_c = jnp.zeros((g, bsz, cfg.ssm_conv - 1, di),
                            x.dtype)
        xs = (group_params, (dummy_s, dummy_c))
    x, new_states = jax.lax.scan(body, x, xs)
    return x, new_states


def forward(params, cfg: ModelConfig, *, tokens, cache: Optional[Dict] = None,
            cache_pos=0, positions=None, policy: GemmPolicy = EXACT,
            attn_chunk: int = 1024, batch_axes=(), q_len=None,
            paged_kernel=None):
    """`q_len` (B,) marks valid-token counts for chunked serving (trailing
    padding never advances SSM state or writes KV); a cache with a
    ``block_tables`` leaf pages the shared-attention KV through block pools
    (see `transformer.forward`)."""
    g, n_full, rem = _group_structure(cfg)
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5,
                                              params["embed"].dtype)
    x = L.constrain_batch(x, batch_axes)
    b, s, _ = x.shape
    cache_pos = jnp.asarray(cache_pos, jnp.int32)
    if positions is None:
        base = cache_pos if cache is not None else jnp.int32(0)
        offs = jnp.arange(s, dtype=jnp.int32)
        positions = base[:, None] + offs[None, :] if base.ndim else offs + base
    token_valid = None
    if q_len is not None:
        q_len = jnp.asarray(q_len, jnp.int32)
        token_valid = jnp.arange(s, dtype=jnp.int32)[None, :] < q_len[:, None]
    valid_s = s if q_len is None else q_len
    kv_valid = (cache_pos + valid_s) if cache is not None else s
    block_tables = cache.get("block_tables") if cache is not None else None
    new_cache = {k: v for k, v in cache.items()} if cache is not None else None

    def shared_attn(x, attn_idx):
        sp = params["shared_attn"]
        h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
        kv = None
        if cache is not None:
            kv = (new_cache["k"][attn_idx], new_cache["v"][attn_idx])
        out, kv_new = L.attention_block(
            sp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta, q_positions=positions,
            kv_cache=kv, cache_pos=cache_pos, kv_valid_len=kv_valid,
            causal=True, window=0, softcap=0.0, chunk=attn_chunk, policy=policy,
            layer="attn", block_tables=block_tables, token_valid=token_valid,
            paged_kernel=paged_kernel)
        x = x + out
        h = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + L.mlp_block(sp["mlp"], h, act=cfg.act, policy=policy,
                            layer="mlp")
        if cache is not None:
            new_cache["k"] = new_cache["k"].at[attn_idx].set(kv_new[0])
            new_cache["v"] = new_cache["v"].at[attn_idx].set(kv_new[1])
        return x

    for gi in range(n_full):
        gp = jax.tree.map(lambda z: z[gi], params["groups"])
        states = None
        if cache is not None:
            states = (new_cache["ssm_s"][gi], new_cache["ssm_conv"][gi])
        x, ns = _mamba_group_scan(gp, x, cfg, policy, states,
                                  token_valid=token_valid)
        if cache is not None:
            new_cache["ssm_s"] = new_cache["ssm_s"].at[gi].set(ns[0])
            new_cache["ssm_conv"] = new_cache["ssm_conv"].at[gi].set(ns[1])
        x = shared_attn(x, gi)
    if rem:
        states = None
        if cache is not None:
            states = (new_cache["tail_s"], new_cache["tail_conv"])
        x, ns = _mamba_group_scan(params["tail"], x, cfg, policy, states,
                                  token_valid=token_valid)
        if cache is not None:
            new_cache["tail_s"], new_cache["tail_conv"] = ns
        x = shared_attn(x, n_full)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache


def lm_loss(params, cfg: ModelConfig, tokens, *, policy: GemmPolicy = EXACT,
            remat: bool = True, batch_axes=(), **_):
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    hidden, _ = forward(params, cfg, tokens=inp, policy=policy,
                        batch_axes=batch_axes)
    logits = dot(hidden, L.head_weight(params, hidden.dtype), policy,
                 layer="lm_head").astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return (lse - ll).mean()


def prefill(params, cfg, tokens, cache, *, policy=EXACT, attn_chunk=1024,
            batch_axes=(), **_):
    hidden, cache = forward(params, cfg, tokens=tokens, cache=cache, cache_pos=0,
                            policy=policy, attn_chunk=attn_chunk,
                            batch_axes=batch_axes)
    logits = dot(hidden[:, -1:], L.head_weight(params, hidden.dtype), policy,
                 layer="lm_head")
    return logits.astype(jnp.float32), cache


def chunk_step(params, cfg, tokens, cache, pos, q_len, *, policy=EXACT,
               attn_chunk=1024, batch_axes=(), paged_kernel=None, **_):
    """Unified serving step over a (B, T) token block — see
    `transformer.chunk_step`. Returns each slot's last-valid-token logits."""
    pos = jnp.asarray(pos, jnp.int32)
    t = tokens.shape[1]
    positions = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    hidden, cache = forward(params, cfg, tokens=tokens, cache=cache,
                            cache_pos=pos, positions=positions, policy=policy,
                            attn_chunk=attn_chunk, batch_axes=batch_axes,
                            q_len=q_len, paged_kernel=paged_kernel)
    sel = jnp.maximum(jnp.asarray(q_len, jnp.int32) - 1, 0)
    hidden = jnp.take_along_axis(hidden, sel[:, None, None], axis=1)
    logits = dot(hidden, L.head_weight(params, hidden.dtype), policy,
                 layer="lm_head")
    return logits.astype(jnp.float32), cache


def decode_step(params, cfg, token, cache, pos, *, policy=EXACT,
                attn_chunk=1024, batch_axes=(), paged_kernel=None, **_):
    """`pos` may be a scalar (lockstep) or a (B,) per-slot position vector
    (ragged continuous batching) — see `transformer.decode_step`."""
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim else jnp.full((1,), pos, jnp.int32)
    hidden, cache = forward(params, cfg, tokens=token, cache=cache,
                            cache_pos=pos, positions=positions, policy=policy,
                            attn_chunk=attn_chunk, batch_axes=batch_axes,
                            paged_kernel=paged_kernel)
    logits = dot(hidden, L.head_weight(params, hidden.dtype), policy,
                 layer="lm_head")
    return logits.astype(jnp.float32), cache
