"""Shared neural-net layers: RMSNorm, RoPE, chunked (flash-style) GQA attention
with sliding-window / softcap support, SwiGLU MLP.

All matmuls route through the unified `core.gemm.dot` so the paper's
exact/approximate systolic backends are selectable per layer (the framework's
first-class feature) and `gemm.bind`-prepared weight leaves run
weight-stationary.
Attention is computed with an online-softmax scan over KV chunks so 32k-token
prefill never materializes an (S, S) score matrix.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.gemm import EXACT, GemmPolicy, dot

BIG_NEG = -2.3819763e38  # min bf16


def _as_batched(x, dtype=jnp.int32) -> jnp.ndarray:
    """Normalize a per-sequence vector to batched form: (S,) -> (1, S)."""
    x = jnp.asarray(x, dtype)
    return x[None, :] if x.ndim == 1 else x


def constrain_batch(x: jnp.ndarray, batch_axes) -> jnp.ndarray:
    """Pin the leading (batch) dim's sharding on activations. GSPMD otherwise
    replicates after the embedding gather (vocab-sharded table x batch-sharded
    indices), blowing per-device activation memory by the data-axis size."""
    if not batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(batch_axes), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def head_weight(params, dtype):
    """Vocab-projection weight: the untied ``lm_head`` leaf, a ``bind``-prepared
    head (present even for tied embeddings — see ``gemm.bind(tie_lm_head=)``),
    or the transposed embedding table. Raw arrays are cast to the activation
    dtype (a bf16 matmul even for an f32 checkpoint, as before the unified-dot
    migration); prepared operands pass through uncast."""
    w = params["lm_head"] if "lm_head" in params else params["embed"].T
    return w.astype(dtype) if hasattr(w, "astype") else w


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(x: jnp.ndarray, cap) -> jnp.ndarray:
    return jnp.where(cap > 0, cap * jnp.tanh(x / jnp.where(cap > 0, cap, 1.0)), x)


class AttnState(NamedTuple):
    acc: jnp.ndarray   # (B, KH, G, Sq, D) running numerator
    m: jnp.ndarray     # (B, KH, G, Sq)    running max
    l: jnp.ndarray     # (B, KH, G, Sq)    running denominator


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      q_positions: jnp.ndarray, kv_valid_len,
                      *, causal: bool = True, window=0, softcap=0.0,
                      chunk: int = 1024, q_chunk: int = 1024,
                      kv_positions=None, block_tables=None,
                      paged_kernel=None) -> jnp.ndarray:
    """Flash-style attention: outer scan over Q chunks, inner online-softmax scan
    over KV chunks — score/probability tensors never exceed
    (B, H, q_chunk, chunk), so 32k prefill fits HBM.

    q: (B, Sq, H, D); k/v: (B, Skv, KH, D) (the cache, possibly partly invalid).
    q_positions: (Sq,) global positions of the queries, or (B, Sq) per-slot
    positions (ragged continuous batching — every batch row sits at its own
    point in its own sequence). kv_valid_len: scalar or per-slot (B,) vector —
    entries at kv index >= kv_valid_len are masked (unwritten cache slots).
    kv_positions (ring caches): (Skv,) or per-slot (B, Skv). The unbatched
    forms are the lockstep degenerate case and broadcast to all rows.
    `window` may be a traced per-layer scalar; 0/negative means full attention.

    **Paged KV** (`block_tables` given): k/v are *block pools*
    ``(n_blocks + 1, block_size, KH, D)`` and ``block_tables`` is the per-slot
    ``(B, max_blocks)`` map from logical block index to pool block. Each KV
    chunk gathers only its own blocks inside the scan (storage layout is
    decoupled from the compute schedule), reconstructing exactly the
    positional layout of a contiguous cache — chunk grids, masking, and
    therefore output bits are identical to the contiguous path.

    ``paged_kernel`` (paged caches only): truthy routes the read to the
    fused Pallas kernel (`kernels.paged_attention`) that walks the block
    table *inside* the kernel — no HBM gather, per-slot early exit. The
    integer value is the flash-decoding split count: 1/True is the
    sequential scan (bit-identical to this gather path — the tests pin it),
    >1 splits the KV range with a log-sum-exp combine (tolerance-level
    parity; long contexts only). This gather path stays the interpret-mode
    reference the kernel is validated against.
    """
    if block_tables is not None and paged_kernel:
        from repro.kernels.paged_attention import paged_attention
        return paged_attention(q, k, v, block_tables, kv_valid_len,
                               q_positions, causal=causal, window=window,
                               softcap=softcap, chunk=chunk, q_chunk=q_chunk,
                               n_splits=int(paged_kernel),
                               int8_scale=CACHE_INT8_SCALE)
    b, sq, h, d = q.shape
    kh = k.shape[-2]
    g = h // kh
    scale = d ** -0.5
    qc = min(q_chunk, sq)
    nq = -(-sq // qc)
    qpad = nq * qc - sq
    qh = (q * scale).reshape(b, sq, kh, g, d).transpose(0, 2, 3, 1, 4)
    qpos = _as_batched(q_positions)                         # (Bq, Sq)
    if qpad:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, 0), (0, qpad), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, qpad)))
    qh = qh.reshape(b, kh, g, nq, qc, d).transpose(3, 0, 1, 2, 4, 5)  # NQ,B,KH,G,qc,D
    qpos_c = qpos.reshape(qpos.shape[0], nq, qc).swapaxes(0, 1)  # NQ,Bq,qc

    kvp_c = None
    if block_tables is not None:
        # paged pool: logical length = table width * block size; the chunk
        # grid must match the contiguous grid bit-for-bit, so blocks are
        # required to tile the chunk exactly
        blk_sz = k.shape[1]
        if chunk % blk_sz:
            raise ValueError(f"attention chunk {chunk} must be a multiple of "
                             f"the KV block size {blk_sz}")
        skv = block_tables.shape[1] * blk_sz
        if skv <= chunk:
            # the whole logical cache fits one KV chunk (the common serving
            # regime): one gather of the *real* blocks reconstructs the
            # contiguous layout, and the shared code path below zero-pads to
            # the chunk grid — bit-identical to a contiguous cache (padding
            # is masked either way) at a fraction of the dump-padded
            # per-chunk gather cost
            k = jnp.take(k, block_tables, axis=0).reshape(b, skv, kh, d)
            v = jnp.take(v, block_tables, axis=0).reshape(b, skv, kh, d)
            block_tables = None
        else:
            nk = -(-skv // chunk)
            nbpc = chunk // blk_sz
            pad_b = nk * nbpc - block_tables.shape[1]
            bt = block_tables
            if pad_b:   # pad with the dump block — masked like zero-pad
                bt = jnp.pad(bt, ((0, 0), (0, pad_b)),
                             constant_values=k.shape[0] - 1)
            bt_c = bt.reshape(b, nk, nbpc).swapaxes(0, 1)    # NK,B,nbpc
    if block_tables is None:
        skv = k.shape[1]
        nk = -(-skv // chunk)
        kpad = nk * chunk - skv
        if kv_positions is not None:
            kv_positions = _as_batched(kv_positions)        # (Bk, Skv)
        if kpad:
            k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
            if kv_positions is not None:
                kv_positions = jnp.pad(kv_positions, ((0, 0), (0, kpad)),
                                       constant_values=-(10 ** 9))
        kc = k.reshape(b, nk, chunk, kh, d).transpose(1, 0, 3, 2, 4)  # NK,B,KH,C,D
        vc = v.reshape(b, nk, chunk, kh, d).transpose(1, 0, 3, 2, 4)
        kvp_c = (kv_positions.reshape(kv_positions.shape[0], nk, chunk)
                 .swapaxes(0, 1) if kv_positions is not None else None)  # NK,Bk,C
    kv_len = jnp.asarray(kv_valid_len, jnp.int32).reshape(-1)   # (1,) or (B,)
    window_eff = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window),
                           jnp.iinfo(jnp.int32).max).astype(jnp.int32)

    def q_body(_, q_in):
        q_blk, qp = q_in                               # (B,KH,G,qc,D), (Bq,qc)

        def kv_body(state: AttnState, kv_in):
            if block_tables is not None:
                idx, bt_blk = kv_in
                kg = jnp.take(k, bt_blk, axis=0)       # (B,nbpc,blk,KH,D)
                k_blk = kg.reshape(b, chunk, kh, d).transpose(0, 2, 1, 3)
                vg = jnp.take(v, bt_blk, axis=0)
                v_blk = vg.reshape(b, chunk, kh, d).transpose(0, 2, 1, 3)
                kp = None
            else:
                idx, k_blk, v_blk, kp = kv_in
            kpos = (kp if kvp_c is not None            # (Bk, C)
                    else (idx * chunk
                          + jnp.arange(chunk, dtype=jnp.int32))[None, :])
            s = jnp.einsum("bkgqd,bkcd->bkgqc", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32))
            s = _softcap(s, softcap)
            if kvp_c is not None:
                valid = (kpos[:, None, :] >= 0)   # ring slots carry positions
            else:
                valid = (kpos[:, None, :] < kv_len[:, None, None])
            if causal:
                delta = qp[:, :, None] - kpos[:, None, :]  # (B*, qc, C)
                valid = valid & (delta >= 0) & (delta < window_eff)
            else:
                valid = jnp.broadcast_to(valid,
                                         (valid.shape[0], qc, chunk))
            s = jnp.where(valid[:, None, None], s, BIG_NEG)
            m_new = jnp.maximum(state.m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(state.m - m_new)
            l_new = state.l * corr + p.sum(axis=-1)
            acc_new = state.acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, v_blk.astype(jnp.float32))
            return AttnState(acc_new, m_new, l_new), None

        init = AttnState(
            jnp.zeros((b, kh, g, qc, d), jnp.float32),
            jnp.full((b, kh, g, qc), BIG_NEG, jnp.float32),
            jnp.zeros((b, kh, g, qc), jnp.float32),
        )
        idxs = jnp.arange(nk, dtype=jnp.int32)
        if block_tables is not None:
            xs = (idxs, bt_c)
        else:
            kvp_xs = kvp_c if kvp_c is not None else jnp.zeros((nk, 1, chunk),
                                                               jnp.int32)
            xs = (idxs, kc, vc, kvp_xs)
        # checkpoint the chunk body: backward recomputes each chunk's scores
        # instead of saving O(S^2/chunk) probability residuals (flash backward)
        st, _ = jax.lax.scan(jax.checkpoint(kv_body), init, xs)
        out = st.acc / jnp.maximum(st.l, 1e-30)[..., None]  # (B,KH,G,qc,D)
        return None, out

    _, out_c = jax.lax.scan(q_body, None, (qh, qpos_c))     # (NQ,B,KH,G,qc,D)
    out = out_c.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * qc, h, d)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# KV-cache payload helpers (optional int8 storage: the paper's low-precision
# insight applied to cache bandwidth — 2x HBM traffic reduction on decode)
# ---------------------------------------------------------------------------

CACHE_INT8_SCALE = 32.0


def init_block_tables(batch: int, max_len: int, n_blocks: int,
                      block_size: int) -> jnp.ndarray:
    """The per-slot block-table leaf of a paged cache: ``(batch,
    ceil(max_len / block_size))`` int32, every entry initialized to the dump
    index ``n_blocks`` (the pool's scratch row). One definition so every
    family's ``init_cache`` and `launch.paged.BlockPool` share the same
    width and sentinel convention."""
    return jnp.full((batch, -(-max_len // block_size)), n_blocks, jnp.int32)


def cache_store(x: jnp.ndarray, dtype) -> jnp.ndarray:
    if dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * CACHE_INT8_SCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def cache_load(x: jnp.ndarray) -> jnp.ndarray:
    if x.dtype == jnp.int8:
        return x.astype(jnp.float32) / CACHE_INT8_SCALE
    return x


def ring_write(ck, cv, kpos, k_new, v_new, cache_pos, window: int,
               valid=None):
    """Write new K/V into a ring buffer of size `window`.

    ck/cv: (B, W, KH, D); kpos: (B, W) positions held by each row's slots
    (-2^30 if empty — per-slot rows so ragged batches track their own rings).
    Decode (sq=1): slot = pos % W per batch row; `cache_pos` may be a scalar
    (lockstep) or a (B,) per-slot vector, and `valid` an optional (B,) bool
    mask — rows with `valid=False` (padded chunk tokens, inactive slots)
    leave their ring untouched. Prefill (sq=S): scalar `cache_pos`;
    requires S % W == 0 or S <= W — the last W entries land contiguously
    because S % W == 0.
    """
    b, sq = k_new.shape[0], k_new.shape[1]
    cp = jnp.asarray(cache_pos, jnp.int32)
    if sq == 1:
        posv = cp if cp.ndim else jnp.full((b,), cp)        # (B,)
        slot = jnp.mod(posv, window)
        bidx = jnp.arange(b)
        new_k = cache_store(k_new[:, 0], ck.dtype)
        new_v = cache_store(v_new[:, 0], cv.dtype)
        new_p = posv
        if valid is not None:
            new_k = jnp.where(valid[:, None, None], new_k, ck[bidx, slot])
            new_v = jnp.where(valid[:, None, None], new_v, cv[bidx, slot])
            new_p = jnp.where(valid, posv, kpos[bidx, slot])
        ck = ck.at[bidx, slot].set(new_k)
        cv = cv.at[bidx, slot].set(new_v)
        kpos = kpos.at[bidx, slot].set(new_p)
        return ck, cv, kpos
    w = ck.shape[1]
    if sq < w:
        # prefill shorter than the window (starts at slot cache_pos % w == 0)
        ck = jax.lax.dynamic_update_slice(
            ck, cache_store(k_new, ck.dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, cache_store(v_new, cv.dtype), (0, 0, 0, 0))
        newpos = jnp.arange(sq, dtype=jnp.int32) + cp
        kpos = jax.lax.dynamic_update_slice(
            kpos, jnp.broadcast_to(newpos, (b, sq)), (0, 0))
        return ck, cv, kpos
    # sq >= w: the last w tokens land at slots ((start + j) % w) — a roll
    start = cp + sq - w
    shift = jnp.mod(start, w)
    ck = jnp.roll(cache_store(k_new[:, -w:], ck.dtype), shift, axis=1)
    cv = jnp.roll(cache_store(v_new[:, -w:], cv.dtype), shift, axis=1)
    kpos = jnp.broadcast_to(
        start + jnp.mod(jnp.arange(w, dtype=jnp.int32) - shift, w), (b, w))
    return ck, cv, kpos


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
                   qkv_bias: bool, dtype):
    ks = jax.random.split(key, 4)
    std = d_model ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads * head_dim)) * std).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_kv_heads * head_dim)) * std).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_kv_heads * head_dim)) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads * head_dim, d_model)) * std).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def attention_block(p, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                    q_positions, kv_cache=None, ring_cache=None, cache_pos=None,
                    kv_valid_len=None, causal=True, window=0, softcap=0.0,
                    chunk=1024, policy: GemmPolicy = EXACT, layer: str = "",
                    block_tables=None, token_valid=None, paged_kernel=None):
    """GQA attention.

    kv_cache=(k, v): uniform cache — new K/V written at cache_pos, attention
    over the (possibly int8) cache. With `block_tables` the uniform cache is
    a *paged block pool* ``(n_blocks + 1, block_size, KH, D)``: writes
    scatter to per-slot ``(block, offset)`` pairs (masked tokens land in the
    dump block, pool index ``n_blocks``), reads gather through the table in
    `chunked_attention`. ring_cache=(k, v, kpos): windowed ring buffer of
    size `window` — decode attends over the ring via per-slot positions;
    serving prefill (sq > 1 with a ring) advances the ring token by token so
    any chunking of the prompt writes and reads the same ring states.
    Returns (out, new_cache_or_ring).

    `q_positions` may be (Sq,) or per-slot (B, Sq); `cache_pos` and
    `kv_valid_len` may be scalars (lockstep decode — the whole batch at one
    position) or (B,) vectors (ragged continuous batching — each batch row
    writes and masks its own cache length). Scalar and all-equal-vector
    forms are bit-identical. `token_valid` is an optional (B, Sq) bool mask
    for chunked-prefill padding: invalid tokens never write cache state.
    """
    b, sq, _ = x.shape
    q = dot(x, p["wq"], policy, layer=layer + "/wq")
    k = dot(x, p["wk"], policy, layer=layer + "/wk")
    v = dot(x, p["wv"], policy, layer=layer + "/wv")
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, sq, n_heads, head_dim)
    k = k.reshape(b, sq, n_kv_heads, head_dim)
    v = v.reshape(b, sq, n_kv_heads, head_dim)
    q = rope(q, q_positions, rope_theta)
    k = rope(k, q_positions, rope_theta)

    if ring_cache is not None:
        ck, cv, kpos = ring_cache
        w = ck.shape[1]
        if sq == 1:   # decode: attend over the ring (positions per slot)
            val = token_valid[:, 0] if token_valid is not None else None
            ck, cv, kpos = ring_write(ck, cv, kpos, k, v, cache_pos, w,
                                      valid=val)
            out = chunked_attention(q, cache_load(ck), cache_load(cv),
                                    q_positions, w, causal=causal, window=window,
                                    softcap=softcap, chunk=min(chunk, w),
                                    kv_positions=kpos)
        else:
            # serving prefill: advance the ring one token at a time — each
            # step is exactly the decode step's write + ring attention, so a
            # prompt fed in chunks of any size (the chunked-prefill admission
            # path) reaches bit-identical ring states and outputs
            qpos = _as_batched(q_positions)
            qpos = jnp.broadcast_to(qpos, (b, sq))
            val = (token_valid if token_valid is not None
                   else jnp.ones((b, sq), bool))

            def tok_body(carry, xs_t):
                ck, cv, kpos = carry
                k_t, v_t, q_t, qp_t, val_t = xs_t
                ck, cv, kpos = ring_write(ck, cv, kpos, k_t[:, None],
                                          v_t[:, None], qp_t, w, valid=val_t)
                out_t = chunked_attention(
                    q_t[:, None], cache_load(ck), cache_load(cv), qp_t[:, None],
                    w, causal=causal, window=window, softcap=softcap,
                    chunk=min(chunk, w), kv_positions=kpos)
                return (ck, cv, kpos), out_t[:, 0]

            (ck, cv, kpos), outs = jax.lax.scan(
                tok_body, (ck, cv, kpos),
                (k.swapaxes(0, 1), v.swapaxes(0, 1), q.swapaxes(0, 1),
                 qpos.T, val.T))
            out = outs.swapaxes(0, 1)                       # (B, Sq, H, D)
        out = out.reshape(b, sq, n_heads * head_dim)
        return dot(out, p["wo"], policy, layer=layer + "/wo"), (ck, cv, kpos)

    if kv_cache is not None:
        ck, cv = kv_cache
        cp = jnp.asarray(cache_pos, jnp.int32)
        if block_tables is not None:
            # paged write: token at logical position p lands in pool block
            # block_tables[b, p // bs] at offset p % bs; masked tokens are
            # redirected to the dump block (pool row n_blocks) so they can
            # never touch another slot's storage
            blk_sz = ck.shape[1]
            cpv = cp if cp.ndim else jnp.full((b,), cp)
            idx = cpv[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
            lblk = jnp.minimum(idx // blk_sz, block_tables.shape[1] - 1)
            blk = jnp.take_along_axis(block_tables, lblk, axis=1)
            off = jnp.mod(idx, blk_sz)
            if token_valid is not None:
                blk = jnp.where(token_valid, blk, ck.shape[0] - 1)
            ck = ck.at[blk, off].set(cache_store(k, ck.dtype))
            cv = cv.at[blk, off].set(cache_store(v, cv.dtype))
            new_cache = (ck, cv)
            valid = kv_valid_len if kv_valid_len is not None else cp + sq
            # fused-kernel reads take the raw pools — int8 payloads are
            # dequantized block by block in VMEM, never as a full-pool copy
            ka, va = (ck, cv) if paged_kernel else (cache_load(ck),
                                                    cache_load(cv))
            out = chunked_attention(q, ka, va,
                                    q_positions, valid, causal=causal,
                                    window=window, softcap=softcap, chunk=chunk,
                                    block_tables=block_tables,
                                    paged_kernel=paged_kernel)
            out = out.reshape(b, sq, n_heads * head_dim)
            return dot(out, p["wo"], policy, layer=layer + "/wo"), new_cache
        if cp.ndim:         # per-slot scatter: row i writes at its own cp[i]
            bidx = jnp.arange(b)[:, None]
            sidx = cp[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
            new_k = cache_store(k, ck.dtype)
            new_v = cache_store(v, cv.dtype)
            if token_valid is not None:
                new_k = jnp.where(token_valid[..., None, None], new_k,
                                  ck[bidx, sidx])
                new_v = jnp.where(token_valid[..., None, None], new_v,
                                  cv[bidx, sidx])
            ck = ck.at[bidx, sidx].set(new_k)
            cv = cv.at[bidx, sidx].set(new_v)
        else:
            ck = jax.lax.dynamic_update_slice(ck, cache_store(k, ck.dtype),
                                              (0, cp, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, cache_store(v, cv.dtype),
                                              (0, cp, 0, 0))
        new_cache = (ck, cv)
        k_all, v_all = cache_load(ck), cache_load(cv)
        valid = kv_valid_len if kv_valid_len is not None else cache_pos + sq
    else:
        new_cache = None
        k_all, v_all = k, v
        valid = sq
    out = chunked_attention(q, k_all, v_all, q_positions, valid, causal=causal,
                            window=window, softcap=softcap, chunk=chunk)
    out = out.reshape(b, sq, n_heads * head_dim)
    return dot(out, p["wo"], policy, layer=layer + "/wo"), new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    std = d_model ** -0.5
    return {
        "w1": (jax.random.normal(ks[0], (d_model, d_ff)) * std).astype(dtype),
        "w3": (jax.random.normal(ks[1], (d_model, d_ff)) * std).astype(dtype),
        "w2": (jax.random.normal(ks[2], (d_ff, d_model)) * (d_ff ** -0.5)).astype(dtype),
    }


def mlp_block(p, x, *, act: str = "silu", policy: GemmPolicy = EXACT,
              layer: str = ""):
    h1 = dot(x, p["w1"], policy, layer=layer + "/w1")
    h3 = dot(x, p["w3"], policy, layer=layer + "/w3")
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    return dot(actf(h1) * h3, p["w2"], policy, layer=layer + "/w2")
