"""Shared neural-net layers: RMSNorm, RoPE, chunked (flash-style) GQA attention
with sliding-window / softcap support, SwiGLU MLP.

All matmuls route through the unified `core.gemm.dot` so the paper's
exact/approximate systolic backends are selectable per layer (the framework's
first-class feature) and `gemm.bind`-prepared weight leaves run
weight-stationary.
Attention is computed with an online-softmax scan over KV chunks so 32k-token
prefill never materializes an (S, S) score matrix.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.gemm import EXACT, GemmPolicy, dot

BIG_NEG = -2.3819763e38  # min bf16


def _as_batched(x, dtype=jnp.int32) -> jnp.ndarray:
    """Normalize a per-sequence vector to batched form: (S,) -> (1, S)."""
    x = jnp.asarray(x, dtype)
    return x[None, :] if x.ndim == 1 else x


def constrain_batch(x: jnp.ndarray, batch_axes) -> jnp.ndarray:
    """Pin the leading (batch) dim's sharding on activations. GSPMD otherwise
    replicates after the embedding gather (vocab-sharded table x batch-sharded
    indices), blowing per-device activation memory by the data-axis size."""
    if not batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(batch_axes), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def head_weight(params, dtype):
    """Vocab-projection weight: the untied ``lm_head`` leaf, a ``bind``-prepared
    head (present even for tied embeddings — see ``gemm.bind(tie_lm_head=)``),
    or the transposed embedding table. Raw arrays are cast to the activation
    dtype (a bf16 matmul even for an f32 checkpoint, as before the unified-dot
    migration); prepared operands pass through uncast."""
    w = params["lm_head"] if "lm_head" in params else params["embed"].T
    return w.astype(dtype) if hasattr(w, "astype") else w


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(x: jnp.ndarray, cap) -> jnp.ndarray:
    return jnp.where(cap > 0, cap * jnp.tanh(x / jnp.where(cap > 0, cap, 1.0)), x)


class AttnState(NamedTuple):
    acc: jnp.ndarray   # (B, KH, G, Sq, D) running numerator
    m: jnp.ndarray     # (B, KH, G, Sq)    running max
    l: jnp.ndarray     # (B, KH, G, Sq)    running denominator


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      q_positions: jnp.ndarray, kv_valid_len,
                      *, causal: bool = True, window=0, softcap=0.0,
                      chunk: int = 1024, q_chunk: int = 1024,
                      kv_positions=None) -> jnp.ndarray:
    """Flash-style attention: outer scan over Q chunks, inner online-softmax scan
    over KV chunks — score/probability tensors never exceed
    (B, H, q_chunk, chunk), so 32k prefill fits HBM.

    q: (B, Sq, H, D); k/v: (B, Skv, KH, D) (the cache, possibly partly invalid).
    q_positions: (Sq,) global positions of the queries, or (B, Sq) per-slot
    positions (ragged continuous batching — every batch row sits at its own
    point in its own sequence). kv_valid_len: scalar or per-slot (B,) vector —
    entries at kv index >= kv_valid_len are masked (unwritten cache slots).
    kv_positions (ring caches): (Skv,) or per-slot (B, Skv). The unbatched
    forms are the lockstep degenerate case and broadcast to all rows.
    `window` may be a traced per-layer scalar; 0/negative means full attention.
    """
    b, sq, h, d = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    scale = d ** -0.5
    qc = min(q_chunk, sq)
    nq = -(-sq // qc)
    qpad = nq * qc - sq
    qh = (q * scale).reshape(b, sq, kh, g, d).transpose(0, 2, 3, 1, 4)
    qpos = _as_batched(q_positions)                         # (Bq, Sq)
    if qpad:
        qh = jnp.pad(qh, ((0, 0), (0, 0), (0, 0), (0, qpad), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, qpad)))
    qh = qh.reshape(b, kh, g, nq, qc, d).transpose(3, 0, 1, 2, 4, 5)  # NQ,B,KH,G,qc,D
    qpos_c = qpos.reshape(qpos.shape[0], nq, qc).swapaxes(0, 1)  # NQ,Bq,qc

    nk = -(-skv // chunk)
    kpad = nk * chunk - skv
    if kv_positions is not None:
        kv_positions = _as_batched(kv_positions)            # (Bk, Skv)
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        if kv_positions is not None:
            kv_positions = jnp.pad(kv_positions, ((0, 0), (0, kpad)),
                                   constant_values=-(10 ** 9))
    kc = k.reshape(b, nk, chunk, kh, d).transpose(1, 0, 3, 2, 4)      # NK,B,KH,C,D
    vc = v.reshape(b, nk, chunk, kh, d).transpose(1, 0, 3, 2, 4)
    kvp_c = (kv_positions.reshape(kv_positions.shape[0], nk, chunk)
             .swapaxes(0, 1) if kv_positions is not None else None)  # NK,Bk,C
    kv_len = jnp.asarray(kv_valid_len, jnp.int32).reshape(-1)   # (1,) or (B,)
    window_eff = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window),
                           jnp.iinfo(jnp.int32).max).astype(jnp.int32)

    def q_body(_, q_in):
        q_blk, qp = q_in                               # (B,KH,G,qc,D), (Bq,qc)

        def kv_body(state: AttnState, kv_in):
            idx, k_blk, v_blk, kp = kv_in
            kpos = (kp if kvp_c is not None            # (Bk, C)
                    else (idx * chunk
                          + jnp.arange(chunk, dtype=jnp.int32))[None, :])
            s = jnp.einsum("bkgqd,bkcd->bkgqc", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32))
            s = _softcap(s, softcap)
            if kvp_c is not None:
                valid = (kpos[:, None, :] >= 0)   # ring slots carry positions
            else:
                valid = (kpos[:, None, :] < kv_len[:, None, None])
            if causal:
                delta = qp[:, :, None] - kpos[:, None, :]  # (B*, qc, C)
                valid = valid & (delta >= 0) & (delta < window_eff)
            else:
                valid = jnp.broadcast_to(valid,
                                         (valid.shape[0], qc, chunk))
            s = jnp.where(valid[:, None, None], s, BIG_NEG)
            m_new = jnp.maximum(state.m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(state.m - m_new)
            l_new = state.l * corr + p.sum(axis=-1)
            acc_new = state.acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, v_blk.astype(jnp.float32))
            return AttnState(acc_new, m_new, l_new), None

        init = AttnState(
            jnp.zeros((b, kh, g, qc, d), jnp.float32),
            jnp.full((b, kh, g, qc), BIG_NEG, jnp.float32),
            jnp.zeros((b, kh, g, qc), jnp.float32),
        )
        idxs = jnp.arange(nk, dtype=jnp.int32)
        kvp_xs = kvp_c if kvp_c is not None else jnp.zeros((nk, 1, chunk),
                                                           jnp.int32)
        # checkpoint the chunk body: backward recomputes each chunk's scores
        # instead of saving O(S^2/chunk) probability residuals (flash backward)
        st, _ = jax.lax.scan(jax.checkpoint(kv_body), init,
                             (idxs, kc, vc, kvp_xs))
        out = st.acc / jnp.maximum(st.l, 1e-30)[..., None]  # (B,KH,G,qc,D)
        return None, out

    _, out_c = jax.lax.scan(q_body, None, (qh, qpos_c))     # (NQ,B,KH,G,qc,D)
    out = out_c.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * qc, h, d)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# KV-cache payload helpers (optional int8 storage: the paper's low-precision
# insight applied to cache bandwidth — 2x HBM traffic reduction on decode)
# ---------------------------------------------------------------------------

CACHE_INT8_SCALE = 32.0


def cache_store(x: jnp.ndarray, dtype) -> jnp.ndarray:
    if dtype == jnp.int8:
        return jnp.clip(jnp.round(x.astype(jnp.float32) * CACHE_INT8_SCALE),
                        -127, 127).astype(jnp.int8)
    return x.astype(dtype)


def cache_load(x: jnp.ndarray) -> jnp.ndarray:
    if x.dtype == jnp.int8:
        return x.astype(jnp.float32) / CACHE_INT8_SCALE
    return x


def ring_write(ck, cv, kpos, k_new, v_new, cache_pos, window: int):
    """Write new K/V into a ring buffer of size `window`.

    ck/cv: (B, W, KH, D); kpos: (B, W) positions held by each row's slots
    (-2^30 if empty — per-slot rows so ragged batches track their own rings).
    Decode (sq=1): slot = pos % W per batch row; `cache_pos` may be a scalar
    (lockstep) or a (B,) per-slot vector. Prefill (sq=S): scalar `cache_pos`;
    requires S % W == 0 or S <= W — the last W entries land contiguously
    because S % W == 0.
    """
    b, sq = k_new.shape[0], k_new.shape[1]
    cp = jnp.asarray(cache_pos, jnp.int32)
    if sq == 1:
        posv = cp if cp.ndim else jnp.full((b,), cp)        # (B,)
        slot = jnp.mod(posv, window)
        bidx = jnp.arange(b)
        ck = ck.at[bidx, slot].set(cache_store(k_new[:, 0], ck.dtype))
        cv = cv.at[bidx, slot].set(cache_store(v_new[:, 0], cv.dtype))
        kpos = kpos.at[bidx, slot].set(posv)
        return ck, cv, kpos
    w = ck.shape[1]
    if sq < w:
        # prefill shorter than the window (starts at slot cache_pos % w == 0)
        ck = jax.lax.dynamic_update_slice(
            ck, cache_store(k_new, ck.dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, cache_store(v_new, cv.dtype), (0, 0, 0, 0))
        newpos = jnp.arange(sq, dtype=jnp.int32) + cp
        kpos = jax.lax.dynamic_update_slice(
            kpos, jnp.broadcast_to(newpos, (b, sq)), (0, 0))
        return ck, cv, kpos
    # sq >= w: the last w tokens land at slots ((start + j) % w) — a roll
    start = cp + sq - w
    shift = jnp.mod(start, w)
    ck = jnp.roll(cache_store(k_new[:, -w:], ck.dtype), shift, axis=1)
    cv = jnp.roll(cache_store(v_new[:, -w:], cv.dtype), shift, axis=1)
    kpos = jnp.broadcast_to(
        start + jnp.mod(jnp.arange(w, dtype=jnp.int32) - shift, w), (b, w))
    return ck, cv, kpos


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
                   qkv_bias: bool, dtype):
    ks = jax.random.split(key, 4)
    std = d_model ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads * head_dim)) * std).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_kv_heads * head_dim)) * std).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_kv_heads * head_dim)) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads * head_dim, d_model)) * std).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def attention_block(p, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                    q_positions, kv_cache=None, ring_cache=None, cache_pos=None,
                    kv_valid_len=None, causal=True, window=0, softcap=0.0,
                    chunk=1024, policy: GemmPolicy = EXACT, layer: str = ""):
    """GQA attention.

    kv_cache=(k, v): uniform cache — new K/V written at cache_pos, attention
    over the (possibly int8) cache. ring_cache=(k, v, kpos): windowed ring
    buffer of size `window` — decode attends over the ring via per-slot
    positions; prefill attends in-sequence and then fills the ring with the
    last `window` K/V. Returns (out, new_cache_or_ring).

    `q_positions` may be (Sq,) or per-slot (B, Sq); `cache_pos` and
    `kv_valid_len` may be scalars (lockstep decode — the whole batch at one
    position) or (B,) vectors (ragged continuous batching — each batch row
    writes and masks its own cache length). Scalar and all-equal-vector
    forms are bit-identical.
    """
    b, sq, _ = x.shape
    q = dot(x, p["wq"], policy, layer=layer + "/wq")
    k = dot(x, p["wk"], policy, layer=layer + "/wk")
    v = dot(x, p["wv"], policy, layer=layer + "/wv")
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, sq, n_heads, head_dim)
    k = k.reshape(b, sq, n_kv_heads, head_dim)
    v = v.reshape(b, sq, n_kv_heads, head_dim)
    q = rope(q, q_positions, rope_theta)
    k = rope(k, q_positions, rope_theta)

    if ring_cache is not None:
        ck, cv, kpos = ring_cache
        w = ck.shape[1]
        ck, cv, kpos = ring_write(ck, cv, kpos, k, v, cache_pos, w)
        if sq == 1:   # decode: attend over the ring (positions per slot)
            out = chunked_attention(q, cache_load(ck), cache_load(cv),
                                    q_positions, w, causal=causal, window=window,
                                    softcap=softcap, chunk=min(chunk, w),
                                    kv_positions=kpos)
        else:         # prefill: attend in-sequence under the window mask
            out = chunked_attention(q, k, v, q_positions, sq, causal=causal,
                                    window=window, softcap=softcap, chunk=chunk)
        out = out.reshape(b, sq, n_heads * head_dim)
        return dot(out, p["wo"], policy, layer=layer + "/wo"), (ck, cv, kpos)

    if kv_cache is not None:
        ck, cv = kv_cache
        cp = jnp.asarray(cache_pos, jnp.int32)
        if cp.ndim:         # per-slot scatter: row i writes at its own cp[i]
            bidx = jnp.arange(b)[:, None]
            sidx = cp[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
            ck = ck.at[bidx, sidx].set(cache_store(k, ck.dtype))
            cv = cv.at[bidx, sidx].set(cache_store(v, cv.dtype))
        else:
            ck = jax.lax.dynamic_update_slice(ck, cache_store(k, ck.dtype),
                                              (0, cp, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, cache_store(v, cv.dtype),
                                              (0, cp, 0, 0))
        new_cache = (ck, cv)
        k_all, v_all = cache_load(ck), cache_load(cv)
        valid = kv_valid_len if kv_valid_len is not None else cache_pos + sq
    else:
        new_cache = None
        k_all, v_all = k, v
        valid = sq
    out = chunked_attention(q, k_all, v_all, q_positions, valid, causal=causal,
                            window=window, softcap=softcap, chunk=chunk)
    out = out.reshape(b, sq, n_heads * head_dim)
    return dot(out, p["wo"], policy, layer=layer + "/wo"), new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    std = d_model ** -0.5
    return {
        "w1": (jax.random.normal(ks[0], (d_model, d_ff)) * std).astype(dtype),
        "w3": (jax.random.normal(ks[1], (d_model, d_ff)) * std).astype(dtype),
        "w2": (jax.random.normal(ks[2], (d_ff, d_model)) * (d_ff ** -0.5)).astype(dtype),
    }


def mlp_block(p, x, *, act: str = "silu", policy: GemmPolicy = EXACT,
              layer: str = ""):
    h1 = dot(x, p["w1"], policy, layer=layer + "/w1")
    h3 = dot(x, p["w3"], policy, layer=layer + "/w3")
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    return dot(actf(h1) * h3, p["w2"], policy, layer=layer + "/w2")
