"""Mixture-of-Experts block with sort-based capacity dispatch (EP-shardable).

Dispatch: flatten (token, top-k slot) assignments, compute each assignment's
position within its expert via a cumsum over expert one-hots, drop assignments
beyond capacity, scatter token activations into an (E, C, d) buffer, run the
expert FFNs as a single batched einsum (expert dim shardable over the `model`
mesh axis = expert parallelism), and combine back weighted by router probs.

HLO FLOPs scale with E*C*d*ff where E*C ~= tokens*topk*capacity_factor, i.e.
with *active* experts — so the roofline's MODEL_FLOPS/HLO_FLOPs ratio stays
honest for MoE archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gemm import EXACT, GemmPolicy, dot
from repro.configs.base import ModelConfig


def init_moe(key, cfg: ModelConfig, dtype):
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * std).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d, ff)) * std).astype(dtype),
        "w3": (jax.random.normal(ks[2], (e, d, ff)) * std).astype(dtype),
        "w2": (jax.random.normal(ks[3], (e, ff, d)) * (ff ** -0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        sff = cfg.moe_d_ff * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": (jax.random.normal(kss[0], (d, sff)) * std).astype(dtype),
            "w3": (jax.random.normal(kss[1], (d, sff)) * std).astype(dtype),
            "w2": (jax.random.normal(kss[2], (sff, d)) * (sff ** -0.5)).astype(dtype),
        }
    return p


def moe_block(p, x, cfg: ModelConfig, *, policy: GemmPolicy = EXACT,
              layer: str = "", full_capacity: bool = False):
    """x: (B, S, d) -> (B, S, d). Returns (out, aux_loss).

    Decode (S == 1) — and any serving call (`full_capacity=True`, set by the
    model forwards whenever a KV/recurrent cache is live) — runs at full
    capacity: a capacity drop depends on the flattened (token, expert)
    cumsum over the *whole* batch, so it would make one request's output
    depend on which other requests (or which prompt-chunk boundaries) happen
    to share its dispatch — continuous batching and chunked prefill need
    each token's output to be batch- and chunking-independent. Training
    keeps the capacity-factor drop semantics.
    """
    b, s, d = x.shape
    t = b * s
    e, topk = cfg.n_experts, cfg.n_active_experts
    cap = t if (s == 1 or full_capacity) \
        else int(t * topk / e * cfg.capacity_factor) + 1

    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p["router"]                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, topk)                      # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[top_e[:, 0]].add(1.0) / t
    aux = e * jnp.sum(me * ce)

    flat_e = top_e.reshape(-1)                                      # (T*K,)
    flat_p = top_p.reshape(-1)
    # position of each assignment within its expert (dense cumsum over one-hots)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)             # (T*K, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)                # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    dest = jnp.where(keep, flat_e * cap + pos, e * cap)             # drop -> OOB

    tok_idx = jnp.repeat(jnp.arange(t), topk)
    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[dest].add(xf[tok_idx])
    buf = buf[:-1].reshape(e, cap, d)

    # expert FFN: grouped (E, C, d) x (E, d, f) GEMMs through the policy —
    # per-expert quantization/preparation under approximate backends, a plain
    # batched matmul under `exact` (identical to the previous einsums)
    h1 = dot(buf, p["w1"], policy, layer=layer + "/w1", grouped=True)
    h3 = dot(buf, p["w3"], policy, layer=layer + "/w3", grouped=True)
    hidden = jax.nn.silu(h1) * h3
    out_e = dot(hidden, p["w2"], policy, layer=layer + "/w2",
                grouped=True)                                       # (E, C, d)

    flat_out = out_e.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], flat_out[jnp.minimum(dest, e * cap - 1)], 0)
    contrib = gathered * flat_p[:, None].astype(gathered.dtype)
    # combine each token's top-k contributions with a fixed association order
    # (token-major reshape + axis sum) — a scatter-add over tok_idx leaves the
    # f32 summation order to the backend, which is shape-dependent and would
    # break bit-parity between lockstep and ragged-batch decode
    combined = contrib.reshape(t, topk, d).sum(axis=1)
    out = combined.reshape(b, s, d).astype(x.dtype)

    if "shared" in p:
        from .layers import mlp_block
        out = out + mlp_block(p["shared"], x, policy=policy, layer=layer + "/shared")
    return out, aux
