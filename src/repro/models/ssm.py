"""Mamba2 (SSD) block — chunked state-space dual form.

Per head: state S (P x N) evolves as S_t = exp(dt_t * A) * S_{t-1} + dt_t *
x_t B_t^T; output y_t = S_t C_t. The chunked SSD algorithm computes within-chunk
interactions with a masked quadratic form and carries the state across chunks
with a scan — linear in sequence length, which is what makes zamba2/long_500k
runnable (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gemm import EXACT, GemmPolicy, dot
from repro.configs.base import ModelConfig


class SSMState(NamedTuple):
    s: jnp.ndarray       # (B, H, P, N) running state
    conv: jnp.ndarray    # (B, conv_w-1, d_inner) conv tail for decode


def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    heads = di // 64                      # head dim P = 64
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        # in_proj -> [z (di), x (di), B (H*N? use shared B/C per head group: H,N), C, dt (H)]
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di + 2 * heads * n + heads)) * std).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di)) * 0.1).astype(dtype),
        "a_log": jnp.zeros((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "norm_w": jnp.zeros((di,), dtype),
        "out_proj": (jax.random.normal(ks[2], (di, d)) * (di ** -0.5)).astype(dtype),
    }


def _ssd_chunked(x, dt, a_log, b_mat, c_mat, s0, chunk: int):
    """Chunked SSD. x: (B,T,H,P), dt: (B,T,H), b/c: (B,T,H,N), s0: (B,H,P,N).
    Returns (y, s_final)."""
    bsz, t, h, p = x.shape
    n = b_mat.shape[-1]
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    a = -jnp.exp(a_log)                                   # (H,) negative decay rate

    def reshape_c(z):
        return z.reshape(bsz, nc, chunk, *z.shape[2:]).swapaxes(0, 1)

    xc, dtc, bc, cc = map(reshape_c, (x, dt, b_mat, c_mat))

    def body(s, inp):
        xk, dtk, bk, ck = inp                             # (B,C,H,P), (B,C,H), ...
        da = dtk * a[None, None, :]                       # (B,C,H) log-decay per step
        cum = jnp.cumsum(da, axis=1)                      # inclusive
        # within-chunk quadratic form: L[i,j] = exp(cum_i - cum_j) for i >= j
        li = cum[:, :, None, :] - cum[:, None, :, :]      # (B,C,C,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        lmat = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0)
        g = jnp.einsum("bihn,bjhn->bijh", ck, bk)         # C_i . B_j
        y_intra = jnp.einsum("bijh,bijh,bjh,bjhp->bihp", g, lmat, dtk, xk)
        # inter-chunk: contribution of incoming state
        y_inter = jnp.einsum("bihn,bhpn,bih->bihp", ck, s, jnp.exp(cum))
        # state update: S' = exp(sum da) S + sum_j exp(cum_C - cum_j) dt_j x_j B_j^T
        decay_tail = jnp.exp(cum[:, -1:, :] - cum)        # (B,C,H)
        s_new = (jnp.exp(cum[:, -1, :])[:, :, None, None] * s
                 + jnp.einsum("bjh,bjh,bjhp,bjhn->bhpn", decay_tail, dtk, xk, bk))
        return s_new, y_intra + y_inter

    s_fin, yc = jax.lax.scan(body, s0, (xc, dtc, bc, cc))
    y = yc.swapaxes(0, 1).reshape(bsz, nc * chunk, h, p)
    return y[:, :t], s_fin


def mamba_block(p, x, cfg: ModelConfig, *, state: Optional[SSMState] = None,
                chunk: int = 256, policy: GemmPolicy = EXACT, layer: str = "",
                token_valid=None):
    """x: (B, T, d). With `state` (serving: decode or chunked prefill) the
    recurrence is advanced **one token at a time** with exactly the decode
    step's update — the resulting state is therefore invariant to how a
    prompt is partitioned into chunks (the chunked-prefill determinism
    contract), unlike the chunked SSD quadratic form whose float grouping
    depends on the chunk grid. `token_valid` (B, T) masks padded chunk
    tokens: invalid steps freeze the SSM state and conv tail. Training
    (state=None) keeps the fast chunked SSD path. Returns (out, new_state).
    """
    bsz, t, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    heads = di // 64
    pdim = 64
    proj = dot(x, p["in_proj"], policy, layer=layer + "/in_proj")
    z, xr, bflat, cflat, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + heads * n, 2 * di + 2 * heads * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,T,H)

    conv_w = p["conv_w"]                                  # (W, di)
    w_len = conv_w.shape[0]
    if state is None:
        xpad = jnp.pad(xr, ((0, 0), (w_len - 1, 0), (0, 0)))
        conv_tail = xpad[:, -(w_len - 1):, :] if w_len > 1 else jnp.zeros((bsz, 0, di), xr.dtype)
        xconv = sum(xpad[:, i:i + t, :] * conv_w[i] for i in range(w_len))
    else:
        hist = jnp.concatenate([state.conv, xr], axis=1)  # (B, W-1+T, di)
        xconv = sum(hist[:, i:i + t, :] * conv_w[i] for i in range(w_len))
        if token_valid is None:
            conv_tail = hist[:, -(w_len - 1):, :]
        else:
            # the tail after consuming q_len valid tokens (padding is always
            # trailing) is hist[q_len : q_len + W-1] per row
            q_len = token_valid.astype(jnp.int32).sum(axis=1)       # (B,)
            tail_idx = q_len[:, None] + jnp.arange(w_len - 1,
                                                   dtype=jnp.int32)[None, :]
            conv_tail = jnp.take_along_axis(hist, tail_idx[..., None], axis=1)
    xconv = jax.nn.silu(xconv)

    xh = xconv.reshape(bsz, t, heads, pdim)
    bh = bflat.reshape(bsz, t, heads, n).astype(jnp.float32)
    ch = cflat.reshape(bsz, t, heads, n).astype(jnp.float32)
    s0 = state.s if state is not None else jnp.zeros((bsz, heads, pdim, n), jnp.float32)

    if state is not None and t == 1 and token_valid is None:
        a = -jnp.exp(p["a_log"])
        da = jnp.exp(dt[:, 0] * a[None, :])               # (B,H)
        s_new = (da[:, :, None, None] * s0
                 + jnp.einsum("bh,bhp,bhn->bhpn", dt[:, 0], xh[:, 0].astype(jnp.float32),
                              bh[:, 0]))
        y = jnp.einsum("bhn,bhpn->bhp", ch[:, 0], s_new)[:, None]    # (B,1,H,P)
        s_fin = s_new
    elif state is not None:
        # serving scan: each step is bit-identical to the t == 1 decode branch
        a = -jnp.exp(p["a_log"])
        valid = (token_valid if token_valid is not None
                 else jnp.ones((bsz, t), bool))

        def step(s, inp):
            dt_t, x_t, b_t, c_t, val_t = inp
            da = jnp.exp(dt_t * a[None, :])               # (B,H)
            s_new = (da[:, :, None, None] * s
                     + jnp.einsum("bh,bhp,bhn->bhpn", dt_t,
                                  x_t.astype(jnp.float32), b_t))
            y_t = jnp.einsum("bhn,bhpn->bhp", c_t, s_new)
            s = jnp.where(val_t[:, None, None, None], s_new, s)
            return s, y_t

        s_fin, ys = jax.lax.scan(
            step, s0, (dt.swapaxes(0, 1), xh.swapaxes(0, 1),
                       bh.swapaxes(0, 1), ch.swapaxes(0, 1), valid.T))
        y = ys.swapaxes(0, 1)                             # (B,T,H,P)
    else:
        y, s_fin = _ssd_chunked(xh.astype(jnp.float32), dt, p["a_log"], bh, ch,
                                s0, min(chunk, t))
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, t, di).astype(x.dtype)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = dot(y, p["out_proj"], policy, layer=layer + "/out_proj")
    return out, SSMState(s_fin, conv_tail)
