"""Decoder/encoder transformer LM with scan-over-layers (dense, MoE, audio, VLM).

One traced layer + `lax.scan` over stacked layer params keeps HLO size O(1) in
depth. Non-uniform attention patterns (gemma3's 5:1 local:global, gemma2's
alternation) are branchless: a per-layer window scalar rides the scan as an xs
input and feeds the chunked-attention mask.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.core.gemm import EXACT, GemmPolicy, dot
from . import layers as L
from . import moe as moe_mod

PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) per-layer window sizes; 0 = global/full attention."""
    idx = jnp.arange(cfg.n_layers)
    if cfg.window_size and cfg.global_every:
        is_global = (idx + 1) % cfg.global_every == 0
        return jnp.where(is_global, 0, cfg.window_size).astype(jnp.int32)
    if cfg.window_size:
        return jnp.full((cfg.n_layers,), cfg.window_size, jnp.int32)
    return jnp.zeros((cfg.n_layers,), jnp.int32)


def init_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    ka, kf = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "attn": L.init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd, cfg.qkv_bias, dt),
    }
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe(kf, cfg, dt)
    else:
        p["mlp"] = L.init_mlp(kf, cfg.d_model, cfg.d_ff, dt)
    return p


def init_params(cfg: ModelConfig, key) -> PyTree:
    dt = _dtype(cfg)
    ke, kl, kh, kp = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab_size, cfg.d_model)) *
                  cfg.d_model ** -0.5).astype(dt),
        "layers": stacked,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) *
                             cfg.d_model ** -0.5).astype(dt)
    if cfg.family == "vlm":
        params["patch_proj"] = (jax.random.normal(kp, (cfg.d_model, cfg.d_model)) *
                                cfg.d_model ** -0.5).astype(dt)
    return params


def _layer_body(lp, x, window, kv_cache, *, cfg: ModelConfig, positions,
                cache_pos, kv_valid_len, policy: GemmPolicy, chunk: int,
                ring_cache=None, remat_attn: bool = False,
                block_tables=None, token_valid=None, paged_kernel=None):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)

    def attn_fn(ap, hh, w):
        return L.attention_block(
            ap, hh, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta, q_positions=positions,
            kv_cache=kv_cache, ring_cache=ring_cache, cache_pos=cache_pos,
            kv_valid_len=kv_valid_len,
            causal=cfg.causal, window=w, softcap=cfg.attn_softcap,
            chunk=chunk, policy=policy, layer="attn",
            block_tables=block_tables, token_valid=token_valid,
            paged_kernel=paged_kernel)

    if remat_attn:
        # "attn-only" remat (§Perf cell-B iter 3): the attention scan's
        # residuals are the memory hot-spot; checkpointing just the attention
        # block gets near-no-remat FLOPs at a fraction of the residency.
        attn_fn = jax.checkpoint(attn_fn)
    attn_out, new_cache = attn_fn(lp["attn"], h, window)
    x = x + checkpoint_name(attn_out, "attn_out")
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    serving = kv_cache is not None or ring_cache is not None
    if cfg.is_moe:
        ffn_out, aux = moe_mod.moe_block(lp["moe"], h, cfg, policy=policy,
                                         layer="moe", full_capacity=serving)
    else:
        ffn_out = L.mlp_block(lp["mlp"], h, act=cfg.act, policy=policy,
                              layer="mlp")
        aux = jnp.zeros((), jnp.float32)
    return x + ffn_out, new_cache, aux


def forward(params: PyTree, cfg: ModelConfig, *, tokens=None, input_embeds=None,
            cache: Optional[Dict] = None, cache_pos=0, positions=None,
            policy: GemmPolicy = EXACT, attn_chunk: int = 1024,
            remat: bool = False, remat_save_attn: bool = False,
            batch_axes=(), q_len=None, embed_mask=None, paged_kernel=None):
    """Returns (hidden, new_cache, aux_loss). Input is tokens (B, S) or
    precomputed embeddings (audio/vlm stubs). `cache_pos` may be a scalar
    (lockstep) or a (B,) per-slot vector (ragged continuous batching);
    `positions` then defaults to per-row `cache_pos[:, None] + arange(S)`.

    Serving extensions (the chunked-prefill path): `q_len` is a per-slot
    (B,) count of *valid* tokens — positions past it are chunk padding and
    never write cache state; `embed_mask` (B, S) selects, per token, the
    `input_embeds` row (VLM patch positions) over the token embedding, so a
    prompt chunk may straddle the patch/text boundary. A cache carrying a
    ``"block_tables"`` leaf is *paged*: its full-attention leaves are block
    pools written via per-slot (block, offset) scatters and read through
    block-table gathers (see `launch.paged`)."""
    if embed_mask is not None:
        tok_emb = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5,
                                                        _dtype(cfg))
        patch = dot(input_embeds.astype(_dtype(cfg)), params["patch_proj"],
                    policy, layer="patch_proj")
        x = jnp.where(embed_mask[..., None], patch, tok_emb)
    elif input_embeds is None:
        x = params["embed"][tokens]                          # (B, S, d)
        if cfg.family != "audio":
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    else:
        x = input_embeds.astype(_dtype(cfg))
        if cfg.family == "vlm" and tokens is not None:
            tok_emb = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5,
                                                            x.dtype)
            x = jnp.concatenate(
                [dot(x, params["patch_proj"], policy, layer="patch_proj"),
                 tok_emb], axis=1)
    x = L.constrain_batch(x, batch_axes)
    b, s, _ = x.shape
    cache_pos = jnp.asarray(cache_pos, jnp.int32)
    if positions is None:
        base = cache_pos if cache is not None else jnp.int32(0)
        offs = jnp.arange(s, dtype=jnp.int32)
        positions = base[:, None] + offs[None, :] if base.ndim else offs + base
    windows = layer_windows(cfg)
    token_valid = None
    if q_len is not None:
        q_len = jnp.asarray(q_len, jnp.int32)
        token_valid = jnp.arange(s, dtype=jnp.int32)[None, :] < q_len[:, None]
    valid_s = s if q_len is None else q_len
    kv_valid = (cache_pos + valid_s) if cache is not None else s
    block_tables = cache.get("block_tables") if cache is not None else None

    if cache is not None and "k_loc" in cache:
        return _grouped_forward(params, cfg, x, cache, cache_pos, positions,
                                kv_valid, policy, attn_chunk, batch_axes,
                                block_tables=block_tables,
                                token_valid=token_valid,
                                paged_kernel=paged_kernel)

    def body(x, xs):
        lp, window, ck, cv = xs
        kv_cache = (ck, cv) if cache is not None else None
        fn = functools.partial(_layer_body, cfg=cfg, positions=positions,
                               cache_pos=cache_pos, kv_valid_len=kv_valid,
                               policy=policy, chunk=attn_chunk,
                               remat_attn=(not remat) and remat_save_attn,
                               block_tables=block_tables,
                               token_valid=token_valid,
                               paged_kernel=paged_kernel)
        if remat:
            # selective remat (§Perf cell-A iter 2): keep each layer's attention
            # output resident so the backward pass recomputes only norms + MLP,
            # not the flash-attention scan — ~0.5 forward-pass of FLOPs saved
            # for +tokens*d bytes/layer of residency.
            pol = (jax.checkpoint_policies.save_only_these_names("attn_out")
                   if remat_save_attn else None)
            fn = jax.checkpoint(fn, static_argnums=(), policy=pol)
        x, new_cache, aux = fn(lp, x, window, kv_cache)
        x = L.constrain_batch(x, batch_axes)
        ys = (new_cache if new_cache is not None else (window, window), aux)
        return x, ys

    if cache is not None:
        xs = (params["layers"], windows, cache["k"], cache["v"])
    else:
        dummy = jnp.zeros((cfg.n_layers,), jnp.int32)
        xs = (params["layers"], windows, dummy, dummy)
    x, (cache_out, auxs) = jax.lax.scan(body, x, xs)
    new_cache = None
    if cache is not None:
        new_cache = {"k": cache_out[0], "v": cache_out[1]}
        if block_tables is not None:
            new_cache["block_tables"] = block_tables
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, auxs.sum()


def _grouped_forward(params, cfg: ModelConfig, x, cache, cache_pos, positions,
                     kv_valid, policy, attn_chunk, batch_axes,
                     block_tables=None, token_valid=None, paged_kernel=None):
    """Two-tier windowed-cache path (gemma-style local:global patterns).

    Layers are processed in groups of `global_every` — (global_every - 1) local
    layers with O(W) ring caches + 1 global layer with a full cache. The outer
    lax.scan runs over groups; within a group the layers are unrolled. This is
    the §Perf cell-C optimization: decode KV traffic and cache memory drop to
    ~(L_loc*W + L_glob*S) / (L*S) of the uniform cache.

    Under a paged cache only the global layers are paged (`block_tables`);
    the O(W) rings stay per-slot — their footprint is already position-free.
    """
    per = cfg.global_every
    g = cfg.n_layers // per
    layers_g = jax.tree.map(lambda a: a.reshape(g, per, *a.shape[1:]),
                            params["layers"])

    def body(x, xs):
        lp_g, kl, vl, kpl, kg, vg = xs
        new_loc = ([], [], [])
        aux_sum = jnp.zeros((), jnp.float32)
        for i in range(per - 1):
            lp = jax.tree.map(lambda a: a[i], lp_g)
            x, ring, aux = _layer_body(
                lp, x, cfg.window_size, None, cfg=cfg, positions=positions,
                cache_pos=cache_pos, kv_valid_len=kv_valid, policy=policy,
                chunk=attn_chunk, ring_cache=(kl[i], vl[i], kpl[i]),
                token_valid=token_valid)
            for lst, val in zip(new_loc, ring):
                lst.append(val)
            aux_sum = aux_sum + aux
        lp = jax.tree.map(lambda a: a[per - 1], lp_g)
        x, kv_glob, aux = _layer_body(
            lp, x, 0, (kg, vg), cfg=cfg, positions=positions,
            cache_pos=cache_pos, kv_valid_len=kv_valid, policy=policy,
            chunk=attn_chunk, block_tables=block_tables,
            token_valid=token_valid, paged_kernel=paged_kernel)
        aux_sum = aux_sum + aux
        x = L.constrain_batch(x, batch_axes)
        ys = (jnp.stack(new_loc[0]), jnp.stack(new_loc[1]),
              jnp.stack(new_loc[2]), kv_glob[0], kv_glob[1], aux_sum)
        return x, ys

    xs = (layers_g, cache["k_loc"], cache["v_loc"], cache["kpos_loc"],
          cache["k_glob"], cache["v_glob"])
    x, ys = jax.lax.scan(body, x, xs)
    new_cache = {"k_loc": ys[0], "v_loc": ys[1], "kpos_loc": ys[2],
                 "k_glob": ys[3], "v_glob": ys[4]}
    if block_tables is not None:
        new_cache["block_tables"] = block_tables
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, ys[5].sum()


def logits_from_hidden(params, cfg: ModelConfig, hidden,
                       policy: GemmPolicy = EXACT):
    w = L.head_weight(params, hidden.dtype)
    logits = dot(hidden, w, policy, layer="lm_head")
    return L._softcap(logits.astype(jnp.float32), cfg.final_softcap)


def lm_loss(params: PyTree, cfg: ModelConfig, tokens, *, input_embeds=None,
            loss_mask=None, policy: GemmPolicy = EXACT, remat: bool = True,
            remat_save_attn: bool = False, ce_chunk: int = 512,
            attn_chunk: int = 1024, batch_axes=()):
    """Causal (or masked) CE loss, with the vocab projection computed in sequence
    chunks so (S, V) logits never materialize for 256k vocabs."""
    if cfg.causal:
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        mask = jnp.ones_like(tgt, jnp.float32) if loss_mask is None \
            else loss_mask[:, 1:].astype(jnp.float32)
    else:  # encoder (audio): tokens are frame labels, inputs are embeddings
        inp, tgt = tokens, tokens
        mask = jnp.ones_like(tgt, jnp.float32) if loss_mask is None \
            else loss_mask.astype(jnp.float32)
    hidden, _, aux = forward(params, cfg, tokens=inp, input_embeds=input_embeds,
                             policy=policy, remat=remat,
                             remat_save_attn=remat_save_attn,
                             attn_chunk=attn_chunk, batch_axes=batch_axes)
    if cfg.family == "vlm" and input_embeds is not None:
        # hidden covers [patches | text[:-1]]; the last S_txt-1 positions plus the
        # final patch position predict text tokens 1..S_txt-1 -> take text slice
        hidden = hidden[:, -tgt.shape[1]:]
    w = L.head_weight(params, hidden.dtype)
    b, s, d = hidden.shape
    n_chunks = -(-s // ce_chunk)
    pad = n_chunks * ce_chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = hidden.reshape(b, n_chunks, ce_chunk, d).swapaxes(0, 1)
    tc = tgt.reshape(b, n_chunks, ce_chunk).swapaxes(0, 1)
    mc = mask.reshape(b, n_chunks, ce_chunk).swapaxes(0, 1)

    def ce(carry, inp3):
        h, t, m = inp3
        logits = L._softcap(
            dot(h, w, policy, layer="lm_head").astype(jnp.float32),
            cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        loss_sum, n_sum = carry
        return (loss_sum + ((lse - ll) * m).sum(), n_sum + m.sum()), None

    (loss_sum, n_sum), _ = jax.lax.scan(ce, (jnp.zeros(()), jnp.zeros(())),
                                        (hc, tc, mc))
    return loss_sum / jnp.maximum(n_sum, 1.0) + 0.01 * aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               *, windowed: Optional[bool] = None, paged=None):
    """Uniform (L, B, S, KH, hd) cache, or — for local:global window patterns —
    a two-tier cache: per-group ring buffers of size W for local layers + full
    caches for the 1-in-`global_every` global layers. dtype=jnp.int8 stores the
    payload quantized (layers.CACHE_INT8_SCALE), halving cache bytes again.

    ``paged=(n_blocks, block_size)`` replaces every full-attention leaf with a
    shared block pool ``(L, n_blocks + 1, block_size, KH, hd)`` (the ``+ 1``
    is the dump block masked writes are redirected to) plus a per-slot
    ``block_tables`` leaf ``(batch, ceil(max_len / block_size))`` initialized
    to the dump index; the engine's allocator (`launch.paged.BlockPool`)
    owns the table contents. O(W) ring leaves stay per-slot."""
    if windowed is None:
        windowed = bool(cfg.window_size and cfg.global_every
                        and max_len > cfg.window_size
                        and cfg.n_layers % cfg.global_every == 0)
    kh, hd = cfg.n_kv_heads, cfg.hd
    if paged is not None:
        n_blocks, blk = paged
        tables = L.init_block_tables(batch, max_len, n_blocks, blk)
    if windowed:
        per = cfg.global_every
        g = cfg.n_layers // per
        w = cfg.window_size
        cache = {
            "k_loc": jnp.zeros((g, per - 1, batch, w, kh, hd), dtype),
            "v_loc": jnp.zeros((g, per - 1, batch, w, kh, hd), dtype),
            "kpos_loc": jnp.full((g, per - 1, batch, w), -(2 ** 30),
                                 jnp.int32),
        }
        if paged is not None:
            cache["k_glob"] = jnp.zeros((g, n_blocks + 1, blk, kh, hd), dtype)
            cache["v_glob"] = jnp.zeros((g, n_blocks + 1, blk, kh, hd), dtype)
            cache["block_tables"] = tables
        else:
            cache["k_glob"] = jnp.zeros((g, batch, max_len, kh, hd), dtype)
            cache["v_glob"] = jnp.zeros((g, batch, max_len, kh, hd), dtype)
        return cache
    if paged is not None:
        shape = (cfg.n_layers, n_blocks + 1, blk, kh, hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "block_tables": tables}
    shape = (cfg.n_layers, batch, max_len, kh, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, cfg: ModelConfig, tokens, cache, *, input_embeds=None,
            policy: GemmPolicy = EXACT, attn_chunk: int = 1024, batch_axes=(),
            paged_kernel=None):
    hidden, cache, _ = forward(params, cfg, tokens=tokens,
                               input_embeds=input_embeds, cache=cache,
                               cache_pos=0, policy=policy, attn_chunk=attn_chunk,
                               batch_axes=batch_axes, paged_kernel=paged_kernel)
    logits = logits_from_hidden(params, cfg, hidden[:, -1:], policy)
    return logits, cache


def chunk_step(params, cfg: ModelConfig, tokens, cache, pos, q_len, *,
               policy: GemmPolicy = EXACT, attn_chunk: int = 1024,
               batch_axes=(), input_embeds=None, embed_mask=None,
               paged_kernel=None):
    """One serving step over a (B, T) token block: the unified form behind
    both decode (T == 1, q_len == 1) and chunked prefill (T = chunk budget,
    per-slot q_len <= T with trailing padding). Mixed prefill+decode batches
    are just rows with different q_len. Writes land at per-slot positions
    `pos[b] + j` for j < q_len[b] (padding is masked — paged caches redirect
    it to the dump block); returns the logits of each slot's **last valid**
    token, (B, 1, V) — bit-identical to the T == 1 decode step for decode
    rows and to whole-prompt prefill's final logits for prompt rows."""
    pos = jnp.asarray(pos, jnp.int32)
    t = tokens.shape[1]
    positions = pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    hidden, cache, _ = forward(params, cfg, tokens=tokens, cache=cache,
                               cache_pos=pos, positions=positions,
                               policy=policy, attn_chunk=attn_chunk,
                               batch_axes=batch_axes, q_len=q_len,
                               input_embeds=input_embeds,
                               embed_mask=embed_mask,
                               paged_kernel=paged_kernel)
    sel = jnp.maximum(jnp.asarray(q_len, jnp.int32) - 1, 0)
    hidden = jnp.take_along_axis(hidden, sel[:, None, None], axis=1)  # (B,1,d)
    return logits_from_hidden(params, cfg, hidden, policy), cache


def decode_step(params, cfg: ModelConfig, token, cache, pos, *,
                policy: GemmPolicy = EXACT, attn_chunk: int = 1024,
                batch_axes=(), paged_kernel=None):
    """One decode step. token: (B, 1); pos: scalar int32 (current length,
    lockstep — the whole batch at one position) or (B,) int32 per-slot
    positions (ragged continuous batching; the scalar form is the all-equal
    degenerate case and is bit-identical to the vector form)."""
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim else jnp.full((1,), pos, jnp.int32)
    hidden, cache, _ = forward(params, cfg, tokens=token, cache=cache,
                               cache_pos=pos, positions=positions, policy=policy,
                               attn_chunk=attn_chunk, batch_axes=batch_axes,
                               paged_kernel=paged_kernel)
    return logits_from_hidden(params, cfg, hidden, policy), cache
