"""xLSTM blocks: mLSTM (matrix memory, chunked linear-attention form) and sLSTM
(scalar memory with exponential gating, sequential scan).

mLSTM recurrence per head:  C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1}
+ i_t k_t ;  y_t = C_t q_t / max(|n_t^T q_t|, 1). Computed chunkwise (same shape
of algorithm as SSD) so training is linear in T and decode is O(1) per token.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.gemm import EXACT, GemmPolicy, dot
from repro.configs.base import ModelConfig


class MLSTMState(NamedTuple):
    c: jnp.ndarray    # (B, H, D, D) matrix memory
    n: jnp.ndarray    # (B, H, D)    normalizer
    m: jnp.ndarray    # (B, H)       max-gate stabilizer


class SLSTMState(NamedTuple):
    c: jnp.ndarray    # (B, d)
    n: jnp.ndarray    # (B, d)
    h: jnp.ndarray    # (B, d)
    m: jnp.ndarray    # (B, d)


def init_mlstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    return {
        "up": (jax.random.normal(ks[0], (d, 2 * di)) * std).astype(dtype),
        "wq": (jax.random.normal(ks[1], (di, di)) * (di ** -0.5)).astype(dtype),
        "wk": (jax.random.normal(ks[2], (di, di)) * (di ** -0.5)).astype(dtype),
        "wv": (jax.random.normal(ks[3], (di, di)) * (di ** -0.5)).astype(dtype),
        "w_if": (jax.random.normal(ks[4], (di, 2 * h)) * (di ** -0.5)).astype(jnp.float32),
        "norm_w": jnp.zeros((di,), dtype),
        "down": (jax.random.normal(ks[5], (di, d)) * (di ** -0.5)).astype(dtype),
    }


def _mlstm_chunked(q, k, v, log_i, log_f, state: Optional[MLSTMState], chunk: int,
                   valid=None):
    """q/k/v: (B,T,H,D); log_i/log_f: (B,T,H). Stabilized chunked computation.

    `valid` (B, T) masks padded serving tokens at chunk granularity — it
    requires chunk == 1 (the per-token serving form), where a masked step
    leaves the (C, n, m) carry untouched."""
    bsz, t, h, d = q.shape
    if valid is not None and chunk != 1:
        raise ValueError("token masking requires the per-token form (chunk=1)")
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        q, k, v = (jnp.pad(z, ((0, 0), (0, pad), (0, 0), (0, 0))) for z in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e9)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        if valid is not None:
            valid = jnp.pad(valid, ((0, 0), (0, pad)))

    def rc(z):
        return z.reshape(bsz, nc, chunk, *z.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lic, lfc = map(rc, (q, k, v, log_i, log_f))
    vac = (rc(valid)[:, :, 0] if valid is not None
           else jnp.ones((nc, bsz), bool))                  # (NC, B)
    if state is None:
        c0 = jnp.zeros((bsz, h, d, d), jnp.float32)
        n0 = jnp.zeros((bsz, h, d), jnp.float32)
        m0 = jnp.zeros((bsz, h), jnp.float32)
    else:
        c0, n0, m0 = state

    def body(carry, inp):
        c, n, m = carry
        qk_, kk_, vk_, li, lf, val = inp
        cumf = jnp.cumsum(lf, axis=1)                        # (B,C,H) inclusive
        # log weight of source j for target i (i >= j): cumf_i - cumf_j + li_j
        lw = cumf[:, :, None, :] - cumf[:, None, :, :] + li[:, None, :, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        lw = jnp.where(mask[None, :, :, None], lw, -jnp.inf)
        # log weight of incoming state for target i: cumf_i + m
        lw_state = cumf + m[:, None, :]                      # (B,C,H)
        m_new = jnp.maximum(lw.max(axis=2), lw_state)        # (B,C,H)
        w = jnp.exp(lw - m_new[:, :, None, :])               # (B,C,C,H)
        ws = jnp.exp(lw_state - m_new)                       # (B,C,H)
        g = jnp.einsum("bihd,bjhd->bijh", qk_, kk_)          # q_i . k_j
        y_intra = jnp.einsum("bijh,bijh,bjhd->bihd", g, w, vk_)
        # C[d, e] = sum_j v_d k_e: contract q with the k-dim (e) -> y_d
        y_inter = jnp.einsum("bihe,bhde,bih->bihd", qk_, c, ws)
        denom_intra = jnp.einsum("bijh,bijh->bih", g, w)
        denom_inter = jnp.einsum("bihd,bhd,bih->bih", qk_, n, ws)
        denom = jnp.abs(denom_intra + denom_inter)
        y = (y_intra + y_inter) / jnp.maximum(denom, jnp.exp(-m_new))[..., None]
        # carry update (stabilized at the chunk's final max)
        m_fin = m_new[:, -1]                                 # (B,H)
        decay_tail = jnp.exp(cumf[:, -1:, :] - cumf + li - m_fin[:, None])
        c_new = (jnp.exp(cumf[:, -1] + m - m_fin)[:, :, None, None] * c
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", decay_tail, vk_, kk_))
        n_new = (jnp.exp(cumf[:, -1] + m - m_fin)[:, :, None] * n
                 + jnp.einsum("bjh,bjhd->bhd", decay_tail, kk_))
        c_new = jnp.where(val[:, None, None, None], c_new, c)
        n_new = jnp.where(val[:, None, None], n_new, n)
        m_fin = jnp.where(val[:, None], m_fin, m)
        return (c_new, n_new, m_fin), y

    (c_f, n_f, m_f), yc = jax.lax.scan(body, (c0, n0, m0),
                                       (qc, kc, vc, lic, lfc, vac))
    y = yc.swapaxes(0, 1).reshape(bsz, nc * chunk, h, d)[:, :t]
    return y, MLSTMState(c_f, n_f, m_f)


def mlstm_block(p, x, cfg: ModelConfig, *, state: Optional[MLSTMState] = None,
                chunk: int = 256, policy: GemmPolicy = EXACT, layer: str = "",
                token_valid=None):
    """With `state` (serving) the recurrence runs in the per-token form
    (chunk=1) — every step is the decode step's update, so chunked prefill
    reaches bit-identical memories whatever the chunk grid; `token_valid`
    (B, T) freezes the carry on padded tokens. Training stays chunked."""
    bsz, t, d = x.shape
    di = cfg.ssm_expand * d
    h = cfg.n_heads
    hd = di // h
    up = dot(x, p["up"], policy, layer=layer + "/up")
    xi, z = jnp.split(up, 2, axis=-1)
    q = dot(xi, p["wq"], policy, layer=layer + "/wq").reshape(bsz, t, h, hd)
    k = dot(xi, p["wk"], policy, layer=layer + "/wk").reshape(bsz, t, h, hd) * hd ** -0.5
    v = dot(xi, p["wv"], policy, layer=layer + "/wv").reshape(bsz, t, h, hd)
    gates = xi.astype(jnp.float32) @ p["w_if"]                       # (B,T,2H)
    log_i, f_raw = jnp.split(gates, 2, axis=-1)
    log_f = -jax.nn.softplus(-f_raw)                                 # log sigmoid
    chunk_eff = 1 if state is not None else min(chunk, t)
    y, new_state = _mlstm_chunked(q.astype(jnp.float32), k.astype(jnp.float32),
                                  v.astype(jnp.float32), log_i, log_f, state,
                                  chunk_eff, valid=token_valid)
    y = y.reshape(bsz, t, di).astype(x.dtype)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return dot(y, p["down"], policy, layer=layer + "/down"), new_state


def init_slstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    std = d ** -0.5
    return {
        "w_in": (jax.random.normal(ks[0], (d, 4 * d)) * std).astype(dtype),
        "r_in": (jax.random.normal(ks[1], (d, 4 * d)) * std * 0.1).astype(dtype),
        "out": (jax.random.normal(ks[2], (d, d)) * std).astype(dtype),
    }


def slstm_block(p, x, cfg: ModelConfig, *, state: Optional[SLSTMState] = None,
                policy: GemmPolicy = EXACT, layer: str = "",
                token_valid=None):
    """Sequential sLSTM (exponential gating, recurrent weights R).

    Already per-token, so chunked prefill is chunk-invariant by construction;
    `token_valid` (B, T) freezes the carry on padded serving tokens."""
    bsz, t, d = x.shape
    wx = dot(x, p["w_in"], policy, layer=layer + "/w_in")   # (B,T,4d)
    if state is None:
        state = SLSTMState(*(jnp.zeros((bsz, d), jnp.float32) for _ in range(4)))

    r_in = p["r_in"]
    valid = (token_valid if token_valid is not None
             else jnp.ones((bsz, t), bool))

    def step(carry, inp):
        wx_t, val_t = inp
        c, n, h, m = carry
        pre = wx_t.astype(jnp.float32) + h @ r_in.astype(jnp.float32)
        zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zi)
        ot = jax.nn.sigmoid(oi)
        log_f = -jax.nn.softplus(-fi)
        m_new = jnp.maximum(log_f + m, ii)
        i_g = jnp.exp(ii - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c_new = f_g * c + i_g * zt
        n_new = f_g * n + i_g
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        keep = val_t[:, None]
        new = SLSTMState(jnp.where(keep, c_new, c), jnp.where(keep, n_new, n),
                         jnp.where(keep, h_new, h), jnp.where(keep, m_new, m))
        return new, h_new

    new_state, hs = jax.lax.scan(step, state, (wx.swapaxes(0, 1), valid.T))
    y = hs.swapaxes(0, 1).astype(x.dtype)                      # (B,T,d)
    return dot(y, p["out"], policy, layer=layer + "/out"), new_state
