"""xLSTM LM: repeats of (slstm_every-1 mLSTM blocks + 1 sLSTM block).

Outer scan over repeats, inner scan over the stacked mLSTM blocks of each repeat
-> O(1) HLO in depth. Decode carries mLSTM matrix memories and sLSTM scalar
states.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.gemm import EXACT, GemmPolicy, dot
from . import layers as L
from . import xlstm as X


def _structure(cfg: ModelConfig):
    per = cfg.slstm_every                    # repeat length (m-1 mLSTM + 1 sLSTM)
    assert per >= 2 and cfg.n_layers % per == 0, (cfg.n_layers, per)
    return cfg.n_layers // per, per - 1


def init_params(cfg: ModelConfig, key):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    n_rep, n_m = _structure(cfg)
    ke, km, ks, kh = jax.random.split(key, 4)

    def init_m(k):
        return {"ln": jnp.zeros((cfg.d_model,), dt),
                "mlstm": X.init_mlstm(k, cfg, dt)}

    def init_s(k):
        return {"ln": jnp.zeros((cfg.d_model,), dt),
                "slstm": X.init_slstm(k, cfg, dt)}

    mkeys = jax.random.split(km, n_rep * n_m).reshape(n_rep, n_m, 2)
    skeys = jax.random.split(ks, n_rep)
    return {
        "embed": (jax.random.normal(ke, (cfg.vocab_size, cfg.d_model)) *
                  cfg.d_model ** -0.5).astype(dt),
        "mlstm_blocks": jax.vmap(jax.vmap(init_m))(mkeys),        # (R, M, ...)
        "slstm_blocks": jax.vmap(init_s)(skeys),                   # (R, ...)
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab_size)) *
                    cfg.d_model ** -0.5).astype(dt),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               paged=None):
    """All state is recurrent (O(1) per slot) — nothing pages. ``paged``
    still adds the ``block_tables`` leaf so the serve engine drives every
    family through one cache shape convention; the model ignores it."""
    n_rep, n_m = _structure(cfg)
    di = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    hd = di // h
    d = cfg.d_model
    cache = {
        "m_c": jnp.zeros((n_rep, n_m, batch, h, hd, hd), jnp.float32),
        "m_n": jnp.zeros((n_rep, n_m, batch, h, hd), jnp.float32),
        "m_m": jnp.zeros((n_rep, n_m, batch, h), jnp.float32),
        "s_c": jnp.zeros((n_rep, batch, d), jnp.float32),
        "s_n": jnp.zeros((n_rep, batch, d), jnp.float32),
        "s_h": jnp.zeros((n_rep, batch, d), jnp.float32),
        "s_m": jnp.zeros((n_rep, batch, d), jnp.float32),
    }
    if paged is not None:
        n_blocks, blk = paged
        cache["block_tables"] = L.init_block_tables(batch, max_len, n_blocks,
                                                    blk)
    return cache


def forward(params, cfg: ModelConfig, *, tokens, cache: Optional[Dict] = None,
            policy: GemmPolicy = EXACT, chunk: int = 256, batch_axes=(),
            q_len=None):
    """`q_len` (B,) marks valid-token counts for chunked serving — trailing
    padded tokens freeze every mLSTM/sLSTM carry (see models.xlstm)."""
    n_rep, n_m = _structure(cfg)
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5,
                                              params["embed"].dtype)
    x = L.constrain_batch(x, batch_axes)
    use_cache = cache is not None
    new_cache = dict(cache) if use_cache else None
    token_valid = None
    if q_len is not None:
        s = x.shape[1]
        q_len = jnp.asarray(q_len, jnp.int32)
        token_valid = jnp.arange(s, dtype=jnp.int32)[None, :] < q_len[:, None]

    def m_scan(rep_params, x, states):
        def body(x, xs):
            lp, st = xs

            def layer(lp_, x_):
                h = L.rms_norm(x_, lp_["ln"], cfg.norm_eps)
                out, ns = X.mlstm_block(
                    lp_["mlstm"], h, cfg,
                    state=X.MLSTMState(*st) if use_cache else None,
                    chunk=chunk, policy=policy, layer="mlstm",
                    token_valid=token_valid)
                return x_ + out, (ns.c, ns.n, ns.m)

            if not use_cache:   # training: checkpoint (chunk quadratics)
                layer = jax.checkpoint(layer)
            return layer(lp, x)
        if use_cache:
            xs = (rep_params, states)
        else:
            b = x.shape[0]
            di = cfg.ssm_expand * cfg.d_model
            hh, hd = cfg.n_heads, di // cfg.n_heads
            xs = (rep_params, (jnp.zeros((n_m, b, hh, hd, hd), jnp.float32),
                               jnp.zeros((n_m, b, hh, hd), jnp.float32),
                               jnp.zeros((n_m, b, hh), jnp.float32)))
        return jax.lax.scan(body, x, xs)

    def s_apply(sp, x, state):
        h = L.rms_norm(x, sp["ln"], cfg.norm_eps)
        out, ns = X.slstm_block(sp["slstm"], h, cfg, state=state,
                                policy=policy, layer="slstm",
                                token_valid=token_valid)
        return x + out, ns

    def rep_body(x, xs):
        rep_m, rep_s, m_st, s_st = xs
        x, new_m = m_scan(rep_m, x, m_st)
        x, new_s = s_apply(rep_s, x,
                           X.SLSTMState(*s_st) if use_cache else None)
        return x, (new_m, (new_s.c, new_s.n, new_s.h, new_s.m))

    if use_cache:
        m_states = (cache["m_c"], cache["m_n"], cache["m_m"])
        s_states = (cache["s_c"], cache["s_n"], cache["s_h"], cache["s_m"])
    else:
        b = x.shape[0]
        di = cfg.ssm_expand * cfg.d_model
        hh, hd = cfg.n_heads, di // cfg.n_heads
        d = cfg.d_model
        m_states = (jnp.zeros((n_rep, n_m, b, hh, hd, hd), jnp.float32),
                    jnp.zeros((n_rep, n_m, b, hh, hd), jnp.float32),
                    jnp.zeros((n_rep, n_m, b, hh), jnp.float32))
        s_states = tuple(jnp.zeros((n_rep, b, d), jnp.float32) for _ in range(4))

    x, (m_out, s_out) = jax.lax.scan(
        rep_body, x, (params["mlstm_blocks"], params["slstm_blocks"],
                      m_states, s_states))
    if use_cache:
        new_cache = {"m_c": m_out[0], "m_n": m_out[1], "m_m": m_out[2],
                     "s_c": s_out[0], "s_n": s_out[1], "s_h": s_out[2],
                     "s_m": s_out[3]}
        if "block_tables" in cache:
            new_cache["block_tables"] = cache["block_tables"]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache


def lm_loss(params, cfg: ModelConfig, tokens, *, policy: GemmPolicy = EXACT,
            batch_axes=(), **_):
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    hidden, _ = forward(params, cfg, tokens=inp, policy=policy,
                        batch_axes=batch_axes)
    logits = dot(hidden, L.head_weight(params, hidden.dtype), policy,
                 layer="lm_head").astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    return (lse - ll).mean()


def prefill(params, cfg, tokens, cache, *, policy=EXACT, batch_axes=(), **_):
    hidden, cache = forward(params, cfg, tokens=tokens, cache=cache,
                            policy=policy, batch_axes=batch_axes)
    logits = dot(hidden[:, -1:], L.head_weight(params, hidden.dtype), policy,
                 layer="lm_head")
    return logits.astype(jnp.float32), cache


def chunk_step(params, cfg, tokens, cache, pos, q_len, *, policy=EXACT,
               batch_axes=(), **_):
    """Unified serving step over a (B, T) token block — `pos` is accepted
    for API uniformity but unused (the recurrence is position-free).
    Returns each slot's last-valid-token logits, (B, 1, V)."""
    hidden, cache = forward(params, cfg, tokens=tokens, cache=cache,
                            policy=policy, batch_axes=batch_axes, q_len=q_len)
    sel = jnp.maximum(jnp.asarray(q_len, jnp.int32) - 1, 0)
    hidden = jnp.take_along_axis(hidden, sel[:, None, None], axis=1)
    logits = dot(hidden, L.head_weight(params, hidden.dtype), policy,
                 layer="lm_head")
    return logits.astype(jnp.float32), cache


def decode_step(params, cfg, token, cache, pos, *, policy=EXACT,
                batch_axes=(), **_):
    """`pos` (scalar or per-slot (B,) vector) is accepted for API uniformity
    but unused: the recurrence carries no positional state, so ragged
    continuous batching is position-free here."""
    hidden, cache = forward(params, cfg, tokens=token, cache=cache,
                            policy=policy, batch_axes=batch_axes)
    logits = dot(hidden, L.head_weight(params, hidden.dtype), policy,
                 layer="lm_head")
    return logits.astype(jnp.float32), cache
