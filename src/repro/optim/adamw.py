"""AdamW with decoupled weight decay. Optimizer state shards like params
(ZeRO: the sharding specs give every state tensor its param's sharding)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


def init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def update(grads: PyTree, state: AdamWState, params: PyTree, *, lr,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1, grad_clip: float = 1.0):
    """Returns (new_params, new_state). `lr` may be a traced scalar."""
    step = state.step + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        newp = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu)
