"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

The paper's insight — low-order bit columns of a MAC are cheap to approximate in
error-tolerant workloads — applies directly to gradient communication: gradients
tolerate low-precision summation with error feedback. Before the (slow, inter-pod)
all-reduce we quantize each gradient tensor to int8 with a per-tensor scale and
carry the quantization residual into the next step (error feedback), making the
compression unbiased over time. 4x traffic reduction on the pod hop.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads: PyTree, err: PyTree) -> Tuple[PyTree, PyTree, PyTree]:
    """Returns (int8_payload, scales, new_error). Decompress with payload*scale."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(g))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_e = g - q.astype(jnp.float32) * scale
        return q, scale, new_e

    out = jax.tree.map(one, grads, err)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    return (jax.tree.map(lambda t: t[0], out, is_leaf=is3),
            jax.tree.map(lambda t: t[1], out, is_leaf=is3),
            jax.tree.map(lambda t: t[2], out, is_leaf=is3))


def decompress(payload: PyTree, scales: PyTree) -> PyTree:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, payload, scales)


def compressed_psum(grads: PyTree, err: PyTree, axis_name: str):
    """Quantize -> psum(int32) -> dequantize with max-scale, inside shard_map/pmap.
    (Scales are psum-maxed so the integer sum cannot overflow int32.)"""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int32)
        new_e = g - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q, axis_name).astype(jnp.float32) * scale
        return summed, new_e

    out = jax.tree.map(one, grads, err)
    is2 = lambda t: isinstance(t, tuple) and len(t) == 2
    return (jax.tree.map(lambda t: t[0], out, is_leaf=is2),
            jax.tree.map(lambda t: t[1], out, is_leaf=is2))
