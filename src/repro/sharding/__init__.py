from .specs import (batch_axes, cache_shardings, explain, input_shardings,  # noqa: F401
                    param_shardings, param_spec)
