"""DP/TP/EP/SP sharding rules.

Scheme (single-pod mesh ("data", "model"); multi-pod prepends "pod"):

* params — TP over `model` on every linear's output-feature dim (attention heads,
  d_ff, vocab, MoE expert dim = EP) and FSDP over `data` on the d_model dim
  (ZeRO-3: params/grads/optimizer state all sharded; XLA all-gathers per layer
  inside the scan). The `pod` axis replicates params (gradient all-reduce crosses
  the inter-pod link once per step — the hop gradient compression targets).
* activations — batch over ("pod", "data").
* KV caches — batch over ("pod", "data"); kv-head dim over `model` when
  divisible, else the sequence dim (SP) so 500k caches and small-kv-head archs
  still shard.

A dim is sharded only if divisible by the axis size; otherwise left replicated
(recorded by `explain()` for the dry-run report).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# param-name -> (tp_dim, fsdp_dim); dims count from the *unstacked* param's end
_RULES = {
    "embed": (0, 1), "lm_head": (1, 0), "patch_proj": (1, 0),
    "wq": (1, 0), "wk": (1, 0), "wv": (1, 0), "wo": (0, 1),
    "bq": (0, None), "bk": (0, None), "bv": (0, None),
    "w1": (1, 0), "w3": (1, 0), "w2": (0, 1),
    "router": (1, 0),
    "in_proj": (1, 0), "out_proj": (0, 1), "conv_w": (1, None),
    "up": (1, 0), "down": (0, 1), "w_in": (1, 0), "r_in": (1, 0),
    "w_if": (None, 0), "out": (1, 0),
}
# MoE expert tensors: leading expert dim -> EP over model
_MOE_NAMES = {"w1", "w3", "w2"}


def _axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis]


def _maybe(dim_size: int, mesh: Mesh, axis: Optional[str]):
    if axis is None:
        return None
    return axis if dim_size % _axis_size(mesh, axis) == 0 else None


def param_spec(path: str, shape, mesh: Mesh) -> P:
    """PartitionSpec for one parameter. `path` is '/'-joined tree path; leading
    stacked layer/group dims (from scan-stacking) are detected as extra dims."""
    name = path.split("/")[-1]
    parts = path.split("/")
    ndim = len(shape)
    spec = [None] * ndim
    if name not in _RULES:
        return P(*spec)
    tp_dim, fsdp_dim = _RULES[name]
    is_expert = ("moe" in parts and name in _MOE_NAMES and ndim >= 3)
    # number of the param's own (unstacked) dims
    own = 3 if is_expert else {"bq": 1, "bk": 1, "bv": 1}.get(name, 2)
    lead = ndim - own                      # stacked scan dims
    if is_expert:
        # (..., E, d, ff) style: EP on expert dim; fsdp/tp inside
        e_dim = lead
        spec[e_dim] = _maybe(shape[e_dim], mesh, "model")
        # remaining dims replicated except fsdp on the larger of the two
        d_dim = lead + 1
        spec[d_dim] = _maybe(shape[d_dim], mesh, "data")
        return P(*spec)
    if tp_dim is not None and tp_dim < own:
        dim = lead + tp_dim
        spec[dim] = _maybe(shape[dim], mesh, "model")
    if fsdp_dim is not None and fsdp_dim < own:
        dim = lead + fsdp_dim
        if spec[dim] is None:
            spec[dim] = _maybe(shape[dim], mesh, "data")
    return P(*spec)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return "/".join(out)


def param_shardings(params_shape: PyTree, mesh: Mesh) -> PyTree:
    """NamedSharding tree matching a params (shape) tree."""
    def one(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def input_shardings(specs: PyTree, mesh: Mesh) -> PyTree:
    """Shard every step input on its leading (batch) dim over pod+data."""
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]

    def one(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % bsize == 0 and leaf.shape[0] > 1:
            return NamedSharding(mesh, P(baxes))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, specs)


def cache_shardings(cache_shapes: PyTree, mesh: Mesh, *, batch: int) -> PyTree:
    """KV/SSM cache shardings: batch over pod+data; kv-heads over model when
    divisible, else sequence (SP)."""
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    msize = mesh.shape["model"]

    def one(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        spec = [None] * len(shape)
        leaf_name = name.split("/")[-1]
        if leaf_name.startswith("kpos"):
            return NamedSharding(mesh, P())          # tiny slot-position arrays
        if leaf_name in ("k", "v", "k_loc", "v_loc", "k_glob", "v_glob"):
            b_dim = len(shape) - 4                   # (..., B, S, KH, hd)
        else:
            # SSM/mLSTM states: first dim equal to `batch` after stack dims
            b_dim = None
            for i, d in enumerate(shape):
                if d == batch:
                    b_dim = i
                    break
        if b_dim is not None and batch % bsize == 0 and batch > 1:
            spec[b_dim] = baxes
        if leaf_name in ("k", "v", "k_loc", "v_loc", "k_glob", "v_glob"):
            # (..., B, S, KH, hd)
            kh_dim, s_dim = len(shape) - 2, len(shape) - 3
            if shape[kh_dim] % msize == 0:
                spec[kh_dim] = "model"
            elif shape[s_dim] % msize == 0:
                spec[s_dim] = "model"
            if spec[b_dim] is None and b_dim is not None:
                # batch unshardable (long-context b=1): SP the sequence over data
                rem = [a for a in baxes]
                if spec[s_dim] == "model" and shape[s_dim] % (msize * bsize) == 0:
                    spec[s_dim] = tuple(rem) + ("model",)
                elif spec[s_dim] is None and shape[s_dim] % bsize == 0:
                    spec[s_dim] = tuple(rem)
        else:
            # SSM/mLSTM states: shard head dim over model if divisible
            for i in range(len(shape) - 1, max(-1, (b_dim or 0)), -1):
                if i != b_dim and shape[i] % msize == 0 and shape[i] >= msize:
                    spec[i] = "model"
                    break
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def explain(params_shape: PyTree, mesh: Mesh):
    """(path, shape, spec) rows for the dry-run report."""
    rows = []

    def one(path, leaf):
        rows.append((_path_str(path), tuple(leaf.shape),
                     str(param_spec(_path_str(path), leaf.shape, mesh))))
        return leaf
    jax.tree_util.tree_map_with_path(one, params_shape)
    return rows
