from . import fault, loop  # noqa: F401
