"""Fault tolerance: checkpoint/restart, straggler watchdog, bounded retry.

On a real multi-pod deployment, failures arrive as (a) hard process death —
handled by checkpoint/restart via the launcher re-exec'ing `train.py`, which
resumes from the latest manifest; (b) transient step failures (preemption
notices, flaky interconnect) — handled by bounded re-execution of the step; and
(c) stragglers — detected by a per-step wall-time EWMA; the watchdog flags hosts
whose step times exceed `threshold` x the fleet median so the launcher can
exclude them at the next elastic restart (the data pipeline and checkpoints are
both host-count agnostic, so N-1 hosts resume cleanly).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerWatchdog:
    alpha: float = 0.1              # EWMA coefficient
    threshold: float = 2.0          # flag if step > threshold * ewma
    warmup_steps: int = 5
    ewma: Optional[float] = None
    steps: int = 0
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Record one step; returns True if this step is a straggler event."""
        self.steps += 1
        if self.ewma is None:
            self.ewma = seconds
            return False
        slow = (self.steps > self.warmup_steps
                and seconds > self.threshold * self.ewma)
        if slow:
            self.flagged.append(step)
        # don't let outliers poison the baseline
        upd = min(seconds, 4 * self.ewma)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * upd
        return slow


class TransientError(RuntimeError):
    """Raised by step wrappers for retryable failures."""


def run_with_retries(fn: Callable, *args, max_retries: int = 3,
                     backoff_s: float = 1.0, on_retry: Optional[Callable] = None):
    """Bounded re-execution for transient step failures."""
    attempt = 0
    while True:
        try:
            return fn(*args)
        except TransientError:
            attempt += 1
            if attempt > max_retries:
                raise
            if on_retry:
                on_retry(attempt)
            time.sleep(backoff_s * attempt)
