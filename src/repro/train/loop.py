"""Training loop: data -> step -> metrics -> periodic async checkpoint, with
resume-from-latest, straggler watchdog, bounded transient retry, and an
optional in-loop eval under a (possibly approximate) serving policy."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core import gemm
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.configs.base import ModelConfig, ShapeSpec
from repro.optim import adamw
from . import fault


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    resume: bool = True
    # in-loop eval: every `eval_every` steps, run `eval_steps` batches under
    # `eval_policy` (None = exact). Non-exact policies are evaluated
    # weight-stationary: params are bound once per eval (gemm.bind).
    eval_every: int = 0
    eval_steps: int = 2
    eval_policy: Optional[gemm.GemmPolicy] = None


# jitted eval-loss wrappers, keyed on (loss_fn, policy contents): a fresh
# jax.jit(lambda ...) per evaluate() call would miss jit's function cache
# and recompile the whole eval forward at every eval interval
_JITTED_LOSS: Dict = {}


def _jitted_loss(loss_fn: Callable, policy: gemm.GemmPolicy) -> Callable:
    key = (loss_fn, policy.backend, policy.k, policy.n_bits, policy.acc_bits,
           tuple(sorted(policy.overrides.items())) if policy.overrides else None,
           policy.delta_rank, policy.delta_tol)
    fn = _JITTED_LOSS.get(key)
    if fn is None:
        if len(_JITTED_LOSS) > 32:
            _JITTED_LOSS.clear()
        fn = _JITTED_LOSS[key] = jax.jit(lambda p, b: loss_fn(p, b, policy))
    return fn


def evaluate(loss_fn: Callable, params, batches, *,
             policy: Optional[gemm.GemmPolicy] = None,
             bind_weights: bool = True) -> Dict[str, float]:
    """Forward-only eval of `loss_fn(params, batch, policy)` over `batches`.

    ``params`` may be raw or already-bound (`gemm.BoundParams`). With
    ``bind_weights`` (default) and a non-exact policy, raw params are bound
    once — every weight leaf quantized + backend-prepared up front — so the
    eval forward passes pay only the moving-activation cost per batch, the
    same weight-stationary regime the serve path uses. Bit-exact with the
    unbound forward (pinned by tests/test_bound_params.py).
    """
    policy = policy or gemm.EXACT
    if bind_weights and (policy.backend != "exact" or policy.overrides):
        # cached=False: mid-training params are transient — caching their
        # prepared forms would pin dead device tensors until LRU eviction
        params = gemm.bind(params, policy, cached=False)
    jitted = _jitted_loss(loss_fn, policy)
    losses = []
    for batch in batches:
        losses.append(float(jitted(params, batch)))
    out = {"eval_loss": float(np.mean(losses)) if losses else float("nan"),
           "eval_batches": float(len(losses))}
    return out


def train(cfg: ModelConfig, shape: ShapeSpec, step_fn: Callable,
          init_params_fn: Callable, lc: LoopConfig, *, n_micro: int = 1,
          data=None, shardings=None, eval_loss_fn: Optional[Callable] = None,
          log: Callable[[str], None] = print) -> Dict[str, float]:
    """Run the loop. `step_fn(params, opt, batch) -> (params, opt, metrics)`
    must already be jit'd (with shardings for the production mesh).

    With `lc.eval_every` and an `eval_loss_fn(params, batch, policy)` (e.g.
    `model.lm_loss`), every `eval_every` steps the current params are
    evaluated on held-out synthetic batches under `lc.eval_policy` —
    weight-stationary via `gemm.bind`, so approximate-backend eval does not
    re-quantize weights per batch."""
    data = data or SyntheticLM(cfg, shape, DataConfig(n_micro=n_micro))
    start_step = 0
    params = None
    opt = None
    if lc.resume and lc.ckpt_dir and ckpt.latest_step(lc.ckpt_dir) is not None:
        start_step = ckpt.latest_step(lc.ckpt_dir)
        shapes = jax.eval_shape(init_params_fn, jax.random.PRNGKey(0))
        params = ckpt.restore(lc.ckpt_dir, {"params": shapes},
                              shardings=None)["params"]
        params = jax.tree.map(jax.numpy.asarray, params)  # host -> device
        log(f"resumed params from step {start_step}")
    if params is None:
        params = init_params_fn(jax.random.PRNGKey(0))
    if opt is None:
        opt = adamw.init(params)

    saver = ckpt.AsyncCheckpointer(lc.ckpt_dir) if lc.ckpt_dir else None
    watchdog = fault.StragglerWatchdog()
    losses = []
    last_eval = None
    for step in range(start_step, lc.steps):
        batch = data.batch(step)
        t0 = time.time()
        params, opt, metrics = fault.run_with_retries(step_fn, params, opt,
                                                      batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if watchdog.observe(step, dt):
            log(f"[watchdog] step {step} straggled: {dt:.2f}s "
                f"(ewma {watchdog.ewma:.2f}s)")
        losses.append(loss)
        if step % lc.log_every == 0:
            log(f"step {step}: loss {loss:.4f}  ({dt:.2f}s/step)")
        if saver and step > start_step and step % lc.ckpt_every == 0:
            saver.save_async(step, {"params": params})
        if (eval_loss_fn and lc.eval_every and step > start_step
                and step % lc.eval_every == 0):
            ev = evaluate(eval_loss_fn, params,
                          [data.batch(lc.steps + 1 + i)
                           for i in range(lc.eval_steps)],
                          policy=lc.eval_policy)
            last_eval = ev
            log(f"step {step}: eval_loss {ev['eval_loss']:.4f} "
                f"(policy={getattr(lc.eval_policy, 'backend', 'exact')})")
    if saver:
        saver.save_async(lc.steps, {"params": params})
        saver.wait()
    out = {"first_loss": losses[0] if losses else float("nan"),
           "last_loss": losses[-1] if losses else float("nan"),
           "steps": len(losses),
           "straggler_events": len(watchdog.flagged)}
    if last_eval is not None:
        out.update(last_eval)
    return out
