"""Training loop: data -> step -> metrics -> periodic async checkpoint, with
resume-from-latest, straggler watchdog, and bounded transient retry."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.configs.base import ModelConfig, ShapeSpec
from repro.optim import adamw
from . import fault


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    resume: bool = True


def train(cfg: ModelConfig, shape: ShapeSpec, step_fn: Callable,
          init_params_fn: Callable, lc: LoopConfig, *, n_micro: int = 1,
          data=None, shardings=None,
          log: Callable[[str], None] = print) -> Dict[str, float]:
    """Run the loop. `step_fn(params, opt, batch) -> (params, opt, metrics)`
    must already be jit'd (with shardings for the production mesh)."""
    data = data or SyntheticLM(cfg, shape, DataConfig(n_micro=n_micro))
    start_step = 0
    params = None
    opt = None
    if lc.resume and lc.ckpt_dir and ckpt.latest_step(lc.ckpt_dir) is not None:
        start_step = ckpt.latest_step(lc.ckpt_dir)
        shapes = jax.eval_shape(init_params_fn, jax.random.PRNGKey(0))
        params = ckpt.restore(lc.ckpt_dir, {"params": shapes},
                              shardings=None)["params"]
        params = jax.tree.map(jax.numpy.asarray, params)  # host -> device
        log(f"resumed params from step {start_step}")
    if params is None:
        params = init_params_fn(jax.random.PRNGKey(0))
    if opt is None:
        opt = adamw.init(params)

    saver = ckpt.AsyncCheckpointer(lc.ckpt_dir) if lc.ckpt_dir else None
    watchdog = fault.StragglerWatchdog()
    losses = []
    for step in range(start_step, lc.steps):
        batch = data.batch(step)
        t0 = time.time()
        params, opt, metrics = fault.run_with_retries(step_fn, params, opt,
                                                      batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if watchdog.observe(step, dt):
            log(f"[watchdog] step {step} straggled: {dt:.2f}s "
                f"(ewma {watchdog.ewma:.2f}s)")
        losses.append(loss)
        if step % lc.log_every == 0:
            log(f"step {step}: loss {loss:.4f}  ({dt:.2f}s/step)")
        if saver and step > start_step and step % lc.ckpt_every == 0:
            saver.save_async(step, {"params": params})
    if saver:
        saver.save_async(lc.steps, {"params": params})
        saver.wait()
    return {"first_loss": losses[0] if losses else float("nan"),
            "last_loss": losses[-1] if losses else float("nan"),
            "steps": len(losses),
            "straggler_events": len(watchdog.flagged)}
