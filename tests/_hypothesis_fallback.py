"""Deterministic stand-in for the `hypothesis` API surface this suite uses.

The container may not ship `hypothesis` (it is in requirements-dev.txt for CI
and dev machines). Rather than skipping four whole test modules, test files do

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

and property tests then run `max_examples` seeded-random samples instead of
hypothesis' adaptive search — no shrinking, but the same assertions execute.
Only the subset of the API the suite uses is implemented (`st.integers`,
`st.sampled_from`, `st.booleans`, `@given` positional/keyword,
`@settings(max_examples=..., deadline=...)`).
"""
from __future__ import annotations

import functools
import random
import zlib

_DEFAULT_MAX_EXAMPLES = 20


class _IntegersStrategy:
    def __init__(self, min_value: int, max_value: int):
        self.min_value = min_value
        self.max_value = max_value

    def example(self, rng: random.Random) -> int:
        # hit the bounds often — hypothesis is good at edges, emulate that
        roll = rng.random()
        if roll < 0.1:
            return self.min_value
        if roll < 0.2:
            return self.max_value
        return rng.randint(self.min_value, self.max_value)


class _SampledFromStrategy:
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, rng: random.Random):
        return rng.choice(self.elements)


class strategies:  # noqa: N801 - mirrors the hypothesis module name
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntegersStrategy:
        return _IntegersStrategy(min_value, max_value)

    @staticmethod
    def sampled_from(elements) -> _SampledFromStrategy:
        return _SampledFromStrategy(elements)

    @staticmethod
    def booleans() -> _SampledFromStrategy:
        return _SampledFromStrategy([False, True])


st = strategies


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            # stable per-test seed so failures reproduce across runs
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.example(rng) for s in arg_strategies]
                drawn_kw = {name: s.example(rng)
                            for name, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)
        # pytest must not resolve the wrapped function's parameters as
        # fixtures: hide the original signature from inspect.signature
        del wrapper.__wrapped__
        return wrapper
    return deco
