"""Make the tests directory importable regardless of pytest import mode, so
test modules can fall back to `_hypothesis_fallback` when hypothesis is absent.

Also turns on the serve engine's retirement-time BlockPool invariant sweep
for the whole suite (off by default in production): every engine test then
doubles as a block-leak regression test."""
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))

from repro.launch import engine as _engine_mod  # noqa: E402

_engine_mod.VALIDATE_POOL_DEFAULT = True


@pytest.fixture(autouse=True, scope="module")
def _bounded_compile_state():
    """Drop JAX's compiled-executable caches after each test module.

    A full-suite run compiles thousands of distinct XLA programs in one
    process; on small CI boxes the accumulated compiler state eventually
    segfaults the CPU backend mid-compile (observed deterministically near
    the end of the suite). Modules rarely share executables, so clearing
    between modules bounds the accumulation for a few percent of extra
    compile time.
    """
    yield
    import jax

    jax.clear_caches()
