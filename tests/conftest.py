"""Make the tests directory importable regardless of pytest import mode, so
test modules can fall back to `_hypothesis_fallback` when hypothesis is absent."""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
