"""Linter fixture: a model module with deliberately planted violations.

NOT part of the shipping tree (lives under tests/fixtures/, outside the
``src/repro`` lint root) — tests/test_no_gemm_bypass.py lints this file
directly to pin the retired grep guard's coverage: every bypass the grep
caught must still produce a lint finding, so rule regressions surface as
test failures rather than silently-passing CI.
"""
import jax
import jax.numpy as jnp
from jax import lax


def bad_lm_head(x, p):
    return jnp.matmul(x, p["lm_head"])          # planted: jnp.matmul bypass


def bad_einsum(x, p):
    return jnp.einsum("btd,dv->btv", x, p["w"])  # planted: unsanctioned einsum


def bad_operator(x, p):
    return x @ p["w_up"]                         # planted: @ operator bypass


def bad_dot_general(x, p):
    return lax.dot_general(x, p["w"], (((1,), (0,)), ((), ())))


def bad_unnamed_dot(x, p, dot, policy):
    return dot(x, p["w"], policy)                # planted: dot without layer=


def bad_prng(x):
    return jax.random.PRNGKey(x.shape[0])        # planted: non-literal seed


def sanctioned_lookalike(x, p):
    # same equation as a sanctioned layers.py einsum — but this is NOT
    # layers.py, so the (file, equation) allowlist must still flag it
    return jnp.einsum("bkgqd,bkcd->bkgqc", x, p["probe"])
