"""ABFT checksum guards (core/abft.py) on the GEMM path.

Pins the PR-6 integrity contract:

* **Zero false positives**: with ``guard='detect'`` every backend produces
  bit-identical outputs to the unguarded run on clean data — the detection
  thresholds are derived from the quantization/approximation bounds, so the
  *intended* approximation error never trips the guard, eagerly or under jit.
* **Single-bit weight faults are detected**: flipping any one bit of a
  prepared operand's ``values`` (or of a derived leaf — delta tables, scales)
  raises ``AbftFaultError`` naming the layer.
* **Corrupted device tables are detected**: the golden-copy compare
  (``verify_tables``) flags a poisoned product/factor table before results
  are consumed.
* **Thresholds**: exact-int backends get τ=0; approximate τ scales with the
  contraction size and the backend's per-product error bound; everything is
  capped below int32-wraparound soundness.
* ``guard='recompute'`` is the identity on clean data.

The ``faultinject`` campaign (scheduled CI job, also in the slow tier) sweeps
seeded random flips across every backend and asserts 100% detection.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import abft, gemm
from repro.launch import faults as F

INT_BACKENDS = ("exact", "mxu_int8", "approx_lut", "approx_oracle",
                "approx_onehot", "approx_delta")
PREP_BACKENDS = tuple(b for b in INT_BACKENDS if b != "exact")


def _pol(backend, guard="detect", k=4):
    return gemm.GemmPolicy(backend=backend, k=k, guard=guard)


def _int_ops(m=6, kd=16, n=8, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-128, 128, (m, kd)), jnp.int32)
    b = jnp.asarray(rng.integers(-128, 128, (kd, n)), jnp.int32)
    return a, b


def _float_ops(m=5, kd=16, n=7, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, kd)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(kd, n)), jnp.float32)
    return a, b


# --- clean data: zero false positives, bit-identical outputs -----------------

@pytest.mark.parametrize("backend", INT_BACKENDS)
def test_clean_int_no_false_positive(backend):
    a, b = _int_ops()
    want = gemm.dot(a, b, _pol(backend, "none"))
    for guard in ("detect", "recompute"):
        got = gemm.dot(a, b, _pol(backend, guard))  # must not raise
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert abft.drain_faults() == []


@pytest.mark.parametrize("backend", INT_BACKENDS)
def test_clean_prepared_no_false_positive(backend):
    a, b = _int_ops(seed=1)
    pol = _pol(backend, "detect")
    prep = gemm.prepare_weights(b, pol, layer="t")
    want = gemm.dot(a, b, _pol(backend, "none"))
    np.testing.assert_array_equal(np.asarray(gemm.dot(a, prep, pol)),
                                  np.asarray(want))
    assert abft.drain_faults() == []


@pytest.mark.parametrize("backend", PREP_BACKENDS)
def test_clean_float_bound_no_false_positive(backend):
    """The serving path: float activations against a policy-bound weight."""
    a, b = _float_ops()
    pol = _pol(backend, "detect")
    prep = gemm.prepare_weights(b, pol, layer="t")
    want = gemm.dot(a, gemm.prepare_weights(b, _pol(backend, "none"),
                                            layer="t"), _pol(backend, "none"))
    np.testing.assert_array_equal(np.asarray(gemm.dot(a, prep, pol)),
                                  np.asarray(want))
    assert abft.drain_faults() == []


def test_clean_float_exact_unprepared_no_false_positive():
    a, b = _float_ops(seed=3)
    pol = _pol("exact", "detect")
    want = gemm.dot(a, b, gemm.EXACT)
    np.testing.assert_array_equal(np.asarray(gemm.dot(a, b, pol)),
                                  np.asarray(want))
    assert abft.drain_faults() == []


@pytest.mark.parametrize("backend", ("approx_lut", "approx_delta"))
def test_clean_jit_no_false_positive(backend):
    """Under jit the guard records to the ledger instead of raising — clean
    data must leave the ledger empty after the effects barrier."""
    a, b = _int_ops(seed=2)
    pol = _pol(backend, "detect")
    prep = gemm.prepare_weights(b, pol, layer="t")
    out = jax.jit(lambda x: gemm.dot(x, prep, pol))(a)
    jax.block_until_ready(out)
    assert abft.drain_faults() == []


# --- single-bit faults are detected ------------------------------------------

@pytest.mark.parametrize("backend", PREP_BACKENDS)
def test_weight_values_flip_detected(backend):
    a, b = _int_ops(seed=4)
    pol = _pol(backend, "detect")
    prep = gemm.prepare_weights(b, pol, layer="blk0.w")
    bad = dataclasses.replace(prep, values=F.flip_bit(prep.values, 17, 3))
    with pytest.raises(abft.AbftFaultError) as ei:
        gemm.dot(a, bad, pol, layer="blk0.w")
    assert "blk0.w" in str(ei.value)       # the fault names its layer


@pytest.mark.parametrize("backend", ("approx_onehot", "approx_delta"))
def test_derived_leaf_flip_detected(backend):
    """A flip in a *derived* prepared leaf (not `values`) trips the aux
    bitcast fingerprint even though the row/col checksums cannot see it."""
    a, b = _int_ops(seed=5)
    pol = _pol(backend, "detect")
    prep = gemm.prepare_weights(b, pol, layer="blk1.w")
    if backend == "approx_onehot":
        assert prep.t_b is not None
        bad = dataclasses.replace(prep, t_b=F.flip_bit(prep.t_b, 5, 1))
    else:
        d = prep.delta
        assert d is not None
        bad = dataclasses.replace(
            prep, delta=dataclasses.replace(
                d, gather_tab=F.flip_bit(d.gather_tab, 9, 2)))
    with pytest.raises(abft.AbftFaultError):
        gemm.dot(a, bad, pol)


def test_float_scale_flip_detected():
    """Quantization scales ride the aux fingerprint on the float path."""
    a, b = _float_ops(seed=6)
    pol = _pol("approx_lut", "detect")
    prep = gemm.prepare_weights(b, pol, layer="blk2.w")
    assert prep.scale is not None
    bad = dataclasses.replace(prep, scale=F.flip_bit(prep.scale, 0, 30))
    with pytest.raises(abft.AbftFaultError):
        gemm.dot(a, bad, pol)


def test_jit_fault_lands_in_ledger():
    a, b = _int_ops(seed=7)
    pol = _pol("approx_lut", "detect")
    prep = gemm.prepare_weights(b, pol, layer="jit.w")
    bad = dataclasses.replace(prep, values=F.flip_bit(prep.values, 3, 6))
    out = jax.jit(lambda x: gemm.dot(x, bad, pol, layer="jit.w"))(a)
    jax.block_until_ready(out)
    faults = abft.drain_faults()
    assert faults and any("jit.w" in f.layer for f in faults)
    assert abft.drain_faults() == []        # drained


@pytest.mark.parametrize("which,backend", [("product", "approx_lut"),
                                           ("factors", "approx_delta")])
def test_poisoned_table_detected(which, backend):
    a, b = _int_ops(seed=8)
    pol = _pol(backend, "detect")
    inj = F.FaultInjector(3)
    with inj.poisoned_tables(which=which):
        with pytest.raises(abft.AbftFaultError):
            gemm.dot(a, b, pol)
    gemm.dot(a, b, pol)                     # scope restored: clean again
    assert abft.drain_faults() == []


# --- thresholds ---------------------------------------------------------------

def test_thresholds_exact_backends_are_zero():
    for be in ("exact", "mxu_int8"):
        assert abft.int_thresholds(_pol(be), be, (4, 16), (16, 8)) == (0, 0)


def test_thresholds_scale_with_contraction_and_cap():
    pol = _pol("approx_lut")
    r1, c1 = abft.int_thresholds(pol, "approx_lut", (4, 16), (16, 8))
    r2, c2 = abft.int_thresholds(pol, "approx_lut", (4, 32), (32, 8))
    assert 0 < r1 < r2 and 0 < c1 <= c2
    cap = abft.int_thresholds(pol, "approx_lut", (1 << 20, 1 << 20),
                              (1 << 20, 1 << 20))
    assert cap == (1 << 30, 1 << 30)        # int32-wraparound soundness cap


def test_threshold_oracle_covers_fused_chain():
    """approx_oracle's fused MAC chain runs accumulator bits through the
    approximate columns, so its bound must dominate the LUT model's."""
    shapes = ((4, 16), (16, 8))
    r_lut, _ = abft.int_thresholds(_pol("approx_lut"), "approx_lut", *shapes)
    r_orc, _ = abft.int_thresholds(_pol("approx_oracle"), "approx_oracle",
                                   *shapes)
    assert r_orc >= r_lut


def test_guard_validation():
    with pytest.raises(ValueError):
        gemm.as_policy(gemm.GemmPolicy(backend="exact", guard="bogus"))


# --- fault-injection campaign (scheduled CI job) ------------------------------

@pytest.mark.faultinject
@pytest.mark.slow
@pytest.mark.parametrize("backend", PREP_BACKENDS)
def test_campaign_random_weight_flips_all_detected(backend):
    """Seeded sweep: every random single-bit flip in a prepared operand's
    leaves is detected; interleaved clean runs never false-positive."""
    a, b = _int_ops(m=8, kd=24, n=8, seed=9)
    pol = _pol(backend, "detect")
    prep = gemm.prepare_weights(b, pol, layer=f"campaign.{backend}")
    clean = np.asarray(gemm.dot(a, prep, pol))
    rng = np.random.default_rng(42)
    flat, treedef = jax.tree_util.tree_flatten(prep)
    sized = [i for i, lf in enumerate(flat) if np.asarray(lf).size]
    detected = 0
    for trial in range(24):
        # flip one bit of one array leaf of the whole prepared pytree —
        # values, delta factors, onehot tables, scales, and the checksum
        # metadata itself are all fair game
        li = sized[int(rng.integers(len(sized)))]
        leaf = flat[li]
        idx = int(rng.integers(np.asarray(leaf).size))
        bit = int(rng.integers(np.asarray(leaf).dtype.itemsize * 8))
        bad_flat = list(flat)
        bad_flat[li] = F.flip_bit(leaf, idx, bit)
        bad = jax.tree_util.tree_unflatten(treedef, bad_flat)
        try:
            gemm.dot(a, bad, pol)
        except abft.AbftFaultError:
            detected += 1
        # clean run in between must stay silent and bit-identical
        np.testing.assert_array_equal(np.asarray(gemm.dot(a, prep, pol)),
                                      clean)
    assert detected == 24, f"{backend}: only {detected}/24 flips detected"
    assert abft.drain_faults() == []
