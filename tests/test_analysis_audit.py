"""Kernel contract auditor coverage (repro.analysis.kernel_audit/contracts).

Fixture geometries with deliberate violations — each yields exactly one
typed finding; a clean spec yields zero; the JSON report round-trips; the
full registry audits clean on the shipped tree; and the planners
(`gemm_block_plan`, `paged_kernel_plan`) provably never emit a geometry the
auditor rejects (property tests).
"""
import pathlib

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.analysis import contracts, kernel_audit, run
from repro.analysis.findings import Report

pytestmark = pytest.mark.analysis

REPO_ROOT = pathlib.Path(__file__).parent.parent

MiB = 1024 * 1024


def _geom(operands, grid=(2, 1), scalar_prefetch=(), scratch=0, suppress=None):
    return contracts.KernelGeometry(
        kernel="tests.fixture", grid=grid, operands=tuple(operands),
        scalar_prefetch=tuple(scalar_prefetch), scratch_bytes=scratch,
        tag="fixture", suppress=suppress or {})


def _findings(geom, budget=contracts.DEFAULT_VMEM_BUDGET):
    return contracts.check_geometry(geom, budget)


# ---------------------------------------------------------------------------
# the five deliberate violations — exactly one typed finding each
# ---------------------------------------------------------------------------

def test_f32_sublane_misaligned_block():
    # (7, 128) f32 block in a (14, 128) array: 7 is neither a multiple of the
    # f32 sublane tile (8) nor the full extent (14); divisibility is fine
    fs = _findings(_geom([contracts.OperandSpec(
        "x", (14, 128), "float32", (7, 128), lambda i, j: (i, j))]))
    assert [f.rule for f in fs] == ["tile-misaligned"], [f.format() for f in fs]


def test_int8_block_misaligned_to_32x128():
    # int8 wants (32, 128): a (16, 128) block in a (64, 128) array misses the
    # sublane tile without being the full extent
    fs = _findings(_geom([contracts.OperandSpec(
        "w", (64, 128), "int8", (16, 128), lambda i, j: (i, j))],
        grid=(4, 1)))
    assert [f.rule for f in fs] == ["tile-misaligned"], [f.format() for f in fs]


def test_vmem_over_budget_cell():
    # streamed (256, 256) f32 block double-buffers to 512 KiB > 256 KiB budget
    fs = _findings(_geom([contracts.OperandSpec(
        "x", (512, 256), "float32", (256, 256), lambda i, j: (i, 0))],
        grid=(2, 1)), budget=256 * 1024)
    assert [f.rule for f in fs] == ["vmem-overflow"], [f.format() for f in fs]


def test_f32_scalar_prefetch_operand():
    fs = _findings(_geom(
        [contracts.OperandSpec("x", (8, 128), "float32", (8, 128),
                               lambda i, j: (0, 0))],
        grid=(1, 1),
        scalar_prefetch=[contracts.ScalarSpec("lens", (4,), "float32")]))
    assert [f.rule for f in fs] == ["smem-illegal-dtype"], \
        [f.format() for f in fs]


def test_out_of_bounds_index_map():
    # 16/8 = 2 row blocks, but the map returns block (i + 1): cell i=1 -> 2
    fs = _findings(_geom([contracts.OperandSpec(
        "x", (16, 128), "float32", (8, 128), lambda i, j: (i + 1, 0))],
        grid=(2, 1)))
    assert [f.rule for f in fs] == ["index-oob"], [f.format() for f in fs]


# ---------------------------------------------------------------------------
# clean specs, remaining rules, suppressions
# ---------------------------------------------------------------------------

def test_clean_spec_zero_findings():
    fs = _findings(_geom([
        contracts.OperandSpec("a", (512, 256), "int8", (256, 256),
                              lambda i, j: (i, 0)),
        contracts.OperandSpec("o", (512, 128), "float32", (256, 128),
                              lambda i, j: (i, 0)),
    ], grid=(2, 1),
        scalar_prefetch=[contracts.ScalarSpec("lens", (4,), "int32")]))
    assert fs == []


def test_full_extent_edge_tile_is_legal():
    # a 100-row f32 block covering the whole axis: Mosaic pads one edge tile
    fs = _findings(_geom([contracts.OperandSpec(
        "x", (100, 128), "float32", (100, 128), lambda i, j: (0, 0))],
        grid=(1, 1)))
    assert fs == []


def test_unmasked_remainder_flagged_masked_passes():
    spec = dict(name="x", shape=(300, 128), dtype="float32",
                block=(128, 128), index_map=lambda i, j: (i, 0))
    fs = _findings(_geom([contracts.OperandSpec(**spec)], grid=(3, 1)))
    assert [f.rule for f in fs] == ["block-divisibility"]
    fs = _findings(_geom([contracts.OperandSpec(**spec, masked_axes=(0,))],
                         grid=(3, 1)))
    assert fs == []


def test_grid_empty():
    fs = _findings(_geom([contracts.OperandSpec(
        "x", (8, 128), "float32", (8, 128), lambda i, j: (0, 0))],
        grid=(0, 1)))
    assert [f.rule for f in fs] == ["grid-empty"]


def test_registry_suppression():
    fs = _findings(_geom([contracts.OperandSpec(
        "x", (14, 128), "float32", (7, 128), lambda i, j: (i, j))],
        suppress={"tile-misaligned": "fixture: known-odd geometry"}))
    assert len(fs) == 1 and fs[0].suppressed
    assert fs[0].suppress_reason == "fixture: known-odd geometry"


# ---------------------------------------------------------------------------
# report schema round-trip
# ---------------------------------------------------------------------------

def test_json_report_schema_roundtrip():
    fs = _findings(_geom([contracts.OperandSpec(
        "x", (14, 128), "float32", (7, 128), lambda i, j: (i, j))]))
    rep = Report(findings=fs, meta={"fixture": True})
    d = rep.to_dict()
    assert d["schema_version"] == 1
    assert d["counts"] == {"total": 1, "suppressed": 0, "new": 1}
    back = Report.from_json(rep.to_json())
    assert [f.fingerprint for f in back.findings] == \
        [f.fingerprint for f in rep.findings]
    assert back.findings[0] == rep.findings[0]
    assert back.meta == {"fixture": True}


def test_fingerprint_ignores_line_numbers():
    a = _findings(_geom([contracts.OperandSpec(
        "x", (14, 128), "float32", (7, 128), lambda i, j: (i, j))]))[0]
    import dataclasses
    b = dataclasses.replace(a, line=a.line + 40)
    assert a.fingerprint == b.fingerprint


# ---------------------------------------------------------------------------
# shipped tree: registry audits clean; wrappers never out-plan the auditor
# ---------------------------------------------------------------------------

def test_registry_audit_clean_on_shipped_tree():
    rep = kernel_audit.audit()
    assert rep.meta["cells"] >= 40          # all five kernels, real grids
    bad = [f.format() for f in rep.findings if not f.suppressed]
    assert not bad, "\n".join(bad)


def test_full_run_zero_unsuppressed():
    rep = run(REPO_ROOT)
    bad = [f.format() for f in rep.active()]
    assert not bad, "\n".join(bad)


def test_resident_pool_blocking_rejected():
    """Regression for the paged_attention fix: blocking a whole production
    pool into VMEM (the pre-fix BlockSpec) must be auditor-rejected; the
    shipped ANY-space + chunk-scratch contract is clean at the same size."""
    n_pool, bs, kh, d = 2049, 16, 16, 128
    resident = _geom([contracts.OperandSpec(
        "k_pool", (n_pool, bs, kh, d), "float32", (n_pool, bs, kh, d),
        lambda bi, qi, si: (0, 0, 0, 0))], grid=(4, 1, 1))
    assert any(f.rule == "vmem-overflow" for f in _findings(resident))
    from repro.launch.autotune import paged_kernel_plan
    max_len = n_pool * bs // 4
    kv_chunk, n_splits = paged_kernel_plan(max_len, bs, batch=4, kv_heads=kh,
                                           head_dim=d)
    fs = kernel_audit.check_paged_geometry(
        kv_chunk, n_splits, max_len=max_len, block_size=bs, batch=4,
        kv_heads=kh, head_dim=d)
    assert fs == []


def test_engine_default_geometry_clean():
    # ServeEngine defaults: max_slots=4, max_len=64, block_size=8
    fs = kernel_audit.check_paged_geometry(
        64, 1, max_len=64, block_size=8, batch=4, kv_heads=4, head_dim=64)
    assert fs == []


def test_flash_envelope_boundary():
    env = kernel_audit.flash_kv_envelope(128)
    assert env >= 2048
    from repro.kernels import flash_attention
    over = flash_attention.tpu_contract(1, 1, 128, env * 4, 128)
    assert any(f.rule == "vmem-overflow"
               for f in contracts.check_geometry(over))


def test_block_picker_matches_ops():
    """kernel_audit mirrors ops._blocks' TPU arithmetic — pin them together."""
    from repro.kernels import ops
    for dim in (1, 7, 64, 100, 128, 200, 256, 300, 512, 1000, 4096):
        for pref in (128, 256, 512):
            assert kernel_audit._blocks(dim, pref) == \
                ops._blocks(dim, pref, 128), (dim, pref)


# ---------------------------------------------------------------------------
# planner properties: no plan the auditor rejects
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(max_len=st.integers(min_value=8, max_value=65536),
       block_size=st.sampled_from([8, 16, 32]),
       batch=st.integers(min_value=1, max_value=16),
       kv_heads=st.sampled_from([1, 2, 4, 8, 16]),
       q_per_kv=st.sampled_from([1, 2, 4, 8]),
       head_dim=st.sampled_from([64, 128, 256]),
       kv_dtype=st.sampled_from(["float32", "int8"]),
       allow_splits=st.booleans(),
       budget_mib=st.sampled_from([2, 4, 16]))
def test_paged_plan_never_rejected(max_len, block_size, batch, kv_heads,
                                   q_per_kv, head_dim, kv_dtype,
                                   allow_splits, budget_mib):
    from repro.launch.autotune import paged_kernel_plan
    budget = budget_mib * MiB
    try:
        kv_chunk, n_splits = paged_kernel_plan(
            max_len, block_size, batch=batch, kv_heads=kv_heads,
            allow_splits=allow_splits, head_dim=head_dim, q_per_kv=q_per_kv,
            kv_dtype=kv_dtype, vmem_budget=budget)
    except kernel_audit.ContractViolation:
        return      # refusing to plan an unlowerable geometry is also correct
    fs = kernel_audit.check_paged_geometry(
        kv_chunk, n_splits, max_len=max_len, block_size=block_size,
        batch=batch, kv_heads=kv_heads, head_dim=head_dim,
        q_per_kv=q_per_kv, kv_dtype=kv_dtype, vmem_budget=budget)
    assert fs == [], "\n".join(f.format() for f in fs)
    assert kv_chunk % block_size == 0 and n_splits >= 1


@settings(max_examples=60, deadline=None)
@given(m=st.integers(min_value=1, max_value=4096),
       n=st.integers(min_value=1, max_value=4096),
       k=st.integers(min_value=1, max_value=4096),
       kernel=st.sampled_from(["delta", "systolic", "lut"]),
       rank=st.sampled_from([0, 1, 10, 21]),
       budget_mib=st.sampled_from([1, 4, 16]))
def test_gemm_plan_never_rejected(m, n, k, kernel, rank, budget_mib):
    budget = budget_mib * MiB
    try:
        bm, bn, bk = kernel_audit.gemm_block_plan(
            m, n, k, kernel=kernel, rank=rank, vmem_budget=budget)
    except kernel_audit.ContractViolation:
        return
    mod = kernel_audit._gemm_module(kernel)
    geom = kernel_audit._gemm_contract(mod, m, n, k, bm, bn, bk, rank, 256)
    fs = [f for f in contracts.check_geometry(geom, budget)
          if not f.suppressed]
    assert fs == [], "\n".join(f.format() for f in fs)


def test_gemm_plan_shrinks_under_tight_budget():
    full = kernel_audit.gemm_block_plan(4096, 4096, 4096, kernel="delta",
                                        rank=21)
    tight = kernel_audit.gemm_block_plan(4096, 4096, 4096, kernel="delta",
                                         rank=21, vmem_budget=MiB // 2)
    assert full == (256, 256, 256)
    # a tighter budget shrinks the plan (some block halved) but never below
    # the MXU tile edge — and the result is still contract-clean
    import math
    assert math.prod(tight) < math.prod(full) and min(tight) >= 128
