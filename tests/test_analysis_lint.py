"""AST linter rules, suppression mechanics, baseline workflow, CLI gate
(repro.analysis.lint / baseline / __main__).

Each rule is exercised on planted sources in a throwaway tree; the shipped
tree must lint clean (tests/test_analysis_audit.py pins the combined run,
tests/test_no_gemm_bypass.py pins the gemm-bypass rule specifically).
"""
import json
import pathlib
import textwrap

import pytest

from repro.analysis import baseline, lint
from repro.analysis.__main__ import main as cli_main
from repro.analysis.findings import Report

pytestmark = pytest.mark.analysis


def _tree(tmp_path, files):
    root = tmp_path / "repo"
    for rel, src in files.items():
        p = root / "src" / "repro" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return root


def _rules(findings, suppressed=False):
    return sorted(f.rule for f in findings if f.suppressed == suppressed)


# ---------------------------------------------------------------------------
# per-rule units
# ---------------------------------------------------------------------------

def test_host_sync_scope_is_jit_steps_only(tmp_path):
    root = _tree(tmp_path, {"launch/steps.py": """
        import jax
        import numpy as np

        def make_train_step(cfg):
            def train_step(state, batch):
                loss = float(state["loss"])          # flagged
                host = np.asarray(batch["x"])        # flagged
                tok = state["tok"].item()            # flagged
                state["x"].block_until_ready()       # flagged
                n = int(8)                           # literal: fine
                return loss, host, tok, n
            return jax.jit(train_step)

        def host_helper(x):
            return float(x), np.asarray(x)           # outside a step: fine

        def directly_jitted(x):
            return x.item()                          # flagged via jax.jit(...)
        step = jax.jit(directly_jitted)
        """})
    fs, _ = lint.lint_tree(root)
    host = [f for f in fs if f.rule == "host-sync-in-step"]
    assert len(host) == 5, [f.format() for f in fs]
    assert all("host_helper" not in f.message for f in host)


def test_host_sync_covers_scan_bodies(tmp_path):
    # the multi-step dispatcher traces its horizon body via jax.lax.scan —
    # a scan body is jit-step scope even when defined outside a builder
    root = _tree(tmp_path, {"launch/steps.py": """
        import jax
        import numpy as np

        def body(carry, x):
            tok = np.asarray(carry["tok"])           # flagged
            n = float(carry["n"])                    # flagged
            return carry, tok + n

        def make_multi_step(cfg):
            def multi_step(state):
                def sub_step(carry, i):
                    carry["x"].block_until_ready()   # flagged: builder scope
                    return carry, i
                return jax.lax.scan(sub_step, state, None, length=4)
            return jax.jit(multi_step)

        def drive(state):
            return jax.lax.scan(body, state, None, length=8)

        def host_side(state):
            return np.asarray(state)                 # outside a step: fine
        """})
    fs, _ = lint.lint_tree(root)
    host = [f for f in fs if f.rule == "host-sync-in-step"]
    assert len(host) == 3, [f.format() for f in fs]
    assert all("host_side" not in f.message for f in host)


def test_global_random_rule(tmp_path):
    root = _tree(tmp_path, {"launch/trace.py": """
        import random
        import numpy as np

        def bad():
            a = random.random()                      # flagged: stdlib global
            b = np.random.rand(3)                    # flagged: global np RNG
            c = np.random.default_rng()              # flagged: unseeded
            return a, b, c

        def good(seed):
            rng = np.random.default_rng(seed)        # sanctioned idiom
            return rng.random(3)
        """})
    fs, _ = lint.lint_tree(root)
    assert _rules(fs) == ["global-random"] * 3, [f.format() for f in fs]


def test_prng_discipline_rule(tmp_path):
    src = """
        import jax

        def bad_seed(step):
            return jax.random.PRNGKey(step)          # flagged: non-literal

        def reuse(key, shape):
            a = jax.random.normal(key, shape)
            b = jax.random.uniform(key, shape)       # flagged: key reuse
            return a + b

        def good(key, shape):
            k1, k2 = jax.random.split(key)
            return jax.random.normal(k1, shape) + jax.random.uniform(k2, shape)

        def root():
            return jax.random.PRNGKey(0)             # literal: fine
        """
    root = _tree(tmp_path, {"models/mod.py": src,
                            "launch/sampling.py": src})
    fs, _ = lint.lint_tree(root)
    prng = [f for f in fs if f.rule == "prng-discipline"]
    # sampling.py (the fold-in idiom's home) is out of scope for this rule
    assert len(prng) == 2 and all("sampling" not in f.path for f in prng), \
        [f.format() for f in fs]


def test_suppression_comment_same_line_and_above(tmp_path):
    root = _tree(tmp_path, {"models/m.py": """
        import jax.numpy as jnp

        def f(x, p):
            a = jnp.matmul(x, p["w"])  # lint: allow(gemm-bypass): unit fixture
            # lint: allow(gemm-bypass): line-above form
            b = jnp.matmul(x, p["w"])
            c = jnp.matmul(x, p["w"])  # lint: allow(dot-layer): wrong rule
            return a, b, c
        """})
    fs, _ = lint.lint_tree(root)
    assert _rules(fs, suppressed=True) == ["gemm-bypass"] * 2
    active = [f for f in fs if not f.suppressed]
    assert _rules(active) == ["gemm-bypass"]         # wrong-rule allow ignored
    assert fs[0].suppress_reason == "unit fixture"


def test_suppressed_findings_do_not_gate():
    rep = Report()
    from repro.analysis.findings import Finding
    sup = Finding("lint", "gemm-bypass", "error", "a.py", 3, "s", "m",
                  suppressed=True, suppress_reason="why")
    new = Finding("lint", "gemm-bypass", "error", "a.py", 9, "s2", "m")
    rep.extend([sup, new])
    assert rep.active() == [new]
    assert rep.active([new.fingerprint]) == []


# ---------------------------------------------------------------------------
# baseline workflow + CLI gate
# ---------------------------------------------------------------------------

def _bad_repo(tmp_path):
    root = _tree(tmp_path, {"models/m.py": """
        import jax.numpy as jnp

        def f(x, p):
            return jnp.matmul(x, p["w"])
        """})
    return root


def test_cli_gate_and_baseline_roundtrip(tmp_path, capsys):
    root = _bad_repo(tmp_path)
    args = ["--root", str(root), "--only", "lint"]
    # new finding -> exit 1
    assert cli_main(args + ["--format", "json"]) == 1
    out = json.loads(capsys.readouterr().out)
    assert out["counts"]["new"] == 1
    assert out["findings"][0]["rule"] == "gemm-bypass"

    # accept as baseline -> exit 0, fingerprints persisted
    assert cli_main(args + ["--write-baseline"]) == 0
    capsys.readouterr()
    fps = baseline.load(root / baseline.DEFAULT_NAME)
    assert fps == [out["findings"][0]["fingerprint"]]
    assert cli_main(args) == 0

    # a *second* violation still gates: baseline covers only accepted debt
    m = root / "src" / "repro" / "models" / "m.py"
    m.write_text(m.read_text() +
                 "\ndef g(x, p):\n    return jnp.matmul(x, p['v'])\n")
    assert cli_main(args) == 1

    # fixing the original finding: stale fingerprint is pruned on rewrite
    assert cli_main(args + ["--write-baseline"]) == 0
    capsys.readouterr()
    assert len(baseline.load(root / baseline.DEFAULT_NAME)) == 2


def test_shipped_baseline_is_empty():
    repo = pathlib.Path(__file__).parent.parent
    assert baseline.load(repo / baseline.DEFAULT_NAME) == []


def test_baseline_rejects_unknown_schema(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"schema_version": 99, "fingerprints": []}))
    with pytest.raises(ValueError):
        baseline.load(p)
