"""Backend parity for the image apps: every approximate backend reproduces the
legacy numpy product-table path bit-for-bit.

Before this refactor the apps carried private table-lookup GEMMs
(``dct._gemm(fused=False)``, ``edge.conv_gemm``, ``bdcn.conv_layer``). Those
implementations are pinned *here* as ``_reference_*`` (using the cached
``emulate.product_table``) so the app layer can route through ``GemmPolicy``
while this tier proves the arithmetic is unchanged for ``approx_lut``,
``approx_onehot``, and ``approx_delta`` (at the exact rank); the fused-MAC
oracle path (``dct._gemm(fused=True)``) is pinned via ``emulate.pe_mac``.
"""
import numpy as np
import pytest

from repro.apps import bdcn, dct, edge, images
from repro.core import emulate, gemm, quant

PARITY_BACKENDS = ("approx_lut", "approx_onehot", "approx_delta")
SIZE = 48


# --- pinned legacy implementations ------------------------------------------

def _reference_gemm(a: np.ndarray, b: np.ndarray, k: int) -> np.ndarray:
    """The legacy apps/ table path: batched product-table lookups."""
    table = emulate.product_table(8, k, True, 24)
    return table[a[..., :, :, None] & 255, b[..., None, :, :] & 255].sum(axis=-2)


def _reference_fused_gemm(a: np.ndarray, b: np.ndarray, k: int) -> np.ndarray:
    """The legacy ``dct._gemm(fused=True)`` bit-level PE chain."""
    acc = np.zeros(a.shape[:-1] + (b.shape[-1],), np.int32)
    for kk in range(a.shape[-1]):
        acc = np.asarray(emulate.pe_mac(
            a[..., :, kk][..., :, None], b[..., kk, :][..., None, :], acc,
            n_bits=8, k=k, signed=True, acc_bits=24))
    return acc


def _reference_dct_forward(blocks: np.ndarray, k: int,
                           fused: bool = False) -> np.ndarray:
    g = _reference_fused_gemm if fused else _reference_gemm
    x = blocks.astype(np.int32) - 128
    t = np.broadcast_to(dct.T8, x.shape)
    s1 = np.clip(g(t, x, k) >> 7, -128, 127).astype(np.int32)
    return g(s1, np.broadcast_to(dct.T8.T.copy(), x.shape), k)


def _reference_conv_gemm(img: np.ndarray, kernel: np.ndarray,
                         k: int) -> np.ndarray:
    h, w = img.shape
    cols = edge.im2col(img.astype(np.int32) - 128)
    kflat = kernel.reshape(-1, 1)
    table = emulate.product_table(8, k, True, 24)
    out = table[cols & 255, kflat[None, :, 0] & 255].sum(axis=1)
    return out.reshape(h - 2, w - 2)


def _reference_conv_layer(x: np.ndarray, w: np.ndarray, k: int,
                          exact: bool) -> np.ndarray:
    c_out = w.shape[0]
    _, h, wd = x.shape
    cols = bdcn._im2col_nchw(x)
    wmat = w.reshape(c_out, -1).T
    xq = quant.quantize(np.asarray(cols))
    wq = quant.quantize(np.asarray(wmat), axis=0)
    a = np.asarray(xq.values)
    b = np.asarray(wq.values)
    if exact:
        acc = a.astype(np.int64) @ b.astype(np.int64)
    else:
        table = emulate.product_table(8, k, True, 24).astype(np.int64)
        acc = np.zeros((a.shape[0], b.shape[1]), np.int64)
        for kk in range(a.shape[1]):
            acc += table[a[:, kk] & 255][:, b[kk, :] & 255]
    out = acc.astype(np.float64) * np.asarray(xq.scale) * np.asarray(wq.scale)
    out = np.maximum(out, 0.0)
    return out.T.reshape(c_out, h, wd).astype(np.float32)


def _reference_bdcn_forward(img: np.ndarray, ws, k: int,
                            n_approx_blocks: int = 2) -> np.ndarray:
    x = (img.astype(np.float32) - 128.0) / 128.0
    x = x[None]
    side_maps = []
    for li, w in enumerate(ws):
        exact = (li >= n_approx_blocks) or k == 0
        x = _reference_conv_layer(x, w, k, exact)
        side_maps.append(np.abs(x).mean(axis=0))
    fwd = np.zeros_like(side_maps[0])
    for m in side_maps:
        fwd = 0.5 * fwd + m
    bwd = np.zeros_like(side_maps[0])
    for m in reversed(side_maps):
        bwd = 0.5 * bwd + m
    fused = fwd + bwd
    fused = 255.0 * fused / max(fused.max(), 1e-9)
    return np.clip(fused, 0, 255)


# --- parity -----------------------------------------------------------------

@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("k", [0, 2, 4, 6])
def test_dct_backend_parity(backend, k):
    blocks = images.to_blocks(images.test_image(SIZE, 0))
    want = _reference_dct_forward(blocks, k)
    got = dct.forward_dct_blocks(blocks, k, policy=backend)
    np.testing.assert_array_equal(got, want)


def test_dct_oracle_backend_pins_fused_path():
    blocks = images.to_blocks(images.test_image(SIZE, 0))
    want = _reference_dct_forward(blocks, 4, fused=True)
    got = dct.forward_dct_blocks(blocks, 4, policy="approx_oracle")
    np.testing.assert_array_equal(got, want)
    # the default policy is the paper's fused-MAC simulation
    np.testing.assert_array_equal(dct.forward_dct_blocks(blocks, 4), want)


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("k", [0, 3, 6])
def test_edge_backend_parity(backend, k):
    img = images.test_image(SIZE, 1)
    want = _reference_conv_gemm(img, edge.LAPLACIAN, k)
    got = edge.conv_gemm(img, edge.LAPLACIAN, k, policy=backend)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
def test_edge_backend_parity_laplacian8(backend):
    img = images.test_image(SIZE, 2)
    want = _reference_conv_gemm(img, edge.LAPLACIAN8, 4)
    got = edge.conv_gemm(img, edge.LAPLACIAN8, 4, policy=backend)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("backend", PARITY_BACKENDS)
@pytest.mark.parametrize("k", [0, 4])
def test_bdcn_backend_parity(backend, k):
    img = images.test_image(SIZE, 0)
    ws = bdcn.make_weights([6, 8, 8], 0)
    want = _reference_bdcn_forward(img, ws, k)
    got = bdcn.bdcn_forward(img, ws, k, policy=backend)
    np.testing.assert_array_equal(got, want)


def test_bdcn_hybrid_policy_overrides_match_legacy_split():
    """n_approx_blocks maps onto per-layer GemmPolicy overrides."""
    img = images.test_image(SIZE, 3)
    ws = bdcn.make_weights([6, 8, 8, 8], 1)
    for n_approx in (1, 3):
        want = _reference_bdcn_forward(img, ws, 6, n_approx_blocks=n_approx)
        got = bdcn.bdcn_forward(img, ws, 6, n_approx_blocks=n_approx)
        np.testing.assert_array_equal(got, want)
    pol = bdcn.hybrid_policy(6, n_approx_blocks=1, n_blocks=4)
    assert pol.resolve(bdcn.layer_name(0)) == "approx_lut"
    assert pol.resolve(bdcn.layer_name(3)) == "exact"


@pytest.mark.parametrize("k", [2, 6])
def test_run_dicts_identical_across_table_backends(k):
    """End-to-end run() metrics agree bit-for-bit between the gather path and
    the MXU-resident delta path."""
    lut_res = dct.run(size=SIZE, ks=(k,), policy="approx_lut")
    delta_res = dct.run(size=SIZE, ks=(k,), policy="approx_delta")
    assert lut_res == delta_res
    lut_res = edge.run(size=SIZE, ks=(k,), policy="approx_lut")
    delta_res = edge.run(size=SIZE, ks=(k,), policy="approx_delta")
    assert lut_res == delta_res
