"""Application-level reproduction tests (Table VI trends)."""
import numpy as np
import pytest

from repro.apps import bdcn, dct, edge, images


def test_image_blocks_roundtrip():
    img = images.test_image(64)
    blocks = images.to_blocks(img)
    back = images.from_blocks(blocks, 64, 64)
    np.testing.assert_array_equal(img, back)


def test_dct_quality_decreases_with_k():
    res = dct.run(size=64, ks=(0, 2, 6))
    assert res[2]["psnr"] > res[6]["psnr"]
    assert res[2]["psnr"] > 35.0          # paper: 45.97 dB at k=2
    assert res[2]["ssim"] > 0.95


def test_edge_detection_trend():
    res = edge.run(size=64, ks=(2, 6))
    assert res[2]["psnr"] > res[6]["psnr"]
    assert res[2]["ssim"] > 0.8           # paper: 0.910 at k=2


def test_bdcn_beats_kernel_based():
    """The paper's key claim: CNN-based edge detection tolerates approximation
    far better than kernel-based."""
    e = edge.run(size=64, ks=(6,))
    b = bdcn.run(size=48, ks=(6,))
    assert b[6]["psnr"] > e[6]["psnr"] + 10.0
    assert b[6]["ssim"] > e[6]["ssim"]


def test_bdcn_hybrid_high_quality_at_k2():
    res = bdcn.run(size=48, ks=(2,))
    assert res[2]["psnr"] > 40.0          # paper: 75.98 dB
    assert res[2]["ssim"] > 0.99
