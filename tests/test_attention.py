"""Chunked (flash-style) attention vs naive reference: causal, windowed,
softcapped, GQA, cache-valid masking, odd shapes."""
import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.models.layers import chunked_attention


def naive_attention(q, k, v, q_positions, kv_valid_len, *, causal=True,
                    window=0, softcap=0.0):
    b, sq, h, d = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    qf = q.astype(np.float64) * d ** -0.5
    kf = k.astype(np.float64)
    vf = v.astype(np.float64)
    kf = np.repeat(kf, g, axis=2)
    vf = np.repeat(vf, g, axis=2)
    s = np.einsum("bqhd,bkhd->bhqk", qf, kf)
    if softcap > 0:
        s = softcap * np.tanh(s / softcap)
    kpos = np.arange(skv)
    valid = kpos[None, :] < kv_valid_len
    if causal:
        delta = q_positions[:, None] - kpos[None, :]
        w = window if window > 0 else 10 ** 9
        valid = valid & (delta >= 0) & (delta < w)
    else:
        valid = np.broadcast_to(valid, (sq, skv))
    s = np.where(valid[None, None], s, -1e30)
    s = s - s.max(-1, keepdims=True)
    p = np.exp(s)
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, vf)


@pytest.mark.parametrize("sq,skv,window,softcap,causal,chunk,qc", [
    (16, 16, 0, 0.0, True, 8, 8),
    (16, 16, 5, 0.0, True, 4, 4),
    (8, 24, 0, 50.0, True, 8, 4),
    (16, 16, 0, 0.0, False, 8, 16),
    (7, 13, 3, 0.0, True, 5, 3),       # odd sizes exercise padding paths
    (1, 32, 0, 0.0, True, 8, 4),       # decode shape
])
def test_matches_naive(sq, skv, window, softcap, causal, chunk, qc):
    rng = np.random.default_rng(sq * 100 + skv)
    b, h, kh, d = 2, 4, 2, 16
    q = rng.normal(size=(b, sq, h, d)).astype(np.float32)
    k = rng.normal(size=(b, skv, kh, d)).astype(np.float32)
    v = rng.normal(size=(b, skv, kh, d)).astype(np.float32)
    qpos = np.arange(sq) + (skv - sq if causal and skv >= sq else 0)
    valid_len = skv
    got = np.asarray(chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(qpos),
        valid_len, causal=causal, window=window, softcap=softcap,
        chunk=chunk, q_chunk=qc))
    want = naive_attention(q, k, v, qpos, valid_len, causal=causal,
                           window=window, softcap=softcap)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_cache_valid_len_masks_tail():
    rng = np.random.default_rng(7)
    b, h, d, skv = 1, 2, 8, 32
    q = rng.normal(size=(b, 1, h, d)).astype(np.float32)
    k = rng.normal(size=(b, skv, h, d)).astype(np.float32)
    v = rng.normal(size=(b, skv, h, d)).astype(np.float32)
    qpos = np.array([9])
    out_full = np.asarray(chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(qpos), 10,
        causal=True, chunk=8, q_chunk=1))
    k2 = k.copy()
    k2[:, 10:] = 99.0   # garbage in unwritten slots must not matter
    out_masked = np.asarray(chunked_attention(
        jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v), jnp.asarray(qpos), 10,
        causal=True, chunk=8, q_chunk=1))
    np.testing.assert_allclose(out_full, out_masked, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 24), st.integers(1, 24), st.integers(0, 9),
       st.integers(1, 10), st.integers(1, 10))
def test_property_random_shapes(sq, skv_extra, window, chunk, qc):
    skv = sq + skv_extra
    rng = np.random.default_rng(sq * 31 + skv)
    b, h, kh, d = 1, 2, 1, 8
    q = rng.normal(size=(b, sq, h, d)).astype(np.float32)
    k = rng.normal(size=(b, skv, kh, d)).astype(np.float32)
    v = rng.normal(size=(b, skv, kh, d)).astype(np.float32)
    qpos = np.arange(sq) + (skv - sq)
    got = np.asarray(chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(qpos),
        skv, causal=True, window=window, chunk=chunk, q_chunk=qc))
    want = naive_attention(q, k, v, qpos, skv, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)
