"""Unit tests for the benchmarks/compare.py regression gate.

The gate must be robust to baseline drift: older committed baselines miss
keys that newer bench code emits (and vice versa), and a degenerate baseline
row can carry a zero / near-zero relative metric. Each of those must produce
an explicit skip/WARN line and a clean exit — never a crash, and never a
silent pass that hides what was (or wasn't) compared.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.compare import compare  # noqa: E402


def _doc(rows):
    return {"results": rows}


def _row(**kw):
    base = {"cell": "engine_vs_lockstep", "backend": "exact", "bound": False}
    base.update(kw)
    return base


def test_within_tolerance_passes(capsys):
    new = _doc([_row(speedup=1.55)])
    base = _doc([_row(speedup=1.61)])
    assert compare(new, base, 0.2) == 0
    out = capsys.readouterr().out
    assert "ok" in out and "FAIL" not in out


def test_real_drop_fails(capsys):
    new = _doc([_row(speedup=1.0)])
    base = _doc([_row(speedup=1.61)])
    assert compare(new, base, 0.2) == 1
    assert "FAIL" in capsys.readouterr().out


def test_missing_key_in_baseline_is_reported_skip(capsys):
    # older baseline predates the metric: must not gate, must not be silent
    new = _doc([_row(speedup=1.6, speedup_vs_per_batch=1.2)])
    base = _doc([_row(speedup=1.6)])
    assert compare(new, base, 0.2) == 0
    out = capsys.readouterr().out
    assert "skip" in out and "missing from baseline" in out
    assert "speedup_vs_per_batch" in out


def test_metric_vanished_from_new_run_warns(capsys):
    new = _doc([_row(speedup=1.6)])
    base = _doc([_row(speedup=1.6, speedup_vs_per_batch=1.2)])
    assert compare(new, base, 0.2) == 0
    out = capsys.readouterr().out
    assert "WARN" in out and "missing from new run" in out


def test_zero_baseline_skips_not_crashes(capsys):
    new = _doc([_row(speedup=1.6)])
    base = _doc([_row(speedup=0.0)])
    assert compare(new, base, 0.2) == 0          # no ZeroDivisionError
    out = capsys.readouterr().out
    assert "skip" in out and "unusable baseline" in out


def test_near_zero_baseline_skips(capsys):
    # sub-EPS baseline: ratio would be meaningless noise, must skip loudly
    new = _doc([_row(speedup=0.5)])
    base = _doc([_row(speedup=1e-12)])
    assert compare(new, base, 0.2) == 0
    assert "unusable baseline" in capsys.readouterr().out


def test_non_numeric_baseline_value_skips_not_crashes(capsys):
    new = _doc([_row(speedup=1.6)])
    base = _doc([_row(speedup="n/a")])
    assert compare(new, base, 0.2) == 0
    assert "unusable baseline" in capsys.readouterr().out


def test_new_cell_without_baseline_is_nonfatal(capsys):
    new = _doc([_row(cell="paged_kernel", speedup=1.9), _row(speedup=1.6)])
    base = _doc([_row(speedup=1.6)])
    assert compare(new, base, 0.2) == 0
    assert "new cell (no baseline)" in capsys.readouterr().out


def test_zero_info_key_does_not_crash(capsys):
    new = _doc([_row(speedup=1.6, engine_tok_per_s=900.0)])
    base = _doc([_row(speedup=1.6, engine_tok_per_s=0.0)])
    assert compare(new, base, 0.2) == 0


def test_skip_count_in_summary(capsys):
    new = _doc([_row(speedup=1.6, speedup_vs_per_batch=1.2)])
    base = _doc([_row(speedup=1.6)])
    compare(new, base, 0.2)
    assert "1 skipped" in capsys.readouterr().out
