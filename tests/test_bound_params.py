"""Bound parameter pytrees and the unified `dot` API.

Pins the PR-3 redesign invariants:

* `GemmPolicy.resolve` longest-prefix semantics incl. tie/empty-layer edges.
* `bind` idempotence and leaf selection (norms/embeddings/routers stay raw).
* Bit-exact parity of bound vs unbound prefill+decode for every backend on a
  small transformer config (the weight-stationary path may not change a bit).
* The acceptance assertion: a bound decode step performs **zero** per-call
  weight quantization or backend-factor construction — checked by tracing the
  jitted step with spies on `quant.quantize(axis=0)`, `prepare_delta`, and
  `build_onehot_weights`, not by timing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import error_delta, gemm, lut, quant
from repro.kernels import ops
from repro.models import get_model

BACKENDS = ("mxu_int8", "approx_lut", "approx_oracle", "approx_onehot",
            "approx_delta")


# --- GemmPolicy.resolve edge cases ------------------------------------------

def test_resolve_longest_prefix_wins():
    p = gemm.GemmPolicy(backend="exact",
                        overrides={"attn": "approx_lut",
                                   "attn/wq": "mxu_int8"})
    assert p.resolve("attn/wq") == "mxu_int8"
    assert p.resolve("attn/wk") == "approx_lut"
    assert p.resolve("mlp/w1") == "exact"


def test_resolve_empty_layer_and_empty_prefix():
    # the empty prefix matches everything: a default-override
    p = gemm.GemmPolicy(backend="exact", overrides={"": "mxu_int8"})
    assert p.resolve("") == "mxu_int8"
    assert p.resolve("anything") == "mxu_int8"
    # an empty layer name matches only the empty prefix
    p2 = gemm.GemmPolicy(backend="exact", overrides={"attn": "approx_lut"})
    assert p2.resolve("") == "exact"
    # empty prefix loses to any longer matching prefix
    p3 = gemm.GemmPolicy(backend="exact",
                         overrides={"": "mxu_int8", "attn": "approx_lut"})
    assert p3.resolve("attn/wq") == "approx_lut"
    assert p3.resolve("mlp/w1") == "mxu_int8"


def test_resolve_same_length_prefixes_are_disjoint():
    # equal-length prefixes can never both match one layer (dict keys are
    # unique), so "tie" resolution reduces to: the one that matches wins
    p = gemm.GemmPolicy(backend="exact",
                        overrides={"ab": "mxu_int8", "ax": "approx_lut"})
    assert p.resolve("ab/w") == "mxu_int8"
    assert p.resolve("ax/w") == "approx_lut"
    assert p.resolve("a") == "exact"


# --- bind mechanics ----------------------------------------------------------

def _small_dense():
    return reduced(ARCHS["smollm-360m"])


def test_bind_selects_gemm_weights_only():
    cfg = _small_dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend="mxu_int8")
    bound = model.bind_params(params, pol)
    assert isinstance(bound, gemm.BoundParams)
    # embeddings / norms stay raw arrays
    assert isinstance(bound["embed"], jnp.ndarray)
    assert isinstance(bound["final_norm"], jnp.ndarray)
    # attention/MLP weights are prepared, stacked over layers
    for leaf_name in ("wq", "wk", "wv", "wo"):
        prep = bound["layers"]["attn"][leaf_name]
        assert isinstance(prep, ops.PreparedOperand), leaf_name
        assert prep.values.shape[0] == cfg.n_layers
        assert prep.scale is not None          # float-prepared: scale attached
    # tied embeddings: a prepared lm_head entry is added for the hot path
    assert cfg.tie_embeddings and "lm_head" not in params
    assert isinstance(bound["lm_head"], ops.PreparedOperand)


def test_bind_idempotent():
    cfg = _small_dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend="approx_delta", k=4)
    b1 = model.bind_params(params, pol)
    b2 = model.bind_params(b1, pol)
    l1 = jax.tree_util.tree_leaves(b1, is_leaf=lambda x: isinstance(x, ops.PreparedOperand))
    l2 = jax.tree_util.tree_leaves(b2, is_leaf=lambda x: isinstance(x, ops.PreparedOperand))
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        if isinstance(a, ops.PreparedOperand):
            assert a is b                      # untouched, not re-prepared


def test_bind_exact_layers_stay_raw():
    cfg = _small_dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend="exact", overrides={"mlp": "mxu_int8"})
    bound = model.bind_params(params, pol)
    assert isinstance(bound["layers"]["attn"]["wq"], jnp.ndarray)
    assert isinstance(bound["layers"]["mlp"]["w1"], ops.PreparedOperand)
    assert "lm_head" not in bound              # lm_head resolves exact


def test_bound_params_is_pytree_jit_arg():
    cfg = _small_dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend="mxu_int8")
    bound = model.bind_params(params, pol)
    leaves, treedef = jax.tree_util.tree_flatten(bound)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, gemm.BoundParams)
    assert set(rebuilt) == set(bound)


def test_stale_bound_params_rejected():
    cfg = _small_dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    bound = model.bind_params(params, gemm.GemmPolicy(backend="mxu_int8"))
    wrong = gemm.GemmPolicy(backend="approx_lut", k=4)
    batch = {"tokens": jnp.zeros((1, 4), jnp.int32)}
    cache = model.init_cache(1, 8)
    with pytest.raises(ValueError, match="stale"):
        model.prefill(bound, batch, cache, policy=wrong)


# --- bit-exact parity: bound vs unbound, every backend -----------------------

def _parity_case(cfg, backend, k=4):
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend=backend, k=k)
    rng = np.random.default_rng(0)
    b, s = 2, 8
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    bound = model.bind_params(params, pol)
    cu, cb = model.init_cache(b, s + 2), model.init_cache(b, s + 2)
    pre = jax.jit(lambda p, bt, c: model.prefill(p, bt, c, policy=pol))
    dec = jax.jit(lambda p, t, c, pos:
                  model.decode_step(p, t, c, pos, policy=pol))
    lu, cu = pre(params, batch, cu)
    lb, cb = pre(bound, batch, cb)
    np.testing.assert_array_equal(np.asarray(lu), np.asarray(lb),
                                  err_msg=f"{backend}: prefill logits differ")
    tok = jnp.argmax(lu[:, -1:], axis=-1).astype(jnp.int32)
    du, _ = dec(params, tok, cu, jnp.int32(s))
    db, _ = dec(bound, tok, cb, jnp.int32(s))
    np.testing.assert_array_equal(np.asarray(du), np.asarray(db),
                                  err_msg=f"{backend}: decode logits differ")


@pytest.mark.parametrize("backend", BACKENDS)
def test_bound_unbound_bit_exact_dense(backend):
    cfg = _small_dense()
    if backend == "approx_oracle":
        # the bit-level oracle is slow: shrink to 1 layer / tiny vocab
        cfg = dataclasses.replace(cfg, n_layers=1, vocab_size=64)
    _parity_case(cfg, backend)


@pytest.mark.parametrize("backend", ("mxu_int8", "approx_delta"))
def test_bound_unbound_bit_exact_moe(backend):
    _parity_case(reduced(ARCHS["qwen3-moe-30b-a3b"]), backend)


@pytest.mark.parametrize("arch", ("zamba2-1.2b", "xlstm-350m", "gemma3-12b"))
def test_bound_unbound_bit_exact_families(arch):
    _parity_case(reduced(ARCHS[arch]), "approx_delta")


# --- acceptance: zero per-call weight work on the bound decode path ----------

def _trace_decode(model, cfg, params, pol, monkeypatch):
    """Trace one `launch.steps.make_decode_step` step, recording
    weight-quantize / backend-factor-build calls."""
    from repro.launch import steps as launch_steps
    weight_quant_calls = []
    orig_quant = quant.quantize

    def spy_quant(x, *, n_bits=8, axis=None, eps=1e-8):
        # weights quantize per-output-channel (axis=0 in `dot`'s float path);
        # moving activations quantize per-row (axis=-1/-2) and are expected
        # every call even when bound
        if axis == 0:
            weight_quant_calls.append(getattr(x, "shape", None))
        return orig_quant(x, n_bits=n_bits, axis=axis, eps=eps)

    factor_calls = []
    orig_prep_delta = error_delta.prepare_delta
    orig_onehot = lut.build_onehot_weights
    orig_prep_op = ops.prepare_operand
    monkeypatch.setattr(quant, "quantize", spy_quant)
    monkeypatch.setattr(error_delta, "prepare_delta",
                        lambda *a, **kw: (factor_calls.append("delta"),
                                          orig_prep_delta(*a, **kw))[1])
    monkeypatch.setattr(lut, "build_onehot_weights",
                        lambda *a, **kw: (factor_calls.append("onehot"),
                                          orig_onehot(*a, **kw))[1])
    monkeypatch.setattr(ops, "prepare_operand",
                        lambda *a, **kw: (factor_calls.append("prep"),
                                          orig_prep_op(*a, **kw))[1])
    tok = jnp.zeros((1, 1), jnp.int32)
    cache = model.init_cache(1, 4)
    step = launch_steps.make_decode_step(cfg, pol)
    jax.make_jaxpr(lambda p, t, c: step(p, t, c, 1))(params, tok, cache)
    return weight_quant_calls, factor_calls


@pytest.mark.parametrize("backend", ("mxu_int8", "approx_delta",
                                     "approx_onehot"))
def test_bound_decode_zero_weight_work(backend, monkeypatch):
    from repro.launch import steps as launch_steps
    cfg = _small_dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend=backend, k=4)
    bound = launch_steps.bind_serving_params(cfg, params, pol)
    wq_calls, factor_calls = _trace_decode(model, cfg, bound, pol, monkeypatch)
    assert wq_calls == [], f"bound decode quantized weights: {wq_calls}"
    assert factor_calls == [], \
        f"bound decode rebuilt backend factors: {factor_calls}"


def test_unbound_decode_does_weight_work(monkeypatch):
    # sanity check that the spies actually see the per-call weight work
    cfg = _small_dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend="mxu_int8")
    wq_calls, _ = _trace_decode(model, cfg, params, pol, monkeypatch)
    assert wq_calls, "unbound decode should quantize weights per call"


# --- eval-path integration ---------------------------------------------------

def test_evaluate_binds_and_matches_unbound():
    from repro.train import loop as train_loop
    cfg = _small_dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)),
                                      jnp.int32)} for _ in range(2)]
    pol = gemm.GemmPolicy(backend="mxu_int8")

    def loss_fn(p, b, policy):
        return model.lm_loss(p, b, policy=policy, remat=False)

    ev_bound = train_loop.evaluate(loss_fn, params, batches, policy=pol)
    ev_raw = train_loop.evaluate(loss_fn, params, batches, policy=pol,
                                 bind_weights=False)
    assert ev_bound["eval_loss"] == ev_raw["eval_loss"]
