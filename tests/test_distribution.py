"""Distribution-layer tests on small CPU meshes: sharding specs, roofline
parsing, analytic model invariants, end-to-end jit'd train step on a debug mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.launch import analytic, roofline
from repro.launch.analytic import PerfKnobs
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import TrainHParams, assemble_train
from repro.sharding import specs as sh


def test_param_specs_divisibility_rules():
    mesh = make_debug_mesh(1, 1)
    # use a fake 16x16 mesh object for spec logic (shape only)
    from repro.configs import ARCHS
    cfg = ARCHS["qwen2.5-14b"]

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    fm = FakeMesh()
    # embed (V, d): vocab 152064 % 16 == 0 -> model; d 5120 % 16 == 0 -> data
    assert sh.param_spec("embed", (152064, 5120), fm) == P("model", "data")
    # hubert vocab 504 not divisible -> replicated on that dim
    assert sh.param_spec("embed", (504, 1280), fm) == P(None, "data")
    # stacked attention weight (L, d, H*hd)
    assert sh.param_spec("layers/attn/wq", (48, 5120, 5120), fm) == \
        P(None, "data", "model")
    # MoE expert tensor (L, E, d, ff) -> EP on expert dim
    assert sh.param_spec("layers/moe/w1", (48, 64, 2048, 1408), fm) == \
        P(None, "model", "data", None)
    # norms replicated
    assert sh.param_spec("layers/ln1", (48, 5120), fm) == P(None, None)


def test_collective_parser_on_synthetic_hlo():
    txt = """
  %ar = f32[1024,256]{1,0} all-reduce(%x), channel_id=1
  %ag-start = bf16[512]{0} all-gather-start(%y)
  %ag-done = bf16[512]{0} all-gather-done(%ag-start)
  %a2a = (s32[16,4]{1,0}, s32[16,4]{1,0}) all-to-all(%p, %q)
  %cp = bf16[64,64]{1,0} collective-permute(%z)
"""
    out = roofline.collective_bytes(txt)
    assert out["all-reduce"] == 1024 * 256 * 4
    assert out["all-gather"] == 512 * 2
    assert out["all-to-all"] == 16 * 4 * 4 * 2
    assert out["collective-permute"] == 64 * 64 * 2
    assert out["count"] == 4


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "qwen3-moe-30b-a3b",
                                  "zamba2-1.2b", "xlstm-350m", "gemma3-12b"])
def test_analytic_terms_positive_and_consistent(arch):
    cfg = ARCHS[arch]
    for shape in cfg.shapes:
        if shape.skip:
            continue
        t = analytic.analytic_terms(cfg, shape, 256, PerfKnobs(n_micro=4))
        assert t["t_compute_s"] > 0
        assert t["t_memory_s"] > 0
        assert 0 < t["useful_flops_frac"] <= 1.0, (arch, shape.name, t)
        assert 0 < t["roofline_frac"] <= 1.0


def test_analytic_knob_directions():
    """Napkin-math sanity: more microbatches -> more FSDP traffic; grad
    compression shrinks the pod hop; less TP -> fewer activation reduces."""
    cfg = ARCHS["qwen2.5-14b"]
    shape = cfg.shape("train_4k")
    base = analytic.collective_bytes_per_device(cfg, shape, 256,
                                                PerfKnobs(n_micro=4))
    more_micro = analytic.collective_bytes_per_device(cfg, shape, 256,
                                                      PerfKnobs(n_micro=16))
    assert more_micro > base
    tp1 = analytic.collective_bytes_per_device(cfg, shape, 256,
                                               PerfKnobs(tp=1, n_micro=4))
    assert tp1 < base
    comp = analytic.collective_bytes_per_device(
        cfg, shape, 512, PerfKnobs(n_micro=4, compress_grads=True), pods=2)
    nocomp = analytic.collective_bytes_per_device(
        cfg, shape, 512, PerfKnobs(n_micro=4), pods=2)
    assert comp < nocomp


def test_gemma3_window_cuts_attention_span():
    g3 = ARCHS["gemma3-12b"]
    full = dataclasses.replace(g3, window_size=0, global_every=0)
    s = 32768
    span_win = analytic._mean_attn_span(g3, s)
    span_full = analytic._mean_attn_span(full, s)
    # 5/6 of layers see a 1024 window instead of s/2
    assert span_win < span_full * 0.25
    f_win = analytic.flops_per_device(g3, g3.shape("prefill_32k"), 256,
                                      PerfKnobs())
    f_full = analytic.flops_per_device(full, full.shape("prefill_32k"), 256,
                                       PerfKnobs())
    assert f_win < f_full  # attention is a minor FLOP share at 12B params


def test_jitted_train_step_on_debug_mesh():
    """End-to-end: assemble + jit + run one real step on a 1x1 mesh."""
    from repro.configs import reduced
    from repro.models import get_model
    from repro.optim import adamw
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.configs.base import ShapeSpec
    cfg = reduced(ARCHS["smollm-360m"])
    shape = ShapeSpec("t", "train", 32, 4)
    mesh = make_debug_mesh(1, 1)
    hp = TrainHParams(n_micro=2, total_steps=10)
    step, arg_specs, in_sh, out_sh, hp = assemble_train(cfg, shape, mesh, hp)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    data = SyntheticLM(cfg, shape, DataConfig(n_micro=2))
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        p2, o2, metrics = jitted(params, opt, data.batch(0))
    assert np.isfinite(float(metrics["loss"]))
    assert int(o2.step) == 1
