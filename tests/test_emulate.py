"""Bit-level PE emulation: exactness at k=0, approximation behaviour, oracle GEMM."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import emulate
from repro.core.emulate import matmul_oracle, nppc_count, pe_mac, ppc_count, product_table


def _all_pairs():
    a = np.repeat(np.arange(-128, 128, dtype=np.int32), 256)
    b = np.tile(np.arange(-128, 128, dtype=np.int32), 256)
    return a, b


def test_cell_counts_match_paper_quote():
    # paper quotes 50 PPC + 14 NPPC for the 8-bit signed PE
    assert ppc_count(8) == 50
    assert nppc_count(8) == 14


def test_exact_signed_all_pairs():
    a, b = _all_pairs()
    got = np.asarray(pe_mac(a, b, 0, k=0, signed=True))
    assert np.array_equal(got, a * b)


def test_exact_unsigned_all_pairs():
    a = np.repeat(np.arange(256, dtype=np.int32), 256)
    b = np.tile(np.arange(256, dtype=np.int32), 256)
    got = np.asarray(pe_mac(a, b, 0, k=0, signed=False))
    assert np.array_equal(got, a * b)


def test_exact_fused_accumulate():
    rng = np.random.default_rng(0)
    a, b = _all_pairs()
    c = rng.integers(-(2 ** 20), 2 ** 20, size=a.shape).astype(np.int32)
    got = np.asarray(pe_mac(a, b, c, k=0, signed=True))
    assert np.array_equal(got, a * b + c)


@pytest.mark.parametrize("n_bits", [4, 8])
def test_exact_other_widths(n_bits):
    span = 1 << n_bits
    half = span >> 1
    vals = np.arange(span, dtype=np.int32) - half
    a = np.repeat(vals, span)
    b = np.tile(vals, span)
    got = np.asarray(pe_mac(a, b, 0, n_bits=n_bits, k=0, signed=True))
    assert np.array_equal(got, a * b)


def test_approx_error_monotone_in_k():
    a, b = _all_pairs()
    exact = a.astype(np.int64) * b
    meds = []
    for k in (0, 2, 4, 6, 8):
        got = np.asarray(pe_mac(a, b, 0, k=k, signed=True), np.int64)
        meds.append(np.abs(got - exact).mean())
    assert meds[0] == 0
    assert all(meds[i] <= meds[i + 1] for i in range(len(meds) - 1)), meds


def test_approx_only_touches_low_columns():
    """For factor k, the deviation must stem from columns < k; carries can ripple up
    but the per-MAC error is bounded well below 2^{k+ceil(log2 rows)}."""
    a, b = _all_pairs()
    exact = a.astype(np.int64) * b
    for k in (2, 4, 6):
        got = np.asarray(pe_mac(a, b, 0, k=k, signed=True), np.int64)
        bound = (1 << k) * 16  # generous carry-ripple envelope
        assert np.abs(got - exact).max() < bound


def test_gemm_oracle_exact():
    rng = np.random.default_rng(2)
    a = rng.integers(-128, 128, (24, 40)).astype(np.int32)
    b = rng.integers(-128, 128, (40, 12)).astype(np.int32)
    got = np.asarray(matmul_oracle(a, b, k=0))
    assert np.array_equal(got, a @ b)


def test_product_table_matches_pe_mac():
    t = product_table(8, 5, True, 24)
    rng = np.random.default_rng(3)
    a = rng.integers(-128, 128, 500).astype(np.int32)
    b = rng.integers(-128, 128, 500).astype(np.int32)
    got = t[a & 255, b & 255]
    want = np.asarray(pe_mac(a, b, 0, k=5, signed=True))
    assert np.array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(st.integers(-128, 127), st.integers(-128, 127),
       st.integers(-(2 ** 22), 2 ** 22), st.integers(0, 8))
def test_property_exact_dominates_approx_scale(a, b, c, k):
    """Approx output always within the carry-ripple envelope of exact, any inputs."""
    got = int(pe_mac(np.int32(a), np.int32(b), np.int32(c), k=k, signed=True))
    want = a * b + c
    if k == 0:
        assert got == want
    else:
        assert abs(got - want) < (1 << k) * 16


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 12), st.integers(1, 12), st.integers(1, 12))
def test_property_oracle_matches_numpy_exact(m, k_dim, n):
    rng = np.random.default_rng(m * 100 + k_dim * 10 + n)
    a = rng.integers(-128, 128, (m, k_dim)).astype(np.int32)
    b = rng.integers(-128, 128, (k_dim, n)).astype(np.int32)
    got = np.asarray(matmul_oracle(a, b, k=0))
    assert np.array_equal(got, a @ b)
