"""Continuous-batching serve engine: scheduler semantics + bit-parity.

Pins the PR-4 invariants (now on the paged engine — the default — with the
PR-5 paged/chunked-prefill additions):

* **Scheduler**: FIFO admission order, arrival-step gating (trace replay),
  EOS / max-token retirement, slot reuse, full-queue backpressure.
* **Ragged decode is the real path, lockstep the degenerate case**: a batched
  decode step driven with a per-slot `positions` vector is bit-identical to
  the scalar-position step when all slots agree, and per-slot logits equal
  the same request decoded alone.
* **Per-request bit-parity**: engine greedy token streams under ragged
  multi-request batching equal the lockstep reference run per request
  (batch 1) — for every GEMM backend on the dense family, for
  MoE/VLM/hybrid/xLSTM/windowed-dense under exact and weight-stationary
  (`gemm.bind`-bound) approximate policies.
* **Paged == contiguous**: the paged engine (block-table caches + chunked
  prefill) and the PR-4 contiguous engine (per-slot regions + fused
  whole-prompt admit) produce identical streams for all six backends,
  bound and unbound, across every family (`tests/test_paged.py` pins the
  allocator itself and chunk-size invariance).
* **Deterministic per-slot sampling**: a sampled request's tokens depend on
  (seed, rid, token index) only, not on batch composition.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import gemm
from repro.launch import engine as E
from repro.launch import sampling
from repro.launch.serve import lockstep_generate
from repro.models import get_model


def _dense():
    return reduced(ARCHS["smollm-360m"])


def _requests(cfg, lens, *, arrivals=None, seed=0, params=sampling.GREEDY,
              vlm_embed_dim=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for rid, (pl, gl) in enumerate(lens):
        embeds = None
        if vlm_embed_dim:
            embeds = rng.normal(size=(2, vlm_embed_dim)).astype(np.float32)
        reqs.append(E.Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab_size, pl).astype(np.int32),
            max_new_tokens=gl, params=params,
            arrival=0 if arrivals is None else arrivals[rid],
            input_embeds=embeds))
    return reqs


def _check_parity(cfg, params, policy, *, slots=2, max_len=16,
                  lens=((5, 4), (8, 6), (3, 5), (6, 3)), vlm_embed_dim=0,
                  compare_contiguous=False, **engine_kw):
    """Engine ragged greedy streams == per-request lockstep reference.

    The engine under test is the paged one (chunked prefill over block-table
    caches); `compare_contiguous` additionally runs the PR-4 contiguous
    engine on the same trace and requires identical streams."""
    model = get_model(cfg)

    def mkreqs():
        return _requests(cfg, lens, arrivals=[i // 2 for i in range(len(lens))],
                         vlm_embed_dim=vlm_embed_dim)

    reqs = mkreqs()
    eng = E.ServeEngine(cfg, params, policy=policy, max_slots=slots,
                        max_len=max_len, **engine_kw)
    finished = eng.run(reqs)
    assert len(finished) == len(reqs)
    if getattr(eng, "pool", None) is not None:
        eng.pool.check()
    for r in reqs:
        embeds = (jnp.asarray(r.input_embeds[None])
                  if r.input_embeds is not None else None)
        ref = lockstep_generate(cfg, model, params,
                                jnp.asarray(r.prompt[None]), r.max_new_tokens,
                                policy=policy, input_embeds=embeds)
        np.testing.assert_array_equal(
            finished[r.rid].tokens, ref[0],
            err_msg=f"rid={r.rid} diverged from lockstep reference")
    if compare_contiguous:
        cont = E.ServeEngine(cfg, params, policy=policy, max_slots=slots,
                             max_len=max_len, paged=False)
        fin_c = cont.run(mkreqs())
        for rid in finished:
            np.testing.assert_array_equal(
                finished[rid].tokens, fin_c[rid].tokens,
                err_msg=f"rid={rid}: paged engine diverged from contiguous")


# --- ragged == lockstep at the decode-step level -----------------------------

def test_vector_positions_degenerate_equals_scalar():
    """All-equal positions vector must be bit-identical to the scalar path."""
    cfg = _dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 3, 6
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    cache = model.init_cache(b, s + 2)
    logits, cache = model.prefill(params, {"tokens": prompts}, cache)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    l_scalar, _ = model.decode_step(params, tok, cache, jnp.int32(s))
    l_vector, _ = model.decode_step(params, tok, cache,
                                    jnp.full((b,), s, jnp.int32))
    np.testing.assert_array_equal(np.asarray(l_scalar), np.asarray(l_vector))


@pytest.mark.parametrize("arch", ("smollm-360m", "gemma3-12b"))
def test_ragged_slot_logits_equal_solo_decode(arch):
    """Per-slot logits in a ragged batch == the same request decoded alone
    (full-logits check — much stronger than token argmax parity)."""
    cfg = reduced(ARCHS[arch])
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    max_len = 16
    plens = (4, 7, 5)
    b = len(plens)
    cache = model.init_cache(b, max_len)
    solo_logits = []
    # build the ragged batched cache by prefilling each request alone and
    # scattering it into its slot — exactly what the engine's admit does
    from repro.models import api as model_api
    axes = model_api.cache_batch_axes(cache)
    toks = []
    for i, pl in enumerate(plens):
        prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, pl)),
                             jnp.int32)
        c1 = model.init_cache(1, max_len)
        logits, c1 = model.prefill(params, {"tokens": prompt}, c1)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        l_solo, _ = model.decode_step(params, tok, c1,
                                      jnp.full((1,), pl, jnp.int32))
        solo_logits.append(np.asarray(l_solo))
        toks.append(tok)
        cache = {key: jax.lax.dynamic_update_slice_in_dim(
            cache[key], c1[key], i, axis=axes[key]) for key in cache}
    positions = jnp.asarray(plens, jnp.int32)
    l_batch, _ = model.decode_step(params, jnp.concatenate(toks), cache,
                                   positions)
    for i in range(b):
        np.testing.assert_array_equal(np.asarray(l_batch)[i:i + 1],
                                      solo_logits[i],
                                      err_msg=f"slot {i} (pos {plens[i]})")


# --- per-request engine-vs-lockstep parity -----------------------------------

BACKENDS = ("exact", "mxu_int8", "approx_lut", "approx_onehot", "approx_delta")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bound", (False, True))
def test_engine_parity_dense_all_backends(backend, bound):
    """Acceptance grid: paged streams == solo lockstep for every backend,
    bound and unbound, on the dense family — plus paged == the PR-4
    contiguous engine on the MXU-resident backends (the gather backends are
    interpret-mode slow; their contiguous equality follows transitively
    through the lockstep reference both engines are pinned to)."""
    if bound and backend == "exact":
        pytest.skip("binding is a no-op for exact — identical to unbound")
    cfg = _dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend=backend, k=4)
    p = model.bind_params(params, pol) if bound else params
    slow = backend in ("approx_lut", "approx_onehot")
    kw = {"lens": ((4, 3), (6, 4), (3, 3))} if slow else {}
    _check_parity(cfg, p, pol, compare_contiguous=not slow, block_size=4,
                  prefill_chunk=3, **kw)


@pytest.mark.parametrize("bound", (False, True))
def test_engine_parity_dense_oracle(bound):
    # the bit-level oracle is slow: 1 layer, tiny vocab, short streams
    cfg = dataclasses.replace(_dense(), n_layers=1, vocab_size=64)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend="approx_oracle", k=4)
    p = model.bind_params(params, pol) if bound else params
    _check_parity(cfg, p, pol, lens=((3, 2), (4, 3), (2, 2)),
                  max_len=8, compare_contiguous=True, block_size=2,
                  prefill_chunk=2)


@pytest.mark.parametrize("arch", ("qwen3-moe-30b-a3b", "zamba2-1.2b",
                                  "xlstm-350m", "gemma3-12b", "pixtral-12b"))
@pytest.mark.parametrize("mode", ("exact", "delta_bound"))
def test_engine_parity_families(arch, mode):
    """All families through the paged engine (mixed-chunk prefill straddling
    ring windows, SSM states, xLSTM carries, VLM patch boundaries), pinned
    against both the contiguous engine and the lockstep reference."""
    cfg = reduced(ARCHS[arch])
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    if mode == "exact":
        pol, p = gemm.EXACT, params
    else:
        pol = gemm.GemmPolicy(backend="approx_delta", k=4)
        p = model.bind_params(params, pol)
    # gemma3 reduced: window 8; max_len 24 > window exercises the two-tier
    # windowed cache (paged global layers + per-slot rings) in the engine
    kw = {"max_len": 24} if arch == "gemma3-12b" else {}
    if arch == "pixtral-12b":
        kw["vlm_embed_dim"] = cfg.d_model
    _check_parity(cfg, p, pol, compare_contiguous=(mode == "exact"),
                  block_size=4, prefill_chunk=3, **kw)


# --- scheduler semantics -----------------------------------------------------

def _greedy_engine(cfg, params, **kw):
    return E.ServeEngine(cfg, params, policy=gemm.EXACT, **kw)


def test_admission_fifo_and_slot_reuse():
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    eng = _greedy_engine(cfg, params, max_slots=2, max_len=12)
    reqs = _requests(cfg, [(4, 3)] * 5)
    finished = eng.run(reqs)
    assert sorted(finished) == [0, 1, 2, 3, 4]
    # FIFO: a request never finishes before one submitted two slots earlier
    # was admitted; with 2 slots and equal lengths, admission order is rid
    order = sorted(finished.values(), key=lambda f: (f.admitted_step, f.rid))
    assert [f.rid for f in order] == [0, 1, 2, 3, 4]
    # slot reuse: 5 requests through 2 slots — later admits start after
    # earlier retirements, not all at step 0
    assert order[-1].admitted_step > order[0].admitted_step


def test_full_queue_backpressure():
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    eng = _greedy_engine(cfg, params, max_slots=2, max_len=12)
    for r in _requests(cfg, [(4, 4)] * 6):
        eng.submit(r)
    eng._admit_ready()
    assert int(eng.active.sum()) == 2 and len(eng.queue) == 4
    eng.step()                    # nothing retires yet -> queue stays put
    assert len(eng.queue) == 4
    while eng.queue or eng.active.any():
        eng.step()
    assert len(eng.finished) == 6 and not eng.queue


def test_arrival_gating():
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    eng = _greedy_engine(cfg, params, max_slots=2, max_len=12)
    reqs = _requests(cfg, [(4, 2), (4, 2)], arrivals=[0, 9])
    finished = eng.run(reqs)
    assert finished[1].admitted_step >= 9
    assert finished[0].finished_step < finished[1].admitted_step


def test_eos_retirement():
    cfg = _dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    probe = _greedy_engine(cfg, params, max_slots=1, max_len=16)
    [req] = _requests(cfg, [(5, 6)])
    tokens = probe.run([req])[0].tokens
    assert len(tokens) == 6
    # re-run with eos set to a token of the stream: must retire at its
    # *first* occurrence (greedy streams of a random-init model repeat)
    eos = int(tokens[2])
    cut = int(np.argmax(tokens == eos)) + 1
    eng = _greedy_engine(cfg, params, max_slots=1, max_len=16, eos_id=eos)
    [req2] = _requests(cfg, [(5, 6)])
    fin = eng.run([req2])[0]
    assert fin.finish_reason == "eos"
    np.testing.assert_array_equal(fin.tokens, tokens[:cut])


def test_sampling_deterministic_per_slot():
    """A sampled request's stream is a function of (seed, rid, step) only —
    identical whatever other requests share the batch."""
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    sp = sampling.SamplingParams(temperature=0.9, top_k=40, top_p=0.95,
                                 seed=7)
    probe = _requests(cfg, [(5, 6)], params=sp)
    alone = _greedy_engine(cfg, params, max_slots=1, max_len=16)
    t_alone = alone.run(probe)[0].tokens
    # same request (rid 0) inside a busy ragged batch
    crowd = _requests(cfg, [(5, 6), (7, 4), (3, 6), (6, 5)], params=sp)
    busy = _greedy_engine(cfg, params, max_slots=3, max_len=16)
    t_busy = busy.run(crowd)[0].tokens
    np.testing.assert_array_equal(t_alone, t_busy)
    # and a different seed moves the stream (the sampler is actually live)
    sp2 = dataclasses.replace(sp, seed=8)
    other = _greedy_engine(cfg, params, max_slots=1, max_len=16)
    t_other = other.run(_requests(cfg, [(5, 6)], params=sp2))[0].tokens
    assert not np.array_equal(t_alone, t_other)


def test_sampler_greedy_topk1_temperature_agree():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                         jnp.float32)
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(4)])
    greedy = sampling.sample_tokens(logits, jnp.zeros(4), jnp.zeros(4, jnp.int32),
                                    jnp.ones(4), keys)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))
    # top_k=1 forces the argmax whatever the temperature
    topk1 = sampling.sample_tokens(logits, jnp.full(4, 2.0),
                                   jnp.ones(4, jnp.int32), jnp.ones(4), keys)
    np.testing.assert_array_equal(np.asarray(topk1), np.asarray(greedy))


def test_sampler_top_p_masks_tail():
    # one dominant token with p=0.5 mass; top_p=0.4 must always pick it
    logits = jnp.log(jnp.asarray([[0.5, 0.2, 0.2, 0.1]]))
    keys = jnp.stack([jax.random.PRNGKey(3)])
    for i in range(5):
        k = jnp.stack([jax.random.fold_in(keys[0], i)])
        tok = sampling.sample_tokens(logits, jnp.ones(1), jnp.zeros(1, jnp.int32),
                                     jnp.asarray([0.4]), k)
        assert int(tok[0]) == 0


def test_prompt_longer_than_max_len_rejected():
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    eng = _greedy_engine(cfg, params, max_slots=1, max_len=6)
    with pytest.raises(ValueError, match="max_len"):
        eng.run(_requests(cfg, [(8, 2)]))


def test_budget_uses_full_cache_capacity():
    """A slot holds max_len - P + 1 tokens (the final token's KV is never
    written), and the tight-fit stream matches the roomy-cache one; a slot
    parked past its full cache must not corrupt later occupants."""
    cfg = _dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    p, want = 5, 8
    # rid 0 fills its slot exactly; rids 1-2 keep decoding (and rid 2 reuses
    # a slot) while rid 0's retired row sits parked at position == max_len
    tight = _greedy_engine(cfg, params, max_slots=2, max_len=p + want - 1)
    fin = tight.run(_requests(cfg, [(p, want), (p, 6), (p, 6)]))
    assert [len(fin[r].tokens) for r in range(3)] == [want, 6, 6]
    roomy = _greedy_engine(cfg, params, max_slots=3, max_len=32)
    ref = roomy.run(_requests(cfg, [(p, want), (p, 6), (p, 6)]))
    for r in range(3):
        np.testing.assert_array_equal(fin[r].tokens, ref[r].tokens)
