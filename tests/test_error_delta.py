"""error_delta decomposition + approx_delta backend: bit-equality with the
gather path (lut.lut_matmul) across shapes/ranks, rank selection, padding."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import error_delta, gemm, lut
from repro.core.emulate import product_table
from repro.kernels import ops


def _rand(shape, rng, lo=-128, hi=128):
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int32)


# --- decomposition ----------------------------------------------------------

@pytest.mark.parametrize("k", [0, 2, 4, 6])
def test_rank_for_exact_reproduces_table(k):
    fac = error_delta.delta_factors(8, k, True, 24)
    assert fac.rank == error_delta.rank_for_exact(8, k, True, 24)
    assert fac.exact, "residual must vanish at the exact rank"
    recon = np.round(fac.f.astype(np.float64) @ fac.g.astype(np.float64))
    t0 = product_table(8, 0, True, 24).astype(np.int64)
    tk = product_table(8, k, True, 24).astype(np.int64)
    np.testing.assert_array_equal(t0 + recon.astype(np.int64), tk)


def test_error_table_is_low_bit_periodic():
    # E depends only on the low k bits of each operand (the approximate cells
    # sit in columns < k) — the property that makes the rank small
    for k in (2, 4, 6):
        e = error_delta.error_table(8, k, True, 24)
        low = 1 << k
        np.testing.assert_array_equal(
            e, np.tile(e[:low, :low], (256 // low, 256 // low)))


def test_rank_selection():
    r_exact = error_delta.rank_for_exact(8, 6, True, 24)
    assert error_delta.rank_for_exact(8, 0, True, 24) == 0
    assert error_delta.rank_for_tol(0.0, 8, 6, True, 24) == r_exact
    e = error_delta.error_table(8, 6, True, 24)
    assert error_delta.rank_for_tol(float(np.abs(e).max()), 8, 6, True, 24) == 0
    # tolerance between the extremes buys a strictly smaller rank
    r_mid = error_delta.rank_for_tol(5.0, 8, 6, True, 24)
    assert 0 < r_mid < r_exact
    fac = error_delta.delta_factors(8, 6, True, 24, tol=5.0)
    assert fac.rank == r_mid and fac.max_err <= 5.0


def test_truncated_rank_residual_tracks_defect():
    fac = error_delta.delta_factors(8, 6, True, 24, rank=8)
    assert not fac.exact
    e = error_delta.error_table(8, 6, True, 24)
    recon = fac.f.astype(np.float64) @ fac.g.astype(np.float64)
    np.testing.assert_array_equal(fac.residual,
                                  e - np.round(recon).astype(np.int32))
    np.testing.assert_allclose(fac.defect, e - recon, atol=1e-3)


# --- reference + kernel bit-equality ---------------------------------------

SHAPES = [(8, 8, 8), (16, 24, 8), (33, 1, 5), (100, 70, 36), (1, 128, 1),
          (65, 129, 3)]


@pytest.mark.parametrize("m,kd,n", SHAPES)
@pytest.mark.parametrize("kf", [0, 3, 6])
def test_delta_ref_matches_lut(m, kd, n, kf):
    rng = np.random.default_rng(m * 5 + kd + n + kf)
    a, b = _rand((m, kd), rng), _rand((kd, n), rng)
    want = np.asarray(lut.lut_matmul(a, b, k=kf))
    out = np.asarray(error_delta.delta_matmul_ref(a, b, k=kf))
    np.testing.assert_array_equal(out, want)


@pytest.mark.parametrize("m,kd,n", SHAPES)
@pytest.mark.parametrize("kf", [0, 3, 6])
def test_delta_kernel_matches_lut(m, kd, n, kf):
    """Non-block-multiple shapes: padding + K-pad correction must stay exact."""
    rng = np.random.default_rng(m + kd * 7 + n + kf)
    a, b = _rand((m, kd), rng), _rand((kd, n), rng)
    want = np.asarray(lut.lut_matmul(a, b, k=kf))
    out = np.asarray(ops.approx_delta_matmul(a, b, k=kf))
    np.testing.assert_array_equal(out, want)
    if kf == 0:
        np.testing.assert_array_equal(out, np.asarray(a) @ np.asarray(b))


@pytest.mark.parametrize("kf", [4, 6])
def test_truncated_rank_with_residual_is_exact(kf):
    rng = np.random.default_rng(kf)
    a, b = _rand((40, 30), rng), _rand((30, 20), rng)
    want = np.asarray(lut.lut_matmul(a, b, k=kf))
    r = max(1, error_delta.rank_for_exact(8, kf, True, 24) // 2)
    out = np.asarray(ops.approx_delta_matmul(a, b, k=kf, rank=r,
                                             apply_residual=True))
    np.testing.assert_array_equal(out, want)
    ref = np.asarray(error_delta.delta_matmul_ref(a, b, k=kf, rank=r,
                                                  apply_residual=True))
    np.testing.assert_array_equal(ref, want)


def test_truncated_rank_error_bounded_by_tol():
    rng = np.random.default_rng(9)
    a, b = _rand((24, 16), rng), _rand((16, 24), rng)
    tol = 4.0
    want = np.asarray(lut.lut_matmul(a, b, k=6))
    out = np.asarray(ops.approx_delta_matmul(a, b, k=6, tol=tol,
                                             apply_residual=False))
    # per-product error <= tol, K products per output, plus <=0.5/block rounding
    assert np.abs(out - want).max() <= tol * 16 + 1


def test_unsigned_falls_back_to_reference():
    rng = np.random.default_rng(3)
    a = _rand((20, 12), rng, 0, 256)
    b = _rand((12, 10), rng, 0, 256)
    want = np.asarray(lut.lut_matmul(a, b, k=4, signed=False))
    out = np.asarray(ops.approx_delta_matmul(a, b, k=4, signed=False))
    np.testing.assert_array_equal(out, want)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 48), st.integers(1, 48), st.integers(1, 48),
       st.integers(0, 7))
def test_property_delta_matches_lut_any_shape(m, kd, n, kf):
    rng = np.random.default_rng(m * 311 + kd * 17 + n * 3 + kf)
    a, b = _rand((m, kd), rng), _rand((kd, n), rng)
    want = np.asarray(lut.lut_matmul(a, b, k=kf))
    np.testing.assert_array_equal(
        np.asarray(ops.approx_delta_matmul(a, b, k=kf)), want)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40),
       st.integers(0, 7))
def test_property_approx_matmul_matches_lut_any_shape(m, kd, n, kf):
    """ops.approx_matmul (gather kernel) on non-block-multiple shapes, incl.
    the padded-K t00 correction, is bit-equal to the jnp gather path."""
    rng = np.random.default_rng(m * 131 + kd * 19 + n * 5 + kf)
    a, b = _rand((m, kd), rng), _rand((kd, n), rng)
    want = np.asarray(lut.lut_matmul(a, b, k=kf))
    np.testing.assert_array_equal(
        np.asarray(ops.approx_matmul(a, b, k=kf)), want)


# --- registry ---------------------------------------------------------------

def test_policy_delta_backend_bit_equals_lut_backend():
    rng = np.random.default_rng(11)
    xq = _rand((9, 33), rng)
    wq = _rand((33, 5), rng)
    pol_d = gemm.GemmPolicy(backend="approx_delta", k=4)
    pol_l = gemm.GemmPolicy(backend="approx_lut", k=4)
    np.testing.assert_array_equal(np.asarray(gemm.dot(xq, wq, pol_d)),
                                  np.asarray(gemm.dot(xq, wq, pol_l)))


def test_dot_delta_close_to_float():
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.normal(size=(4, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)
    out = gemm.dot(x, w, gemm.GemmPolicy(backend="approx_delta", k=2))
    ref = x @ w
    rel = float(jnp.abs(out - ref).mean() / jnp.abs(ref).mean())
    assert rel < 0.08, rel
