"""Error-metric (Table V) and energy-model (Tables II-IV) reproduction tests."""
import numpy as np
import pytest

from repro.core import energy, errors, systolic


# --- Table V: NMED/MRED trend (paper's exact per-k values depend on unpublished
# netlist details; we assert order-of-magnitude agreement and monotonicity) ----

PAPER_SIGNED_NMED = {2: 0.0001, 4: 0.0004, 5: 0.0006, 6: 0.0022, 8: 0.0081}


def test_table5_signed_nmed_order_and_trend():
    ours = {k: errors.pe_error_metrics(8, k, signed=True)["NMED"]
            for k in PAPER_SIGNED_NMED}
    vals = [ours[k] for k in sorted(ours)]
    assert all(a <= b for a, b in zip(vals, vals[1:])), ours
    for k, paper in PAPER_SIGNED_NMED.items():
        assert ours[k] < 20 * paper + 1e-9, (k, ours[k], paper)
        # non-trivial error present for k >= 4
        if k >= 4:
            assert ours[k] > 0


def test_unsigned_metrics_finite_and_small():
    m = errors.pe_error_metrics(8, 6, signed=False)
    assert 0 < m["NMED"] < 0.05
    assert 0 < m["MRED"] < 0.2


def test_psnr_ssim_identity():
    img = np.random.default_rng(0).integers(0, 256, (64, 64)).astype(np.float64)
    assert errors.psnr(img, img) == float("inf")
    assert errors.ssim(img, img) == pytest.approx(1.0, abs=1e-9)


def test_psnr_known_value():
    ref = np.zeros((16, 16))
    test = ref + 1.0
    assert errors.psnr(ref, test) == pytest.approx(10 * np.log10(255 ** 2), rel=1e-6)


# --- Energy model: recompute the paper's headline claims --------------------

def test_cell_savings_claims():
    c = energy.cell_energy_claims()
    assert c["exact_ppc_vs_ref6"] == pytest.approx(0.064, abs=0.01)
    assert c["approx_ppc_vs_ref5"] == pytest.approx(0.468, abs=0.01)
    assert c["approx_nppc_vs_ref5"] == pytest.approx(0.388, abs=0.06)  # abstract: 34.4%


def test_pe_savings_claims():
    p = energy.pe_energy_claims()
    assert p["exact_pe_vs_ref6"] == pytest.approx(0.2026, abs=0.01)
    assert p["approx_pe_vs_ref5"] == pytest.approx(0.131, abs=0.02)
    # abstract's 24.37%/22.51% refer to slightly different baselines; PADP claim:
    assert p["approx_pe_padp_vs_ref5"] == pytest.approx(0.2253, abs=0.01)  # ~23%


def test_sa_savings_claims():
    s = energy.sa_energy_claims()
    # abstract: 16% exact / 68% approx savings at the 8x8 SA level
    assert s["sa8_exact_vs_ref6"] == pytest.approx(0.16, abs=0.02)
    assert s["sa8_approx_vs_exact_ref6"] == pytest.approx(0.68, abs=0.02)
    # fig 8(b): 62.7% and 24.2% at 16x16
    assert s["sa16_approx_vs_exact_ref6"] == pytest.approx(0.627, abs=0.01)
    assert s["sa16_approx_vs_ref5"] == pytest.approx(0.242, abs=0.01)


def test_gemm_energy_estimate_scales():
    e1 = energy.gemm_energy_estimate(64, 64, 64, sa_dim=8)
    e2 = energy.gemm_energy_estimate(128, 64, 64, sa_dim=8)
    assert e2["energy_nJ"] == pytest.approx(2 * e1["energy_nJ"], rel=0.01)
    ex = energy.gemm_energy_estimate(64, 64, 64, sa_dim=8, design="exact_ref6")
    ap = energy.gemm_energy_estimate(64, 64, 64, sa_dim=8, design="proposed_approx")
    assert ap["energy_nJ"] < ex["energy_nJ"]


# --- Latency formula 3N-2 [11] ----------------------------------------------

@pytest.mark.parametrize("n", [2, 3, 4, 8])
def test_latency_formula(n):
    assert systolic.latency_cycles(n) == 3 * n - 2


def test_systolic_simulation_exact():
    rng = np.random.default_rng(5)
    a = rng.integers(-128, 128, (4, 4)).astype(np.int64)
    b = rng.integers(-128, 128, (4, 4)).astype(np.int64)
    out, cycles = systolic.simulate(a, b)
    assert np.array_equal(out, a @ b)
    assert cycles == 3 * 4 - 2


def test_systolic_simulation_approx_pe():
    rng = np.random.default_rng(6)
    a = rng.integers(-16, 16, (3, 3)).astype(np.int64)
    b = rng.integers(-16, 16, (3, 3)).astype(np.int64)
    out, _ = systolic.simulate_approx(a, b, k=0)
    assert np.array_equal(out, a @ b)
    out4, _ = systolic.simulate_approx(a, b, k=4)
    assert np.abs(out4 - a @ b).max() < (1 << 4) * 16
