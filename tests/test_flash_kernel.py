"""Pallas flash-attention kernel vs the validated pure-JAX chunked attention."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import chunked_attention


def _ref(q, k, v, *, causal, window, softcap):
    # (B,H,S,D) -> layers.chunked_attention layout (B,S,H,D)
    b, h, s, d = q.shape
    qpos = np.arange(s)
    out = chunked_attention(
        jnp.asarray(q.transpose(0, 2, 1, 3)), jnp.asarray(k.transpose(0, 2, 1, 3)),
        jnp.asarray(v.transpose(0, 2, 1, 3)), jnp.asarray(qpos), k.shape[2],
        causal=causal, window=window, softcap=softcap, chunk=16, q_chunk=16)
    return np.asarray(out).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("s,bq,bk,causal,window,softcap", [
    (32, 8, 8, True, 0, 0.0),
    (32, 16, 8, True, 0, 0.0),
    (64, 16, 16, True, 12, 0.0),     # sliding window
    (32, 8, 8, True, 0, 30.0),       # softcap
    (32, 8, 16, False, 0, 0.0),      # bidirectional
])
def test_flash_matches_reference(s, bq, bk, causal, window, softcap):
    rng = np.random.default_rng(s + bq)
    b, h, d = 2, 3, 16
    q = rng.normal(size=(b, h, s, d)).astype(np.float32)
    k = rng.normal(size=(b, h, s, d)).astype(np.float32)
    v = rng.normal(size=(b, h, s, d)).astype(np.float32)
    got = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal,
        window=window, softcap=softcap, bq=bq, bk=bk, interpret=True))
    want = _ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_causal_skips_are_exact():
    """The causal early-exit over K blocks must not change results."""
    rng = np.random.default_rng(9)
    b, h, s, d = 1, 2, 64, 8
    q = rng.normal(size=(b, h, s, d)).astype(np.float32)
    k = rng.normal(size=(b, h, s, d)).astype(np.float32)
    v = rng.normal(size=(b, h, s, d)).astype(np.float32)
    a = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), bq=16, bk=16,
                                   interpret=True))
    b_ = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), bq=64, bk=8,
                                    interpret=True))
    np.testing.assert_allclose(a, b_, rtol=2e-5, atol=2e-5)
