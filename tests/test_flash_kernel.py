"""Pallas flash-attention kernel vs the validated pure-JAX chunked attention."""
import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import chunked_attention

pytestmark = pytest.mark.kernel


def _ref(q, k, v, *, causal, window, softcap, kv_valid_len=None):
    # (B,H,S,D) -> layers.chunked_attention layout (B,S,H,D)
    b, h, s, d = q.shape
    qpos = np.arange(s)
    kvl = k.shape[2] if kv_valid_len is None else kv_valid_len
    out = chunked_attention(
        jnp.asarray(q.transpose(0, 2, 1, 3)), jnp.asarray(k.transpose(0, 2, 1, 3)),
        jnp.asarray(v.transpose(0, 2, 1, 3)), jnp.asarray(qpos), kvl,
        causal=causal, window=window, softcap=softcap, chunk=16, q_chunk=16)
    return np.asarray(out).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("s,bq,bk,causal,window,softcap", [
    (32, 8, 8, True, 0, 0.0),
    (32, 16, 8, True, 0, 0.0),
    (64, 16, 16, True, 12, 0.0),     # sliding window
    (32, 8, 8, True, 0, 30.0),       # softcap
    (32, 8, 16, False, 0, 0.0),      # bidirectional
])
def test_flash_matches_reference(s, bq, bk, causal, window, softcap):
    rng = np.random.default_rng(s + bq)
    b, h, d = 2, 3, 16
    q = rng.normal(size=(b, h, s, d)).astype(np.float32)
    k = rng.normal(size=(b, h, s, d)).astype(np.float32)
    v = rng.normal(size=(b, h, s, d)).astype(np.float32)
    got = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal,
        window=window, softcap=softcap, bq=bq, bk=bk, interpret=True))
    want = _ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_causal_skips_are_exact():
    """The causal early-exit over K blocks must not change results."""
    rng = np.random.default_rng(9)
    b, h, s, d = 1, 2, 64, 8
    q = rng.normal(size=(b, h, s, d)).astype(np.float32)
    k = rng.normal(size=(b, h, s, d)).astype(np.float32)
    v = rng.normal(size=(b, h, s, d)).astype(np.float32)
    a = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), bq=16, bk=16,
                                   interpret=True))
    b_ = np.asarray(flash_attention(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), bq=64, bk=8,
                                    interpret=True))
    np.testing.assert_allclose(a, b_, rtol=2e-5, atol=2e-5)


# --- ragged kv_valid_len: non-multiple-of-block live lengths ----------------
#
# Callers zero-pad S_kv up to a block multiple. Without masking, padded rows
# score s = 0 and contribute exp(0 - m) softmax mass — invisible under causal
# self-attention (the causal mask hides trailing keys) but a real divergence
# for non-causal / cross-attention. These tests pin the fix.


def _padded(rng, b, h, s_live, s_pad, d, sq):
    q = rng.normal(size=(b, h, sq, d)).astype(np.float32)
    k = np.zeros((b, h, s_pad, d), np.float32)
    v = np.zeros((b, h, s_pad, d), np.float32)
    k[:, :, :s_live] = rng.normal(size=(b, h, s_live, d))
    v[:, :, :s_live] = rng.normal(size=(b, h, s_live, d))
    return q, k, v


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("live", [1, 7, 8, 9, 15, 17, 31, 32])
def test_flash_ragged_kv_valid_len(causal, live):
    """flash(padded K/V, kv_valid_len=L) == reference on the first L keys."""
    rng = np.random.default_rng(live * 2 + causal)
    b, h, d, sq, bk = 2, 2, 16, 32, 8
    s_pad = 32
    q, k, v = _padded(rng, b, h, live, s_pad, d, sq)
    got = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), kv_valid_len=live,
        causal=causal, bq=8, bk=bk, interpret=True))
    want = _ref(q, k, v, causal=causal, window=0, softcap=0.0,
                kv_valid_len=live)
    # causal rows with no visible key (q pos < first live key never happens
    # here: live >= 1 and causal keys start at 0) — all rows comparable
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_noncausal_padding_was_the_bug():
    """The unmasked kernel demonstrably diverges on non-causal padded K — the
    masked one must match the truncated-input oracle exactly (same math)."""
    rng = np.random.default_rng(3)
    b, h, d, sq, live, s_pad = 1, 2, 8, 16, 11, 16
    q, k, v = _padded(rng, b, h, live, s_pad, d, sq)
    masked = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), kv_valid_len=live,
        causal=False, bq=8, bk=8, interpret=True))
    unmasked = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=False, bq=8, bk=8, interpret=True))
    want = _ref(q, k, v, causal=False, window=0, softcap=0.0,
                kv_valid_len=live)
    np.testing.assert_allclose(masked, want, rtol=2e-4, atol=2e-4)
    # the padded keys carry nonzero softmax mass without the mask
    assert np.abs(unmasked - want).max() > 1e-3


def test_flash_per_batch_kv_valid_len():
    """(B,) lengths: each batch row masks at its own live length."""
    rng = np.random.default_rng(17)
    b, h, d, sq, s_pad = 3, 2, 16, 16, 32
    lens = np.array([5, 19, 32], np.int32)
    q = rng.normal(size=(b, h, sq, d)).astype(np.float32)
    k = np.zeros((b, h, s_pad, d), np.float32)
    v = np.zeros((b, h, s_pad, d), np.float32)
    for i, L in enumerate(lens):
        k[i, :, :L] = rng.normal(size=(h, L, d))
        v[i, :, :L] = rng.normal(size=(h, L, d))
    got = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        kv_valid_len=jnp.asarray(lens), causal=False, bq=8, bk=8,
        interpret=True))
    for i, L in enumerate(lens):
        want = _ref(q[i:i + 1], k[i:i + 1], v[i:i + 1], causal=False,
                    window=0, softcap=0.0, kv_valid_len=int(L))
        np.testing.assert_allclose(got[i:i + 1], want, rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(live=st.integers(1, 64), causal=st.booleans(),
       bk=st.sampled_from([8, 16, 32]))
def test_flash_ragged_property(live, causal, bk):
    """Any live length in [1, S], any block size: padded == truncated oracle."""
    rng = np.random.default_rng(live * 7 + bk + causal)
    b, h, d, sq, s_pad = 1, 2, 8, 32, 64
    q, k, v = _padded(rng, b, h, live, s_pad, d, sq)
    got = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), kv_valid_len=live,
        causal=causal, bq=16, bk=bk, interpret=True))
    want = _ref(q, k, v, causal=causal, window=0, softcap=0.0,
                kv_valid_len=live)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
