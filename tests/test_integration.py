"""Integration: dry-run CLI on the production mesh (subprocess — needs its own
jax process for the 512 placeholder devices), and train-loop checkpoint/resume."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_cli_single_cell(tmp_path):
    """Deliverable (e) in miniature: one real cell through lower+compile on the
    16x16 production mesh with 512 host placeholder devices."""
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-360m",
         "--shape", "decode_32k", "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=500)
    assert res.returncode == 0, res.stdout + res.stderr
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["status"] == "ok"
    assert rec["mesh"] == "16x16"
    assert rec["analytic"]["t_memory_s"] > 0
    assert rec["collectives"]["count"] > 0


def test_train_loop_checkpoints_and_resumes(tmp_path):
    import jax
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.steps import TrainHParams, assemble_train
    from repro.models import get_model
    from repro.train.loop import LoopConfig, train
    from repro.checkpoint import ckpt

    cfg = reduced(ARCHS["smollm-360m"])
    shape = ShapeSpec("t", "train", 16, 4)
    mesh = make_debug_mesh(1, 1)
    hp = TrainHParams(n_micro=1, total_steps=8)
    step, arg_specs, in_sh, out_sh, hp = assemble_train(cfg, shape, mesh, hp)
    model = get_model(cfg)
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lc = LoopConfig(steps=6, ckpt_dir=str(tmp_path), ckpt_every=2,
                        log_every=100)
        stats = train(cfg, shape, jitted, model.init_params, lc,
                      log=lambda *_: None)
        assert stats["steps"] == 6
        assert ckpt.latest_step(str(tmp_path)) == 6
        # resume: continues from step 6, runs 2 more
        lc2 = LoopConfig(steps=8, ckpt_dir=str(tmp_path), ckpt_every=100,
                         log_every=100)
        stats2 = train(cfg, shape, jitted, model.init_params, lc2,
                       log=lambda *_: None)
        assert stats2["steps"] == 2
