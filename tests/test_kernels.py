"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps vs ref.py."""
import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.emulate import matmul_oracle
from repro.kernels import ops, ref
from repro.kernels.approx_gemm import make_table


def _rand(shape, rng, lo=-128, hi=128):
    return rng.integers(lo, hi, shape).astype(np.int32)


SHAPES = [(8, 8, 8), (16, 24, 8), (100, 70, 36), (256, 256, 256), (33, 1, 5),
          (1, 128, 1), (512, 64, 128)]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_systolic_matmul_shapes(m, k, n):
    rng = np.random.default_rng(m + k + n)
    a, b = _rand((m, k), rng), _rand((k, n), rng)
    out = np.asarray(ops.systolic_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(out, a @ b)


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("kf", [0, 3, 6])
def test_approx_matmul_vs_ref(m, k, n, kf):
    rng = np.random.default_rng(m * 3 + k + n + kf)
    a, b = _rand((m, k), rng), _rand((k, n), rng)
    out = np.asarray(ops.approx_matmul(jnp.asarray(a), jnp.asarray(b), k=kf))
    want = np.asarray(ref.approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), k=kf))
    assert np.array_equal(out, want)
    if kf == 0:
        assert np.array_equal(out, a @ b)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 16, 16), (32, 8, 16)])
def test_systolic_matmul_block_sweep(blocks):
    bm, bn, bk = blocks
    rng = np.random.default_rng(sum(blocks))
    a, b = _rand((64, 48), rng), _rand((48, 40), rng)
    out = np.asarray(ops.systolic_matmul(jnp.asarray(a), jnp.asarray(b),
                                         bm=bm, bn=bn, bk=bk))
    assert np.array_equal(out, a @ b)


def test_approx_padding_correction():
    """K padding injects T[0,0] per padded row; the wrapper must subtract it.
    Use k=8 where T[0,0] != 0 (deep approximation corrupts the zero product)."""
    t = np.asarray(make_table(8))
    rng = np.random.default_rng(9)
    a, b = _rand((9, 11), rng), _rand((11, 7), rng)
    out = np.asarray(ops.approx_matmul(jnp.asarray(a), jnp.asarray(b), k=8))
    want = np.asarray(ref.approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), k=8))
    assert np.array_equal(out, want), f"T[0,0]={t[0]}"


def test_lut_model_close_to_fused_oracle():
    """The multiplier-approx model must track the fused bit-level oracle closely
    (it drops only the accumulator's low-column error component)."""
    rng = np.random.default_rng(11)
    a, b = _rand((32, 64), rng), _rand((64, 16), rng)
    for kf in (2, 4, 6):
        fused = np.asarray(matmul_oracle(a, b, k=kf), np.int64)
        lutm = np.asarray(ops.approx_matmul(jnp.asarray(a), jnp.asarray(b), k=kf),
                          np.int64)
        exact = (a.astype(np.int64) @ b)
        scale = np.abs(exact).mean() + 1
        rel = np.abs(fused - lutm).mean() / scale
        # deviation = the fused accumulator's own low-column error, which the LUT
        # model intentionally drops; it grows ~2^k per MAC (k=6 -> a few percent)
        assert rel < 2 ** kf * 0.0008, (kf, rel)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40),
       st.integers(0, 8))
def test_property_kernels_match_ref(m, k, n, kf):
    rng = np.random.default_rng(m * 7919 + k * 104729 + n * 1299709 + kf)
    a, b = _rand((m, k), rng), _rand((k, n), rng)
    out_e = np.asarray(ops.systolic_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(out_e, a @ b)
    out_a = np.asarray(ops.approx_matmul(jnp.asarray(a), jnp.asarray(b), k=kf))
    want = np.asarray(ref.approx_matmul_ref(jnp.asarray(a), jnp.asarray(b), k=kf))
    assert np.array_equal(out_a, want)


def test_int4_tables():
    """Kernel path generalizes across operand widths (dtype sweep analogue)."""
    rng = np.random.default_rng(4)
    a = rng.integers(-8, 8, (16, 16)).astype(np.int32)
    b = rng.integers(-8, 8, (16, 16)).astype(np.int32)
    out = np.asarray(ops.approx_matmul(jnp.asarray(a), jnp.asarray(b), k=0,
                                       n_bits=4, acc_bits=16))
    assert np.array_equal(out, a @ b)
