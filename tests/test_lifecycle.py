"""Serve-engine lifecycle hardening: deadlines, cancellation, bounded queue,
priority preemption, and ABFT fault recovery (PR 6).

Contracts pinned here:

* **Clean guard parity**: ``guard='detect'`` serves bit-identical streams to
  the unguarded engine with zero fault events — scrubbing and output
  checksums never perturb or false-positive on healthy runs.
* **Recovery**: an injected bit flip in the bound params is detected by the
  pre-step scrub and healed by restore-from-pristine + re-dispatch; a flip
  in the paged KV pool quarantines (requeue + pool rebuild). Both recover
  **bit-identical** final streams.
* **Lifecycle**: TTFT/total deadlines retire in engine steps (deterministic),
  cancellation frees slots/blocks immediately, a bounded queue rejects with
  ``rejected_queue_full``, and a higher-priority arrival preempts
  lower-priority slots under block-pool exhaustion — the preempted request
  replays bit-identically and is aged so it cannot starve.
* **Allocator invariants**: `conftest` turns retirement-time
  ``BlockPool.check()`` on for the whole suite, so every run here doubles as
  a block-leak regression test; the property tests additionally drain random
  interleavings of submit/cancel/preempt and assert the pool empties.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

import jax

from repro.configs import ARCHS, reduced
from repro.core import abft, gemm
from repro.launch import engine as E
from repro.launch import faults as F
from repro.models import get_model
from repro.train.fault import TransientError

CFG = reduced(ARCHS["smollm-360m"])
PARAMS = get_model(CFG).init_params(jax.random.PRNGKey(0))
LENS = ((5, 4), (8, 6), (3, 5), (6, 3))
DETECT = gemm.GemmPolicy(backend="approx_lut", k=4, guard="detect")
UNGUARDED = gemm.GemmPolicy(backend="approx_lut", k=4)


def mkreqs(**kw):
    rng = np.random.default_rng(0)
    return [E.Request(rid=i, prompt=rng.integers(
                0, CFG.vocab_size, pl).astype(np.int32),
                      max_new_tokens=gl, **kw)
            for i, (pl, gl) in enumerate(LENS)]


def mkengine(policy=gemm.EXACT, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 16)
    return E.ServeEngine(CFG, PARAMS, policy=policy, **kw)


_BASE_CACHE = {}


def _base_exact():
    """Lazy per-request exact reference streams for the property tests
    (hypothesis-decorated tests cannot take fixtures through the
    deterministic fallback)."""
    if not _BASE_CACHE:
        _BASE_CACHE.update({rid: f.tokens for rid, f in
                            mkengine(gemm.EXACT).run(mkreqs()).items()})
    return _BASE_CACHE


@pytest.fixture(scope="module")
def base():
    """Per-request reference streams from an unguarded clean run."""
    return {p: {rid: f.tokens for rid, f in
                mkengine(p).run(mkreqs()).items()}
            for p in (gemm.EXACT, UNGUARDED)}


def _assert_streams(finished, ref):
    for rid, tokens in ref.items():
        np.testing.assert_array_equal(finished[rid].tokens, tokens,
                                      err_msg=f"rid={rid} stream diverged")


# --- clean guard parity -------------------------------------------------------

def test_guard_detect_clean_parity(base):
    eng = mkengine(DETECT)
    _assert_streams(eng.run(mkreqs()), base[UNGUARDED])
    assert eng.events["faults_detected"] == 0
    assert eng.events["quarantines"] == 0
    st_ = eng.stats
    assert st_["faults_detected"] == 0       # counters surfaced via stats


# --- fault recovery -----------------------------------------------------------

def _strike_at(eng, inj, step, target):
    orig = eng.step

    def step_fn():
        if eng.step_count == step:
            inj.strike_engine(eng, target=target)
        orig()

    eng.step = step_fn


def test_params_fault_restores_and_replays(base):
    inj = F.FaultInjector(7)
    eng = mkengine(DETECT)
    _strike_at(eng, inj, 3, "params")
    fin = eng.run(mkreqs())
    assert eng.events["faults_detected"] >= 1
    assert eng.events["quarantines"] == 0
    _assert_streams(fin, base[UNGUARDED])    # recovery is bit-invisible
    assert len(inj.records) == 1             # campaign log replays from seed


def test_cache_fault_quarantines_and_replays(base):
    inj = F.FaultInjector(11)
    eng = mkengine(DETECT)
    _strike_at(eng, inj, 4, "cache")
    fin = eng.run(mkreqs())
    assert eng.events["quarantines"] >= 1
    assert eng.events["preemptions"] >= 1    # actives were requeued
    _assert_streams(fin, base[UNGUARDED])
    eng.pool.check()


def test_injector_is_deterministic():
    r1 = F.FaultInjector(5).flip_params(PARAMS)[1]
    r2 = F.FaultInjector(5).flip_params(PARAMS)[1]
    assert r1 == r2
    assert F.FaultInjector(6).flip_params(PARAMS)[1] != r1


def test_transient_steps_retried(base):
    inj = F.FaultInjector(13)
    eng = mkengine(gemm.EXACT)
    with inj.failing_steps(eng, [2, 5]):
        fin = eng.run(mkreqs())
    assert eng.events["step_retries"] == 2
    _assert_streams(fin, base[gemm.EXACT])


def test_transient_retries_are_bounded():
    inj = F.FaultInjector(13)
    eng = mkengine(gemm.EXACT, max_step_retries=2)
    with inj.failing_steps(eng, [1], times=5):
        with pytest.raises(TransientError):
            eng.run(mkreqs())
    assert eng.events["step_retries"] == 3   # initial try + 2 retries failed


def test_contiguous_engine_fails_fast():
    inj = F.FaultInjector(17)
    eng = mkengine(DETECT, paged=False)
    _strike_at(eng, inj, 3, "params")
    with pytest.raises(abft.AbftFaultError):
        eng.run(mkreqs())


# --- bounded queue / cancellation / deadlines ---------------------------------

def test_queue_limit_rejects(base):
    eng = mkengine(queue_limit=2)
    oks = [eng.submit(r) for r in mkreqs()]
    assert oks == [True, True, False, False]
    while eng.queue or eng.active.any():
        eng.step()
    assert eng.events[E.REJECTED_QUEUE_FULL] == 2
    assert eng.finished[2].finish_reason == E.REJECTED_QUEUE_FULL
    assert eng.finished[2].admitted_step == -1
    np.testing.assert_array_equal(eng.finished[0].tokens,
                                  base[gemm.EXACT][0])


def test_cancel_frees_slot_and_blocks(base):
    eng = mkengine(max_slots=1)
    for r in mkreqs():
        eng.submit(r)
    eng.step(); eng.step()
    assert eng.cancel(0)                     # active: slot + blocks freed now
    assert eng.pool.allocated_blocks == 0
    assert eng.cancel(2)                     # still queued
    assert not eng.cancel(99)                # unknown rid
    while eng.queue or eng.active.any():
        eng.step()
    assert eng.finished[0].finish_reason == "cancelled"
    assert eng.finished[2].finish_reason == "cancelled"
    assert eng.events["cancelled"] == 2
    np.testing.assert_array_equal(eng.finished[1].tokens,
                                  base[gemm.EXACT][1])
    eng.pool.check()


def test_deadlines_retire_in_engine_steps():
    reqs = mkreqs()
    reqs[2].ttft_deadline = 0                # expires before first admission
    reqs[1].total_deadline = 3
    eng = mkengine()
    fin = eng.run(reqs)
    assert fin[2].finish_reason == "deadline_ttft" and fin[2].tokens.size == 0
    assert fin[1].finish_reason == "deadline_total"
    assert fin[0].finish_reason in ("eos", "length")
    assert eng.events["deadline_ttft"] == 1
    assert eng.events["deadline_total"] == 1


# --- priority preemption ------------------------------------------------------

def _tight_engine(**kw):
    # 3 slots over a 3-block pool: block exhaustion, not slot exhaustion,
    # is the bottleneck — the preemption trigger
    return mkengine(max_slots=3, n_blocks=3, block_size=8, **kw)


def test_priority_preempts_and_replays_bit_identical(base):
    reqs = mkreqs()
    reqs[3].priority = 5
    reqs[3].arrival = 2
    eng = _tight_engine()
    fin = eng.run(reqs)
    assert eng.events["preemptions"] >= 1
    assert any(f.preemptions for f in fin.values())
    _assert_streams(fin, base[gemm.EXACT])   # preemption invisible in streams
    eng.pool.check()


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 3), st.integers(0, 3), st.integers(0, 3),
       st.integers(0, 3), st.integers(0, 6))
def test_property_no_starvation_and_replay(p0, p1, p2, p3, arr):
    """Random priorities + a late arrival over an exhausted pool: every
    request still finishes (aging beats starvation) with its reference
    stream, and the pool drains clean."""
    ref = _base_exact()
    reqs = mkreqs()
    for r, p in zip(reqs, (p0, p1, p2, p3)):
        r.priority = p
    reqs[3].arrival = arr
    eng = _tight_engine()
    fin = eng.run(reqs, max_steps=500)
    assert len(fin) == len(reqs), "a request starved"
    _assert_streams(fin, ref)
    assert not eng.active.any() and eng.pool.allocated_blocks == 0
    assert eng.pool.reserved_blocks == 0
    eng.pool.check()


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 3), st.integers(1, 4), st.integers(0, 30))
def test_property_cancel_interleaving_never_leaks(cancel_rid, cancel_at,
                                                  extra_seed):
    """Cancel a random request at a random step mid-flight: the pool must
    drain to zero and every survivor must keep its reference stream."""
    reqs = mkreqs()
    rng = np.random.default_rng(extra_seed)
    for r in reqs:
        r.priority = int(rng.integers(0, 3))
    eng = _tight_engine()
    for r in reqs:
        eng.submit(r)
    for _ in range(cancel_at):
        eng.step()
    eng.cancel(cancel_rid)
    steps = 0
    while (eng.queue or eng.active.any()) and steps < 500:
        eng.step()
        steps += 1
    assert len(eng.finished) == len(reqs)
    assert eng.pool.allocated_blocks == 0 and eng.pool.reserved_blocks == 0
    eng.pool.check()
    for rid, tokens in _base_exact().items():
        if rid == cancel_rid:
            continue
        np.testing.assert_array_equal(eng.finished[rid].tokens, tokens,
                                      err_msg=f"rid={rid} diverged")




# --- scheduled fault campaign -------------------------------------------------

@pytest.mark.faultinject
@pytest.mark.slow
@pytest.mark.parametrize("seed", range(4))
def test_campaign_engine_strikes_recover(seed, base):
    """Seeded sweep: params/cache strikes at random steps, all detected and
    healed with bit-identical streams; the campaign log replays from seed."""
    rng = np.random.default_rng(seed)
    inj = F.FaultInjector(seed)
    target = ("params", "cache")[int(rng.integers(2))]
    eng = mkengine(DETECT)
    _strike_at(eng, inj, int(rng.integers(1, 8)), target)
    fin = eng.run(mkreqs())
    assert eng.events["faults_detected"] + eng.events["quarantines"] >= 1
    _assert_streams(fin, base[UNGUARDED])
    eng.pool.check()
