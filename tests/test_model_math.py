"""Deep correctness tests for the model math: MoE dispatch vs dense-compute
reference, SSD chunked scan vs sequential recurrence, mLSTM chunked vs
sequential, approximate-GEMM backends inside a full model forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.gemm import GemmPolicy
from repro.models import get_model, moe, ssm, xlstm


def test_moe_matches_dense_reference():
    """Capacity dispatch with ample capacity == explicit per-token expert mix."""
    cfg = dataclasses.replace(reduced(ARCHS["qwen3-moe-30b-a3b"]),
                              capacity_factor=8.0)   # no drops
    key = jax.random.PRNGKey(0)
    p = moe.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model),
                          jnp.float32)
    out, aux = moe.moe_block(p, x, cfg)

    # dense reference: compute every expert on every token, mix by router probs
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.n_active_experts)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    h1 = jnp.einsum("td,edf->tef", xf, p["w1"])
    h3 = jnp.einsum("td,edf->tef", xf, p["w3"])
    all_out = jnp.einsum("tef,efd->ted", jax.nn.silu(h1) * h3, p["w2"])
    mix = jnp.zeros_like(xf)
    for slot in range(cfg.n_active_experts):
        sel = jnp.take_along_axis(all_out, top_e[:, slot][:, None, None],
                                  axis=1)[:, 0]
        mix = mix + sel * top_p[:, slot][:, None]
    want = mix.reshape(x.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert jnp.isfinite(aux)


def test_moe_capacity_drops_tokens_gracefully():
    cfg = dataclasses.replace(reduced(ARCHS["qwen3-moe-30b-a3b"]),
                              capacity_factor=0.25)  # forced drops
    p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, _ = moe.moe_block(p, x, cfg)
    assert jnp.all(jnp.isfinite(out))


def _sequential_ssd(x, dt, a_log, b, c):
    """Reference: step-by-step SSD recurrence."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    a = -np.exp(np.asarray(a_log))
    s = np.zeros((bsz, h, p, n))
    ys = np.zeros((bsz, t, h, p))
    for i in range(t):
        da = np.exp(np.asarray(dt[:, i]) * a[None, :])        # (B,H)
        s = da[:, :, None, None] * s + np.einsum(
            "bh,bhp,bhn->bhpn", np.asarray(dt[:, i]), np.asarray(x[:, i]),
            np.asarray(b[:, i]))
        ys[:, i] = np.einsum("bhn,bhpn->bhp", np.asarray(c[:, i]), s)
    return ys, s


@pytest.mark.parametrize("t,chunk", [(8, 4), (12, 5), (16, 16), (7, 3)])
def test_ssd_chunked_matches_sequential(t, chunk):
    rng = np.random.default_rng(t * 10 + chunk)
    bsz, h, p, n = 2, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(bsz, t, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(bsz, t, h)), jnp.float32)
    a_log = jnp.asarray(rng.uniform(-1, 0.5, size=(h,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bsz, t, h, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(bsz, t, h, n)), jnp.float32)
    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    y, s_fin = ssm._ssd_chunked(x, dt, a_log, b, c, s0, chunk)
    y_ref, s_ref = _sequential_ssd(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), s_ref, rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_prefill_tail():
    """Running T steps of decode == one T-length forward (state equivalence)."""
    cfg = reduced(ARCHS["zamba2-1.2b"])
    p = ssm.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, cfg.d_model),
                          jnp.float32)
    full, st_full = ssm.mamba_block(p, x, cfg, chunk=3)
    # step-by-step
    di = cfg.ssm_expand * cfg.d_model
    heads = di // 64
    st = ssm.SSMState(jnp.zeros((1, heads, 64, cfg.ssm_state), jnp.float32),
                      jnp.zeros((1, cfg.ssm_conv - 1, di), jnp.float32))
    outs = []
    for i in range(6):
        o, st = ssm.mamba_block(p, x[:, i:i + 1], cfg, state=st)
        outs.append(o)
    step_out = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_out), np.asarray(full),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(st.s), np.asarray(st_full.s),
                               rtol=5e-3, atol=5e-3)


def _mlstm_sequential(q, k, v, li, lf):
    """Step-by-step stabilized mLSTM recurrence (xLSTM eqs.):
    C += i v k^T ; n += i k ; y = C q / max(|n.q|, exp(-m))."""
    b, t, h, d = q.shape
    c = np.zeros((b, h, d, d))
    n = np.zeros((b, h, d))
    m = np.zeros((b, h))
    ys = np.zeros((b, t, h, d))
    for i in range(t):
        m_new = np.maximum(lf[:, i] + m, li[:, i])
        f = np.exp(lf[:, i] + m - m_new)
        ig = np.exp(li[:, i] - m_new)
        c = f[:, :, None, None] * c + ig[:, :, None, None] * np.einsum(
            "bhd,bhe->bhde", v[:, i], k[:, i])
        n = f[:, :, None] * n + ig[:, :, None] * k[:, i]
        num = np.einsum("bhe,bhde->bhd", q[:, i], c)     # y_d = v_d (k.q)
        den = np.abs(np.einsum("bhd,bhd->bh", q[:, i], n))
        ys[:, i] = num / np.maximum(den, np.exp(-m_new))[:, :, None]
        m = m_new
    return ys


def test_mlstm_chunked_matches_sequential_and_chunk_invariant():
    rng = np.random.default_rng(0)
    b, t, h, d = 2, 12, 2, 4
    q = rng.normal(size=(b, t, h, d)).astype(np.float32)
    k = rng.normal(size=(b, t, h, d)).astype(np.float32)
    v = rng.normal(size=(b, t, h, d)).astype(np.float32)
    li = rng.normal(size=(b, t, h)).astype(np.float32)
    lf = -np.abs(rng.normal(size=(b, t, h))).astype(np.float32)
    ref = _mlstm_sequential(q, k, v, li, lf)
    for chunk in (3, 4, 12):
        y, _ = xlstm._mlstm_chunked(*(jnp.asarray(z) for z in (q, k, v, li, lf)),
                                    None, chunk)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)


def test_model_forward_with_approx_backend():
    """The paper's approximate GEMM as the model's arithmetic: loss stays finite
    and close to the exact-backend loss at k=2."""
    import dataclasses as dc
    cfg = dc.replace(reduced(ARCHS["smollm-360m"]), n_layers=2)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)))}
    exact = float(model.lm_loss(params, batch))
    approx = float(model.lm_loss(params, batch,
                                 policy=GemmPolicy(backend="approx_lut", k=2)))
    assert np.isfinite(approx)
    assert abs(approx - exact) / max(exact, 1e-9) < 0.1, (exact, approx)


def test_mlstm_state_carry_across_calls():
    """Running two half-sequences with carried state == one full run."""
    rng = np.random.default_rng(3)
    b, t, h, d = 1, 8, 2, 4
    arrs = [rng.normal(size=(b, t, h, d)).astype(np.float32) for _ in range(3)]
    li = rng.normal(size=(b, t, h)).astype(np.float32)
    lf = -np.abs(rng.normal(size=(b, t, h))).astype(np.float32)
    q, k, v = (jnp.asarray(z) for z in arrs)
    lij, lfj = jnp.asarray(li), jnp.asarray(lf)
    y_full, _ = xlstm._mlstm_chunked(q, k, v, lij, lfj, None, 4)
    y1, st = xlstm._mlstm_chunked(q[:, :4], k[:, :4], v[:, :4],
                                  lij[:, :4], lfj[:, :4], None, 4)
    y2, _ = xlstm._mlstm_chunked(q[:, 4:], k[:, 4:], v[:, 4:],
                                 lij[:, 4:], lfj[:, 4:], st, 4)
    got = np.concatenate([np.asarray(y1), np.asarray(y2)], axis=1)
    np.testing.assert_allclose(got, np.asarray(y_full), rtol=2e-4, atol=2e-4)
