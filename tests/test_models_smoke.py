"""Per-architecture smoke tests: reduced config, one train + prefill + decode
step on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import get_model, input_specs

ARCH_IDS = sorted(ARCHS)


def _batch_for(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.family == "audio":
        batch["input_embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
        batch["loss_mask"] = jnp.ones((b, s), jnp.float32)
    if cfg.family == "vlm":
        s_img = max(2, s // 4)
        batch["input_embeds"] = jnp.asarray(
            rng.normal(size=(b, s_img, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = reduced(ARCHS[arch])
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(lambda p: model.lm_loss(p, batch))(params)
    assert jnp.isfinite(loss), (arch, loss)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(jnp.all(jnp.isfinite(g.astype(jnp.float32))) for g in flat), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_and_decode(arch):
    cfg = reduced(ARCHS[arch])
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    b, s = 2, 12
    batch = _batch_for(cfg, b, s)
    if cfg.family == "audio":
        # encoder-only: no decode; forward returns per-frame logits via loss path
        loss = model.lm_loss(params, batch)
        assert jnp.isfinite(loss)
        return
    cache = model.init_cache(b, s + 4)
    if cfg.family == "vlm":
        pre_batch = {"tokens": batch["tokens"], "input_embeds": batch["input_embeds"]}
        pre_len = batch["tokens"].shape[1] + batch["input_embeds"].shape[1]
        cache = model.init_cache(b, pre_len + 4)
    else:
        pre_batch = {"tokens": batch["tokens"]}
        pre_len = s
    logits, cache = model.prefill(params, pre_batch, cache)
    assert logits.shape == (b, 1, cfg.vocab_size), (arch, logits.shape)
    assert jnp.all(jnp.isfinite(logits)), arch
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    logits2, cache = model.decode_step(params, tok, cache, jnp.int32(pre_len))
    assert logits2.shape == (b, 1, cfg.vocab_size), arch
    assert jnp.all(jnp.isfinite(logits2)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = ARCHS[arch]
    assert len(cfg.shapes) == 4
    for sh in cfg.shapes:
        specs = input_specs(cfg, sh)
        assert specs, (arch, sh.name)
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)


def test_param_counts_sane():
    # headline sizes should be in the right ballpark (loose factor-2 bands)
    expect = {"qwen2.5-14b": 14e9, "gemma3-12b": 12e9, "gemma2-27b": 27e9,
              "pixtral-12b": 12e9, "smollm-360m": 0.36e9,
              "moonshot-v1-16b-a3b": 16e9, "qwen3-moe-30b-a3b": 30e9,
              "zamba2-1.2b": 1.2e9, "hubert-xlarge": 1e9, "xlstm-350m": 0.35e9}
    for name, want in expect.items():
        got = ARCHS[name].param_count()
        assert want / 2.2 < got < want * 2.2, (name, got, want)
