"""Multi-step fused-horizon dispatch (PR 9): parity + device retirement.

Pins the `ServeEngine(multi_step=n)` contract (`steps.make_multi_step`):

* **Bit-parity**: multi-step streams equal `multi_step=1` and the solo
  lockstep reference — all six backends, bound/unbound, paged gather +
  paged kernel, contiguous, mixed prefill/decode traces, and a hypothesis
  property over random Poisson traces.
* **Device-resident retirement / trim-past-EOS**: tokens a slot would have
  produced after its in-horizon EOS never reach `slot_out`, for every
  horizon n in {1, 2, 4, 8} with EOS landing on each sub-step offset.
* **Host-overhead telemetry**: `multi_step=8` bounds host syncs per
  generated token to <= 1/8 on a decode-heavy trace (`stats` counters).
* **Reliability**: capped monotonic retry backoff (`backoff_s_total`),
  per-sub-step ABFT fault attribution (`core.abft.substep`), guard-clean
  horizons, and params-fault recovery replaying a whole horizon.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.core import abft, gemm
from repro.launch import engine as E
from repro.launch import faults as F
from repro.launch import sampling
from repro.launch.serve import lockstep_generate
from repro.models import get_model

CFG = reduced(ARCHS["smollm-360m"])
PARAMS = get_model(CFG).init_params(jax.random.PRNGKey(0))
LENS = ((5, 4), (8, 6), (3, 5), (6, 3))
SHORT_LENS = ((4, 3), (6, 4), (3, 3))
BACKENDS = ("exact", "mxu_int8", "approx_lut", "approx_onehot", "approx_delta")


def _requests(cfg, lens, *, arrivals=None, seed=0, params=sampling.GREEDY):
    rng = np.random.default_rng(seed)
    return [E.Request(
        rid=rid, prompt=rng.integers(0, cfg.vocab_size, pl).astype(np.int32),
        max_new_tokens=gl, params=params,
        arrival=0 if arrivals is None else arrivals[rid])
        for rid, (pl, gl) in enumerate(lens)]


def _engine(params=PARAMS, policy=gemm.EXACT, cfg=CFG, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 16)
    return E.ServeEngine(cfg, params, policy=policy, **kw)


def _assert_streams(fin_a, fin_b):
    assert sorted(fin_a) == sorted(fin_b)
    for rid in fin_a:
        np.testing.assert_array_equal(fin_a[rid].tokens, fin_b[rid].tokens,
                                      err_msg=f"rid={rid} stream diverged")


# --- bit-parity grid ---------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("bound", (False, True))
def test_multi_step_parity_all_backends(backend, bound):
    """multi_step=4 paged streams == multi_step=1 == solo lockstep, every
    backend, raw and `gemm.bind`-bound params."""
    if bound and backend == "exact":
        pytest.skip("binding is a no-op for exact — identical to unbound")
    model = get_model(CFG)
    pol = gemm.GemmPolicy(backend=backend, k=4)
    p = model.bind_params(PARAMS, pol) if bound else PARAMS
    lens = SHORT_LENS if backend in ("approx_lut", "approx_onehot") else LENS
    fin1 = _engine(p, pol).run(_requests(CFG, lens))
    fin4 = _engine(p, pol, multi_step=4).run(_requests(CFG, lens))
    _assert_streams(fin1, fin4)
    for r in _requests(CFG, lens):
        ref = lockstep_generate(CFG, model, p, jnp.asarray(r.prompt[None]),
                                r.max_new_tokens, policy=pol)
        np.testing.assert_array_equal(fin4[r.rid].tokens, ref[0],
                                      err_msg=f"rid={r.rid} != lockstep")


@pytest.mark.parametrize("bound", (False, True))
def test_multi_step_parity_oracle(bound):
    # the bit-level oracle is slow: 1 layer, tiny vocab, short streams
    import dataclasses
    cfg = dataclasses.replace(CFG, n_layers=1, vocab_size=64)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend="approx_oracle", k=4)
    p = model.bind_params(params, pol) if bound else params
    lens = ((3, 2), (4, 3), (2, 2))
    fin1 = _engine(p, pol, cfg=cfg, max_len=8, block_size=2).run(
        _requests(cfg, lens))
    fin2 = _engine(p, pol, cfg=cfg, max_len=8, block_size=2,
                   multi_step=2).run(_requests(cfg, lens))
    _assert_streams(fin1, fin2)


def test_multi_step_parity_paged_kernel():
    """Fused Pallas paged-attention reads inside the horizon scan: streams
    bit-identical to the gather path at n_splits == 1."""
    fin_gather = _engine(multi_step=4).run(_requests(CFG, LENS))
    fin_kernel = _engine(multi_step=4, paged_kernel=1).run(
        _requests(CFG, LENS))
    _assert_streams(fin_gather, fin_kernel)


def test_multi_step_parity_contiguous():
    """multi_step on the contiguous engine (fused whole-prompt admit +
    per-slot max_len regions) matches its own per-step mode and paged."""
    fin_c1 = _engine(paged=False).run(_requests(CFG, LENS))
    fin_c4 = _engine(paged=False, multi_step=4).run(_requests(CFG, LENS))
    fin_p4 = _engine(multi_step=4).run(_requests(CFG, LENS))
    _assert_streams(fin_c1, fin_c4)
    _assert_streams(fin_c4, fin_p4)


def test_multi_step_mixed_prefill_decode():
    """Staggered arrivals force horizons to interleave with chunked-prefill
    fallback steps; streams stay batch-composition independent (and the
    sampled ones stay a function of (seed, rid, token index) only)."""
    sp = sampling.SamplingParams(temperature=0.9, top_k=40, top_p=0.95,
                                 seed=7)
    for params in (sampling.GREEDY, sp):
        reqs = lambda: _requests(CFG, LENS, arrivals=[0, 2, 5, 9],
                                 params=params)
        fin1 = _engine(prefill_chunk=3).run(reqs())
        fin4 = _engine(prefill_chunk=3, multi_step=4).run(reqs())
        _assert_streams(fin1, fin4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n=st.sampled_from([2, 4, 8]))
def test_multi_step_random_trace_property(seed, n):
    """Hypothesis property: any random Poisson trace streams bit-identically
    under multi_step=n and multi_step=1."""
    reqs = lambda: E.make_poisson_trace(
        5, rate=2.0, vocab_size=CFG.vocab_size, prompt_lens=(3, 5, 7),
        gen_lens=(2, 4, 6, 9), seed=seed)
    fin1 = _engine().run(reqs())
    finn = _engine(multi_step=n).run(reqs())
    _assert_streams(fin1, finn)


# --- device-resident retirement / trim-past-EOS ------------------------------

def test_trim_past_eos_every_offset():
    """Tokens past an in-horizon EOS never reach `slot_out`: for every
    horizon n and every sub-step offset, retiring on the token at that
    offset yields exactly the per-step engine's trimmed stream."""
    sp = sampling.SamplingParams(temperature=0.9, top_k=0, top_p=1.0, seed=3)
    [probe] = _requests(CFG, [(5, 8)], params=sp)
    tokens = _engine().run([probe])[0].tokens
    assert len(tokens) == 8
    for n in (1, 2, 4, 8):
        for off in range(len(tokens)):
            eos = int(tokens[off])
            cut = int(np.argmax(tokens == eos)) + 1  # first occurrence
            [req] = _requests(CFG, [(5, 8)], params=sp)
            fin = _engine(eos_id=eos, multi_step=n).run([req])[0]
            np.testing.assert_array_equal(
                fin.tokens, tokens[:cut],
                err_msg=f"n={n} off={off}: stream not trimmed at EOS")
            assert fin.finish_reason == "eos", (n, off)
            # the EOS token itself is the stream's last — nothing after it
            assert int(fin.tokens[-1]) == eos


def test_multi_step_honors_budget_mid_horizon():
    """A slot whose token budget ends mid-horizon stops exactly there."""
    for gl in (1, 2, 3, 5, 7):
        fin1 = _engine().run(_requests(CFG, [(4, gl)]))
        fin8 = _engine(multi_step=8).run(_requests(CFG, [(4, gl)]))
        assert len(fin8[0].tokens) == gl
        _assert_streams(fin1, fin8)
        assert fin8[0].finish_reason == "length"


# --- host-overhead telemetry -------------------------------------------------

def test_multi_step_sync_budget():
    """Decode-heavy trace: multi_step=8 needs <= 1/8 host syncs per
    generated token (the acceptance bound) and far fewer than per-step."""
    lens = ((4, 32), (4, 32))
    e1 = _engine(max_len=40)
    e1.run(_requests(CFG, lens))
    e8 = _engine(max_len=40, multi_step=8)
    fin = e8.run(_requests(CFG, lens))
    gen = sum(len(f.tokens) for f in fin.values())
    assert gen == 64
    st1, st8 = e1.stats, e8.stats
    assert st8["host_syncs"] < st1["host_syncs"]
    assert st8["syncs_per_token"] <= 1 / 8, st8
    assert st8["multi_step"] == 8 and st1["multi_step"] == 1


def test_multi_step_rejects_bad_horizon():
    with pytest.raises(ValueError, match="multi_step"):
        _engine(multi_step=0)


# --- reliability: backoff, ABFT attribution, recovery ------------------------

def test_retry_backoff_capped_and_counted():
    """Transient-failure backoff waits against a monotonic deadline, is
    capped by `retry_backoff_cap_s`, and is surfaced in stats."""
    inj = F.FaultInjector(0)
    eng = _engine(retry_backoff_s=0.05, retry_backoff_cap_s=0.08,
                  max_step_retries=3)
    reqs = _requests(CFG, LENS)
    with inj.failing_steps(eng, fail_at=[3], times=2):
        fin = eng.run(reqs)
    st = eng.stats
    assert st["step_retries"] == 2
    # attempt 1 waits 0.05s, attempt 2 is capped at 0.08s (not 0.10s)
    assert 0.10 <= st["backoff_s_total"] <= 0.60, st["backoff_s_total"]
    _assert_streams(fin, _engine().run(_requests(CFG, LENS)))


def test_backoff_disabled_is_free():
    inj = F.FaultInjector(0)
    eng = _engine()                          # retry_backoff_s defaults to 0
    with inj.failing_steps(eng, fail_at=[2], times=1):
        eng.run(_requests(CFG, SHORT_LENS))
    assert eng.stats["backoff_s_total"] == 0.0


def test_abft_substep_attribution():
    """Faults recorded inside a scan body under `abft.substep(i)` carry the
    sub-step index through the traced callback."""
    abft.drain_faults()

    def body(carry, i):
        with abft.substep(i):
            abft.record(jnp.float32(2.0) + carry * 0, layer="scan.gemm",
                        kind="checksum", threshold=1.0)
        return carry, i

    @jax.jit
    def run(x):
        return jax.lax.scan(body, x, jnp.arange(3))[0]

    jax.block_until_ready(run(jnp.zeros(())))
    faults = abft.drain_faults()
    assert sorted(f.substep for f in faults) == [0, 1, 2]
    assert all(f.layer == "scan.gemm" for f in faults)
    assert "substep=" in str(faults[0])
    # outside a substep scope the field stays None (per-step path unchanged)
    abft.record(2.0, layer="plain", kind="checksum", threshold=1.0)
    [plain] = abft.drain_faults()
    assert plain.substep is None and "substep=" not in str(plain)


DETECT = gemm.GemmPolicy(backend="approx_lut", k=4, guard="detect")


def test_multi_step_guard_clean_parity():
    """Guarded multi-step horizons: scrub at horizon boundaries, zero false
    positives, streams identical to the unguarded per-step engine."""
    unguarded = gemm.GemmPolicy(backend="approx_lut", k=4)
    base = _engine(policy=unguarded).run(_requests(CFG, SHORT_LENS))
    eng = _engine(policy=DETECT, multi_step=4)
    fin = eng.run(_requests(CFG, SHORT_LENS))
    assert eng.events["faults_detected"] == 0
    assert eng.events["quarantines"] == 0
    _assert_streams(fin, base)


def test_multi_step_params_fault_replays_horizon():
    """A params fault detected at a horizon boundary restores the pristine
    snapshot and replays the whole horizon — bit-invisible in the stream."""
    unguarded = gemm.GemmPolicy(backend="approx_lut", k=4)
    base = _engine(policy=unguarded).run(_requests(CFG, SHORT_LENS))
    inj = F.FaultInjector(7)
    eng = _engine(policy=DETECT, multi_step=4)
    orig = eng.step
    struck = []

    def step_fn():
        if eng.step_count >= 3 and not struck:
            struck.append(inj.strike_engine(eng, target="params"))
        orig()

    eng.step = step_fn
    fin = eng.run(_requests(CFG, SHORT_LENS))
    assert eng.events["faults_detected"] >= 1
    assert eng.events["quarantines"] == 0
    _assert_streams(fin, base)
