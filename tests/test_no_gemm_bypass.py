"""Tier-1 guard: no GEMM over parameter leaves may bypass `core.gemm.dot`.

PR 8 migrated the original regex grep to the AST linter
(`repro.analysis.lint`, rule ``gemm-bypass`` — allowlists moved there
verbatim); this module now pins two things:

* the shipping ``models/`` tree lints clean (zero unsuppressed findings),
  and the allowlists are not stale (every sanctioned entry still matched);
* **no false-negative regression**: a fixture module with every bypass shape
  the grep used to catch (``jnp.matmul``, unsanctioned einsum, ``@``,
  ``lax.dot_general``, unnamed ``dot``) still produces the expected
  findings — including an einsum whose *equation* is sanctioned but whose
  *file* is not.
"""
import pathlib

from repro.analysis import lint

REPO_ROOT = pathlib.Path(__file__).parent.parent
FIXTURE = REPO_ROOT / "tests" / "fixtures" / "models" / "planted_bypass.py"


def _rules(findings):
    return [f.rule for f in findings if not f.suppressed]


def test_shipping_models_lint_clean():
    findings, _ = lint.lint_tree(REPO_ROOT)
    offenders = [f.format() for f in findings
                 if not f.suppressed and f.rule in ("gemm-bypass", "dot-layer")]
    assert not offenders, (
        "GEMM bypass / unnamed dot in models/ — route through "
        "core.gemm.dot(a, b, policy, layer=...) or sanction in "
        "repro.analysis.lint:\n" + "\n".join(offenders))


def test_sanction_lists_not_stale():
    """Every allowlist entry still matches code — prune the list with the code."""
    _, used = lint.lint_tree(REPO_ROOT)
    stale = lint.stale_sanctions(used)
    assert not stale, f"sanctioned entries no longer in the code: {stale}"


def test_linter_flags_planted_bypasses():
    findings = lint.lint_file(REPO_ROOT, FIXTURE)
    by_line = {}
    for f in findings:
        by_line.setdefault(f.rule, []).append(f)

    bypass = by_line.get("gemm-bypass", [])
    msgs = " | ".join(f.message for f in bypass)
    assert any("jnp.matmul" in f.message for f in bypass), msgs
    assert any("einsum('btd,dv->btv')" in f.message for f in bypass), msgs
    assert any("`@`" in f.message for f in bypass), msgs
    assert any("lax.dot_general" in f.message for f in bypass), msgs
    # sanctioned equation in the WRONG file must still be flagged
    assert any("bkgqd,bkcd->bkgqc" in f.site for f in bypass), msgs
    assert len(bypass) == 5, msgs

    assert len(by_line.get("dot-layer", [])) == 1
    assert len(by_line.get("prng-discipline", [])) == 1


def test_planted_matmul_is_line_accurate():
    """Findings point at the offending line (fixture pins line stability)."""
    findings = lint.lint_file(REPO_ROOT, FIXTURE)
    matmul = next(f for f in findings if "jnp.matmul" in f.message)
    src_line = FIXTURE.read_text().splitlines()[matmul.line - 1]
    assert "jnp.matmul" in src_line
