"""Tier-1 guard: no GEMM over parameter leaves may bypass `core.gemm.dot`.

Every weight matmul in `src/repro/models/` must route through the unified
`dot` entry point so per-layer `GemmPolicy` overrides (and `gemm.bind`
weight-stationary preparation) can target it. This test greps the model
sources and fails fast when a new bypass appears:

* `jnp.matmul` is banned outright — after PR 3 none remain (lm_head,
  patch_proj, and the MoE expert einsums all went through `dot`).
* `jnp.einsum` is allowed only for the *sanctioned* attention / SSM / xLSTM
  inner contractions, which act on activations and recurrent state — never on
  parameter leaves. The allowlist pins the exact equations; a new einsum
  (or repurposing an existing equation for weights) must either move to
  `dot` or be explicitly sanctioned here with justification.

* `@` / `jnp.dot` / `lax.dot_general` over parameter leaves are likewise
  banned, with a short sanction list for gating projections (MoE router,
  xLSTM gate pre-activations) whose outputs select/modulate rather than
  carry the GEMM workload — approximating them would change routing, not
  arithmetic.
"""
import pathlib
import re

import pytest

MODELS_DIR = pathlib.Path(__file__).parent.parent / "src" / "repro" / "models"

# (file, equation) pairs of sanctioned activation/state einsums
SANCTIONED_EINSUMS = {
    # flash attention scores / values (activation x activation)
    ("layers.py", "bkgqd,bkcd->bkgqc"),
    ("layers.py", "bkgqc,bkcd->bkgqd"),
    # Mamba2 SSD chunked recurrence (activations x recurrent state)
    ("ssm.py", "bihn,bjhn->bijh"),
    ("ssm.py", "bijh,bijh,bjh,bjhp->bihp"),
    ("ssm.py", "bihn,bhpn,bih->bihp"),
    ("ssm.py", "bjh,bjh,bjhp,bjhn->bhpn"),
    ("ssm.py", "bh,bhp,bhn->bhpn"),
    ("ssm.py", "bhn,bhpn->bhp"),
    # mLSTM chunked matrix-memory recurrence
    ("xlstm.py", "bihd,bjhd->bijh"),
    ("xlstm.py", "bijh,bijh,bjhd->bihd"),
    ("xlstm.py", "bihe,bhde,bih->bihd"),
    ("xlstm.py", "bijh,bijh->bih"),
    ("xlstm.py", "bihd,bhd,bih->bih"),
    ("xlstm.py", "bjh,bjhd,bjhe->bhde"),
    ("xlstm.py", "bjh,bjhd->bhd"),
}

EINSUM_RE = re.compile(r"jnp\.einsum\(\s*\"([^\"]+)\"", re.MULTILINE)

# `@` / dot_general expressions that are sanctioned gating computations
# (substring match against the offending source line)
SANCTIONED_OPERATOR_GEMMS = {
    ("moe.py", '@ p["router"]'),          # expert-routing logits
    ("xlstm.py", '@ p["w_if"]'),          # mLSTM input/forget gate pre-acts
    ("xlstm.py", "@ r_in.astype"),        # sLSTM recurrent gate pre-acts
}

OPERATOR_GEMM_MARKERS = (" @ ", "jnp.dot(", "lax.dot_general(")


def _model_sources():
    files = sorted(MODELS_DIR.glob("*.py"))
    assert files, f"no model sources found under {MODELS_DIR}"
    return files


def test_no_jnp_matmul_in_models():
    offenders = []
    for f in _model_sources():
        for i, line in enumerate(f.read_text().splitlines(), 1):
            if "jnp.matmul" in line:
                offenders.append(f"{f.name}:{i}: {line.strip()}")
    assert not offenders, (
        "jnp.matmul GEMMs bypass GemmPolicy/bind — route them through "
        "core.gemm.dot(a, b, policy, layer=...):\n" + "\n".join(offenders))


def test_no_operator_gemms_in_models():
    offenders = []
    for f in _model_sources():
        for i, line in enumerate(f.read_text().splitlines(), 1):
            if not any(m in line for m in OPERATOR_GEMM_MARKERS):
                continue
            if any(f.name == fn and frag in line
                   for fn, frag in SANCTIONED_OPERATOR_GEMMS):
                continue
            offenders.append(f"{f.name}:{i}: {line.strip()}")
    assert not offenders, (
        "`@`/jnp.dot/lax.dot_general GEMM bypasses GemmPolicy/bind — route "
        "it through core.gemm.dot, or sanction a genuine gating projection "
        "in SANCTIONED_OPERATOR_GEMMS:\n" + "\n".join(offenders))


def test_operator_sanction_list_not_stale():
    present = []
    for f in _model_sources():
        text = f.read_text()
        for fn, frag in SANCTIONED_OPERATOR_GEMMS:
            if f.name == fn and frag in text:
                present.append((fn, frag))
    stale = SANCTIONED_OPERATOR_GEMMS - set(present)
    assert not stale, f"sanctioned operator GEMMs no longer in the code: {stale}"


def test_all_einsums_sanctioned():
    offenders = []
    for f in _model_sources():
        for eq in EINSUM_RE.findall(f.read_text()):
            if (f.name, eq) not in SANCTIONED_EINSUMS:
                offenders.append(f"{f.name}: einsum({eq!r})")
    assert not offenders, (
        "unsanctioned jnp.einsum in models/ — parameter-leaf GEMMs must use "
        "core.gemm.dot; genuinely activation-only contractions must be added "
        "to SANCTIONED_EINSUMS with justification:\n" + "\n".join(offenders))


def test_sanctioned_list_not_stale():
    """Every sanctioned entry still exists — prune the allowlist with the code."""
    present = set()
    for f in _model_sources():
        for eq in EINSUM_RE.findall(f.read_text()):
            present.add((f.name, eq))
    stale = SANCTIONED_EINSUMS - present
    assert not stale, f"sanctioned einsums no longer in the code: {stale}"
