"""Paged KV cache: block-pool allocator properties + engine-level invariants.

Property tests (hypothesis, PR-1 deterministic fallback) drive random
admit/write/retire workloads through `launch.paged.BlockPool` and check the
allocator's safety invariants after every event:

* alloc/free round-trips leak no blocks (owned + free == pool, always);
* block tables never alias across live slots;
* a slot can never write past its reservation, and admission on an
  exhausted pool backpressures (raises) instead of corrupting.

Engine-level tests pin the behaviors the allocator enables: out-of-blocks
admission queues requests (and still finishes them, streams unmoved), and
prompt chunking at any chunk size cannot move a bit of any stream.
"""
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import ARCHS, reduced
from repro.launch import engine as E
from repro.launch.paged import BlockPool, PagedSpec, chain_keys, default_spec
from repro.models import get_model


# --- allocator properties ----------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 24), st.integers(1, 8), st.integers(1, 6),
       st.integers(0, 10 ** 6))
def test_pool_random_workload_invariants(n_blocks, block_size, n_slots, seed):
    """Random admit/extend/retire sequences: no leaks, no aliasing, writes
    bounded by reservations, full release restores the whole pool."""
    rng = np.random.default_rng(seed)
    max_len = n_blocks * block_size          # a slot may use the whole pool
    pool = BlockPool(PagedSpec(n_blocks, block_size), n_slots, max_len)
    live = {}                                # slot -> (reserved_blocks, written)
    for _ in range(200):
        op = rng.integers(0, 3)
        if op == 0 and len(live) < n_slots:          # admit
            slot = next(s for s in range(n_slots) if s not in live)
            need = int(rng.integers(1, n_blocks + 1))
            if pool.can_reserve(need):
                pool.reserve(slot, need)
                live[slot] = (need, 0)
        elif op == 1 and live:                       # alloc-on-write
            slot = int(rng.choice(list(live)))
            need, written = live[slot]
            upto = int(rng.integers(0, need * block_size + 1))
            pool.ensure(slot, upto)
            live[slot] = (need, max(written, upto))
        elif op == 2 and live:                       # retire
            slot = int(rng.choice(list(live)))
            pool.release(slot)
            del live[slot]
        pool.check()
    for slot in list(live):
        pool.release(slot)
    pool.check()
    assert pool.free_blocks == n_blocks, "full release must restore the pool"


def test_pool_overcommit_raises_instead_of_corrupting():
    pool = BlockPool(PagedSpec(4, 2), 4, 8)
    pool.reserve(0, 3)
    assert not pool.can_reserve(2)
    with pytest.raises(RuntimeError, match="out of blocks"):
        pool.reserve(1, 2)
    pool.reserve(1, 1)                       # what still fits, fits
    with pytest.raises(RuntimeError, match="past its reservation"):
        pool.ensure(1, 2 * 2 + 1)            # 3 blocks > reserved 1
    pool.check()


def test_pool_tables_point_only_at_owned_blocks():
    pool = BlockPool(PagedSpec(6, 4), 3, 24)
    pool.reserve(0, 3)
    pool.reserve(1, 3)
    pool.ensure(0, 9)                        # 3 blocks
    pool.ensure(1, 5)                        # 2 blocks
    t0 = set(pool.tables[0][pool.tables[0] != pool.spec.dump])
    t1 = set(pool.tables[1][pool.tables[1] != pool.spec.dump])
    assert not (t0 & t1), "live tables alias a block"
    pool.release(0)
    pool.reserve(2, 3)
    pool.ensure(2, 12)
    t2 = set(pool.tables[2][pool.tables[2] != pool.spec.dump])
    assert not (t1 & t2)
    pool.check()


def test_default_spec_matches_contiguous_budget():
    spec = default_spec(n_slots=4, max_len=30, block_size=8)
    assert spec.n_blocks == 4 * 4 and spec.block_size == 8
    assert spec.blocks_for(0) == 0 and spec.blocks_for(1) == 1
    assert spec.blocks_for(8) == 1 and spec.blocks_for(9) == 2


# --- prefix-sharing allocator properties (PR 10) -----------------------------

_SEED = b"\x00" * 16                         # any 16-byte chain seed


def test_pool_cow_and_eviction_directed():
    """The full sharing lifecycle on one concrete pool: publish-while-live,
    attach, whole-prompt-cached COW, tail-first eviction, invalidate."""
    pool = BlockPool(PagedSpec(6, 2), 3, 12)
    toks = np.arange(8, dtype=np.int32)      # 4 full blocks at bs=2
    keys = chain_keys(_SEED, toks, 2)
    pool.reserve(0, 4)
    pool.ensure(0, 8)
    pool.publish(0, keys)
    assert pool.cached_blocks == 4
    hits = pool.match_prefix(keys)
    assert hits == pool._owned[0]            # position-aligned attach order
    assert pool.match_prefix(keys[:2] + (b"nope",)) == hits[:2], \
        "match must stop at the first gap (longest *leading* run)"
    # whole-prompt-cached admission: resume at position 7 inside block 3
    pool.reserve(1, 5, hits=hits, extra_cow=1, written=7)
    assert pool.shared_attached == 4
    pool.ensure(1, 8)                        # rewrite pos 7: block 3 is shared
    copies = pool.drain_copies()
    assert copies == [(hits[3], pool._owned[1][3])]
    assert pool._owned[1][3] != hits[3] and pool.cow_copies == 1
    pool.check()
    # both retire: blocks park in the LRU, the COW clone (its key is still
    # mapped to the original) goes back to the free list
    pool.release(0, keys=keys)
    assert pool.evictable_blocks == 1        # slot 1 still pins 3 blocks
    pool.release(1, keys=keys)
    assert pool.cached_blocks == 4 and pool.evictable_blocks == 4
    pool.check()
    # pressure: 3 fresh needed, 2 truly free — eviction drops exactly one
    # cached block, and it is the chain *tail* (deepest key), so the
    # surviving prefix still matches
    pool.reserve(2, 3)
    pool.ensure(2, 6)
    assert pool.evicted_blocks == 1 and pool.cached_blocks == 3
    assert pool.match_prefix(keys) == hits[:3]
    pool.check()
    pool.invalidate()
    assert pool.cached_blocks == 0 and pool.match_prefix(keys) == []
    pool.release(2)
    pool.check()
    assert pool.free_blocks == 6


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 20), st.integers(1, 4), st.integers(2, 5),
       st.integers(0, 10 ** 6))
def test_pool_prefix_sharing_invariants(n_blocks, block_size, n_slots, seed):
    """Random share/write/publish/release/invalidate workloads against a
    small universe of prompt heads (so key collisions actually happen):
    after every event `check()` holds — no double-free, no refcount leak,
    LRU == the ref-0 keyed set — and after each write the COW contract
    holds: every block in the written window is exclusively owned and
    unkeyed (a shared or indexed block is never written in place)."""
    rng = np.random.default_rng(seed)
    bs = block_size
    max_len = n_blocks * bs
    pool = BlockPool(PagedSpec(n_blocks, bs), n_slots, max_len)
    heads = [rng.integers(0, 99, bs * int(rng.integers(1, 4))).astype(np.int32)
             for _ in range(3)]
    live = {}                                # slot -> workload state
    for _ in range(250):
        op = int(rng.integers(0, 5))
        if op == 0 and len(live) < n_slots:          # admit, matching first
            slot = next(s for s in range(n_slots) if s not in live)
            head = heads[int(rng.integers(len(heads)))]
            tail = rng.integers(0, 99,
                                int(rng.integers(1, 2 * bs))).astype(np.int32)
            toks = np.concatenate([head, tail])
            total = min(len(toks) + int(rng.integers(1, 2 * bs + 1)) - 1,
                        max_len)
            if total < len(toks):
                continue                     # prompt alone overflows a slot
            keys = chain_keys(_SEED, toks, bs)
            hits = pool.match_prefix(keys)
            cached = len(hits) * bs
            resume = min(cached, len(toks) - 1)
            extra = 1 if cached >= len(toks) else 0
            need = pool.spec.blocks_for(total)
            if not pool.can_admit(need - len(hits) + extra, hits):
                continue                     # backpressure
            pool.reserve(slot, need, hits=hits, extra_cow=extra,
                         written=resume)
            live[slot] = dict(toks=toks, total=total, keys=keys,
                              written=resume)
        elif op == 1 and live:                       # chunk/decode write
            slot = int(rng.choice(list(live)))
            w = live[slot]
            upto = int(rng.integers(w["written"], w["total"] + 1))
            w_old = w["written"]
            pool.ensure(slot, upto)
            w["written"] = max(w_old, upto)
            for src, dst in pool.drain_copies():
                assert src != dst, "COW clone onto itself"
            if upto > w_old:                 # the COW-sweep contract
                owned = pool._owned[slot]
                for i in range(w_old // bs, pool.spec.blocks_for(upto)):
                    assert pool._ref[owned[i]] == 1, \
                        "written window holds a still-shared block"
                    assert owned[i] not in pool._key_of, \
                        "written window holds an index-mapped block"
        elif op == 2 and live:                       # publish at prefill end
            slot = int(rng.choice(list(live)))
            w = live[slot]
            if w["written"] >= len(w["toks"]):
                pool.publish(slot, w["keys"])
        elif op == 3 and live:                       # retire with cache keys
            slot = int(rng.choice(list(live)))
            w = live[slot]
            full = w["written"] // bs
            gen = (np.arange(w["total"] - len(w["toks"]), dtype=np.int64)
                   + int(w["toks"].sum())) % 97
            seq = np.concatenate([w["toks"], gen.astype(np.int32)])
            pool.release(slot, keys=chain_keys(_SEED, seq[:full * bs], bs))
            del live[slot]
        elif op == 4 and int(rng.integers(8)) == 0:  # rare quarantine
            pool.invalidate()
        pool.check()
    for slot in list(live):
        pool.release(slot)
    pool.check()
    assert pool.free_blocks == n_blocks, "full release must restore the pool"
    pool.invalidate()
    assert pool.cached_blocks == 0 and len(pool._free) == n_blocks


# --- engine-level invariants -------------------------------------------------

def _dense():
    return reduced(ARCHS["smollm-360m"])


def _requests(cfg, lens, *, arrivals=None, seed=0):
    rng = np.random.default_rng(seed)
    return [E.Request(rid=rid,
                      prompt=rng.integers(0, cfg.vocab_size, pl).astype(np.int32),
                      max_new_tokens=gl,
                      arrival=0 if arrivals is None else arrivals[rid])
            for rid, (pl, gl) in enumerate(lens)]


def test_out_of_blocks_backpressure_streams_unmoved():
    """A pool too small for all requests at once queues admissions — every
    request still finishes, with exactly the roomy-pool streams."""
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    lens = [(5, 4), (6, 5), (4, 6), (7, 3)]
    # each request needs ceil((P+G-1)/4) = 2-3 blocks; 4 blocks can hold at
    # most two requests at a time even though 4 slots are configured
    tight = E.ServeEngine(cfg, params, max_slots=4, max_len=16,
                          block_size=4, n_blocks=4, prefill_chunk=4)
    fin_tight = tight.run(_requests(cfg, lens))
    assert tight.stats["peak_active_slots"] <= 2
    tight.pool.check()
    assert tight.pool.free_blocks == 4, "retired requests must free blocks"
    roomy = E.ServeEngine(cfg, params, max_slots=4, max_len=16,
                          block_size=4, prefill_chunk=4)
    fin_roomy = roomy.run(_requests(cfg, lens))
    assert sorted(fin_tight) == sorted(fin_roomy) == [0, 1, 2, 3]
    for rid in fin_roomy:
        np.testing.assert_array_equal(fin_tight[rid].tokens,
                                      fin_roomy[rid].tokens)


def test_single_request_larger_than_pool_rejected():
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    eng = E.ServeEngine(cfg, params, max_slots=1, max_len=16,
                        block_size=4, n_blocks=2)
    with pytest.raises(ValueError, match="blocks"):
        eng.run(_requests(cfg, [(8, 8)]))


@pytest.mark.parametrize("chunk", [1, 3, 8, 64])
def test_prefill_chunk_size_cannot_move_a_bit(chunk):
    """The chunked-prefill determinism contract: any chunk budget (including
    whole-prompt and token-at-a-time) yields identical streams."""
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    lens = [(5, 4), (8, 6), (3, 5)]
    eng = E.ServeEngine(cfg, params, max_slots=2, max_len=16,
                        block_size=4, prefill_chunk=chunk)
    fin = eng.run(_requests(cfg, lens))
    ref_eng = E.ServeEngine(cfg, params, max_slots=2, max_len=16, paged=False)
    ref = ref_eng.run(_requests(cfg, lens))
    for rid in ref:
        np.testing.assert_array_equal(fin[rid].tokens, ref[rid].tokens,
                                      err_msg=f"chunk={chunk} rid={rid}")


def test_engine_block_accounting_during_run():
    """Mid-run the pool's tables never alias and blocks track live slots."""
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    eng = E.ServeEngine(cfg, params, max_slots=2, max_len=16,
                        block_size=4, prefill_chunk=4)
    for r in _requests(cfg, [(5, 6)] * 5):
        eng.submit(r)
    while eng.queue or eng.active.any():
        eng.step()
        eng.pool.check()
    assert len(eng.finished) == 5
    assert eng.pool.free_blocks == eng.pool.spec.n_blocks


def test_occupancy_metrics_populated():
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    eng = E.ServeEngine(cfg, params, max_slots=2, max_len=16,
                        block_size=4, prefill_chunk=4)
    eng.run(_requests(cfg, [(5, 4), (6, 5)]))
    st = eng.stats
    assert 0 < st["slot_utilization"] <= 1
    assert 0 < st["block_utilization"] <= 1
    assert st["prefill_tokens"] == 5 + 6
    assert st["decode_tokens"] == (4 - 1) + (5 - 1)
    assert st["peak_active_slots"] == 2
    assert st["peak_allocated_blocks"] <= eng.pool.spec.n_blocks


def test_paged_capacity_exceeds_contiguous_at_fixed_budget():
    """The headline property: at one fixed KV budget, the paged engine holds
    more live requests than the contiguous engine's slot count allows."""
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    max_len, bs = 32, 4
    budget_blocks = 2 * (max_len // bs)      # contiguous budget: 2 slots
    lens = [(4, 4)] * 6                      # footprint 2 blocks each
    paged = E.ServeEngine(cfg, params, max_slots=6, max_len=max_len,
                          block_size=bs, n_blocks=budget_blocks,
                          prefill_chunk=4)
    fin_p = paged.run(_requests(cfg, lens))
    assert paged.stats["peak_active_slots"] >= 4     # >= 2x the 2 slots
    cont = E.ServeEngine(cfg, params, max_slots=2, max_len=max_len,
                         paged=False)
    fin_c = cont.run(_requests(cfg, lens))
    for rid in fin_c:
        np.testing.assert_array_equal(fin_p[rid].tokens, fin_c[rid].tokens)


def test_paged_attention_multi_kv_chunk_matches_contiguous():
    """nk > 1 paged reads (per-chunk block gathers inside the online-softmax
    scan) are bit-identical to the contiguous cache — the regime where the
    logical cache spans several attention KV chunks."""
    import jax.numpy as jnp
    from repro.models import transformer

    cfg = _dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    b, pl, max_len, bs, attn_chunk = 2, 9, 32, 4, 8     # nk = 32/8 = 4
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, pl)), jnp.int32)

    cont = transformer.init_cache(cfg, b, max_len)
    lc, cont = transformer.prefill(params, cfg, prompts, cont,
                                   attn_chunk=attn_chunk)

    n_blocks = b * (max_len // bs)
    pag = transformer.init_cache(cfg, b, max_len, paged=(n_blocks, bs))
    # identity allocation: slot i owns blocks [i*mb, (i+1)*mb)
    mb = max_len // bs
    pag["block_tables"] = jnp.arange(b * mb, dtype=jnp.int32).reshape(b, mb)
    lp, pag = transformer.prefill(params, cfg, prompts, pag,
                                  attn_chunk=attn_chunk)
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(lp))

    tok = jnp.argmax(lc[:, -1:], -1).astype(jnp.int32)
    pos = jnp.full((b,), pl, jnp.int32)
    dc, _ = transformer.decode_step(params, cfg, tok, cont, pos,
                                    attn_chunk=attn_chunk)
    dp, _ = transformer.decode_step(params, cfg, tok, pag, pos,
                                    attn_chunk=attn_chunk)
    np.testing.assert_array_equal(np.asarray(dc), np.asarray(dp))


# --- fused paged-attention kernel (kernels.paged_attention) ------------------
#
# The serving contract: with n_splits == 1 the fused kernel is bit-identical
# to the gather path (and the gather path to a contiguous cache) whenever
# both execute the same single-chunk geometry — chunk == table width * block
# size, which is what the engine's serving steps arrange. Outside that
# geometry, parity is one float32 ulp, not bitwise, for two verified
# compiler-level reasons (every individual dot/reduction IS bitwise equal
# across the paths in isolation):
#   * multiple KV chunks: the online-softmax accumulate (`l*corr + p.sum()`,
#     `acc*corr + p@v`) compiles to a fused multiply-add inside the
#     reference's lax.scan but rounds twice in op-by-op interpret Pallas;
#   * chunk > logical length: the reference zero-pads the chunk grid while
#     the kernel narrows chunk to the logical length, so the p@v reduction
#     tree associates differently (28-wide vs 32-wide sum of the same terms).
# The gather path stays the interpret-mode reference.

_ULP = dict(rtol=5e-7, atol=5e-7)   # one float32 ulp + headroom


def _assert_parity(ref, got, *, exact):
    if exact:
        np.testing.assert_array_equal(ref, got)
    else:
        np.testing.assert_allclose(ref, got, **_ULP)


def _paged_case(rng, *, b, sq, h, kh, d, width, bs, int8=False):
    """Random pool + fragmented tables + in-contract (qpos < kvl) rows."""
    import jax.numpy as jnp
    from repro.models.layers import cache_store

    n_pool = b * width + 1                   # + dump row
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pool, bs, kh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pool, bs, kh, d)), jnp.float32)
    if int8:
        kp, vp = cache_store(kp, jnp.int8), cache_store(vp, jnp.int8)
    # fragmented ownership: any permutation of the non-dump pool rows
    perm = rng.permutation(n_pool - 1)[:b * width]
    bt = jnp.asarray(perm.reshape(b, width), jnp.int32)
    skv = width * bs
    kvl = jnp.asarray(rng.integers(max(sq, 1), skv + 1, size=b), jnp.int32)
    qpos = (kvl[:, None] - sq + jnp.arange(sq)[None]).astype(jnp.int32)
    return q, kp, vp, bt, kvl, qpos


def _both_paths(case, *, chunk, n_splits=1, **kw):
    import jax.numpy as jnp
    from repro.models.layers import chunked_attention, cache_load

    q, kp, vp, bt, kvl, qpos = case
    quant = kp.dtype == jnp.int8
    ka, va = (cache_load(kp), cache_load(vp)) if quant else (kp, vp)
    ref = chunked_attention(q, ka, va, qpos, kvl, block_tables=bt,
                            chunk=chunk, **kw)
    got = chunked_attention(q, kp, vp, qpos, kvl, block_tables=bt,
                            chunk=chunk, paged_kernel=n_splits, **kw)
    return np.asarray(ref), np.asarray(got)


@pytest.mark.kernel
@pytest.mark.parametrize("bs,width", [
    (1, 24),     # single-token blocks
    (3, 8),      # non-power-of-two block size
    (8, 8),      # the engine default
    (64, 2),     # huge blocks
])
@pytest.mark.parametrize("sq", [1, 5])
def test_paged_kernel_bitwise_vs_gather(bs, width, sq):
    """chunk >= logical length (the engine regime): strictly bitwise."""
    rng = np.random.default_rng(bs * 100 + sq)
    case = _paged_case(rng, b=2, sq=sq, h=4, kh=2, d=16, width=width, bs=bs)
    ref, got = _both_paths(case, chunk=width * bs)
    np.testing.assert_array_equal(ref, got)


@pytest.mark.kernel
@pytest.mark.parametrize("bs,width,chunk", [
    (1, 24, 8),     # nk = 3
    (3, 8, 12),     # nk = 2, non-power-of-two
    (64, 2, 64),    # chunk == block, nk = 2
])
def test_paged_kernel_multichunk_vs_gather(bs, width, chunk):
    """Multiple KV chunks: one-ulp parity (FMA contraction, see above)."""
    rng = np.random.default_rng(bs)
    case = _paged_case(rng, b=2, sq=4, h=4, kh=2, d=16, width=width, bs=bs)
    ref, got = _both_paths(case, chunk=chunk)
    _assert_parity(ref, got, exact=False)


@pytest.mark.kernel
@pytest.mark.parametrize("int8", [False, True])
@pytest.mark.parametrize("softcap,window", [(0.0, 0), (30.0, 0), (0.0, 16)])
def test_paged_kernel_bitwise_variants(int8, softcap, window):
    rng = np.random.default_rng(int(softcap) + window + int8)
    case = _paged_case(rng, b=2, sq=2, h=4, kh=2, d=16, width=8, bs=8,
                       int8=int8)
    ref, got = _both_paths(case, chunk=64, softcap=softcap, window=window)
    np.testing.assert_array_equal(ref, got)


@pytest.mark.kernel
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6), bs=st.sampled_from([1, 2, 4, 8]),
       sq=st.sampled_from([1, 3]))
def test_paged_kernel_fragmented_tables_property(seed, bs, sq):
    """Any pool permutation, any block size, any in-contract length mix:
    fused == gather — bitwise on the exact single-chunk geometry, one ulp
    otherwise."""
    rng = np.random.default_rng(seed)
    width = max(1, 32 // bs)
    chunk = 8 * bs                           # multi-chunk for width*bs > chunk
    case = _paged_case(rng, b=3, sq=sq, h=4, kh=2, d=8, width=width, bs=bs)
    ref, got = _both_paths(case, chunk=chunk)
    _assert_parity(ref, got, exact=width * bs == chunk)


@pytest.mark.kernel
def test_paged_kernel_split_kv_matches_unsplit():
    """Flash-decoding (n_splits > 1) reassociates the combine: tolerance
    parity with the sequential scan, not bitwise."""
    rng = np.random.default_rng(11)
    case = _paged_case(rng, b=2, sq=1, h=4, kh=2, d=16, width=16, bs=4)
    _, seq = _both_paths(case, chunk=8, n_splits=1)
    _, split = _both_paths(case, chunk=8, n_splits=4)
    np.testing.assert_allclose(seq, split, rtol=2e-6, atol=2e-6)


# --- pad_b boundary: table widths around the chunk grid (the bugfix) --------
#
# Width < chunk/bs takes the single-upfront-gather fast path; width == hits
# the exact grid; width > pads the last chunk's table slice with the dump row.
# All three must reproduce the contiguous cache bit-for-bit, and the fused
# kernel must match them in turn.


@pytest.mark.kernel
@pytest.mark.parametrize("width", [7, 8, 9])    # nbpc = chunk//bs = 8
def test_paged_gather_pad_b_boundary_matches_contiguous(width):
    import jax.numpy as jnp
    from repro.models.layers import chunked_attention

    rng = np.random.default_rng(width)
    b, sq, h, kh, d, bs, chunk = 2, 3, 4, 2, 16, 4, 32
    case = _paged_case(rng, b=b, sq=sq, h=h, kh=kh, d=d, width=width, bs=bs)
    q, kp, vp, bt, kvl, qpos = case
    # contiguous reconstruction through the table
    kc = jnp.take(kp, bt, axis=0).reshape(b, width * bs, kh, d)
    vc = jnp.take(vp, bt, axis=0).reshape(b, width * bs, kh, d)
    cont = chunked_attention(q, kc, vc, qpos, kvl, chunk=chunk)
    gather = chunked_attention(q, kp, vp, qpos, kvl, block_tables=bt,
                               chunk=chunk)
    fused = chunked_attention(q, kp, vp, qpos, kvl, block_tables=bt,
                              chunk=chunk, paged_kernel=1)
    # gather vs contiguous: both run the scanned reference on the same chunk
    # grid — bitwise at every width, including the dump-padded last chunk
    np.testing.assert_array_equal(np.asarray(cont), np.asarray(gather))
    _assert_parity(np.asarray(gather), np.asarray(fused),
                   exact=width * bs == chunk)


# --- engine-level: the fused kernel cannot move a bit of any stream ---------


def test_paged_kernel_engine_streams_pinned():
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    lens = [(5, 6), (6, 5), (4, 7), (7, 4), (5, 5)]
    kw = dict(max_slots=3, max_len=16, block_size=4, prefill_chunk=4)
    fin_g = E.ServeEngine(cfg, params, **kw).run(_requests(cfg, lens))
    fin_k = E.ServeEngine(cfg, params, paged_kernel=1,
                          **kw).run(_requests(cfg, lens))
    assert sorted(fin_g) == sorted(fin_k)
    for rid in fin_g:
        np.testing.assert_array_equal(fin_g[rid].tokens, fin_k[rid].tokens)


def test_paged_kernel_requires_paged_cache():
    cfg = _dense()
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged"):
        E.ServeEngine(cfg, params, paged=False, paged_kernel=1)


def test_paged_kernel_hybrid_family_streams_pinned():
    """Hybrid (attention + SSM mix): the fused kernel only touches the
    attention pools; streams must still match the gather engine exactly."""
    cfg = reduced(ARCHS["zamba2-1.2b"])
    params = get_model(cfg).init_params(jax.random.PRNGKey(0))
    lens = [(4, 5), (6, 4), (5, 6)]
    kw = dict(max_slots=3, max_len=16, block_size=4, prefill_chunk=4)
    fin_g = E.ServeEngine(cfg, params, **kw).run(_requests(cfg, lens))
    fin_k = E.ServeEngine(cfg, params, paged_kernel=1,
                          **kw).run(_requests(cfg, lens))
    for rid in fin_g:
        np.testing.assert_array_equal(fin_g[rid].tokens, fin_k[rid].tokens)


@pytest.mark.slow
@pytest.mark.kernel
@pytest.mark.parametrize("bind", [False, True])
@pytest.mark.parametrize("backend", ["exact", "mxu_int8", "approx_lut",
                                     "approx_oracle", "approx_onehot",
                                     "approx_delta"])
def test_paged_kernel_all_backends_streams_pinned(backend, bind):
    """The acceptance matrix: six gemm backends x bound/unbound — the fused
    kernel matches the gather engine's streams bit-for-bit on each."""
    from repro.core import gemm

    cfg = _dense()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend=backend, k=4)
    p = model.bind_params(params, pol) if bind else params
    lens = [(5, 5), (4, 6), (6, 4)]
    kw = dict(max_slots=3, max_len=16, block_size=4, prefill_chunk=4,
              policy=pol)
    fin_g = E.ServeEngine(cfg, p, **kw).run(_requests(cfg, lens))
    fin_k = E.ServeEngine(cfg, p, paged_kernel=1,
                          **kw).run(_requests(cfg, lens))
    for rid in fin_g:
        np.testing.assert_array_equal(fin_g[rid].tokens, fin_k[rid].tokens)
