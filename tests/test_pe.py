"""Table I validation: cell truth tables, error cases, error probability."""
import itertools

import pytest

from repro.core import pe


def test_exact_ppc_is_full_adder():
    for p, s, c in itertools.product((0, 1), repeat=3):
        out = pe.exact_ppc(p, s, c)
        assert 2 * int(out.c) + int(out.s) == p + s + c


def test_exact_nppc_adds_complement():
    for p, s, c in itertools.product((0, 1), repeat=3):
        out = pe.exact_nppc(p, s, c)
        assert 2 * int(out.c) + int(out.s) == (1 - p) + s + c


# Table I approximate PPC columns (a, b, Cin, Sin) -> (C, S)
PPC_APPROX_TABLE = {
    (0, 0, 0, 0): (0, 0), (0, 0, 0, 1): (0, 1), (0, 0, 1, 0): (0, 1),
    (0, 0, 1, 1): (0, 1), (0, 1, 0, 0): (0, 0), (0, 1, 0, 1): (0, 1),
    (0, 1, 1, 0): (0, 1), (0, 1, 1, 1): (0, 1), (1, 0, 0, 0): (0, 0),
    (1, 0, 0, 1): (0, 1), (1, 0, 1, 0): (0, 1), (1, 0, 1, 1): (0, 1),
    (1, 1, 0, 0): (1, 0), (1, 1, 0, 1): (1, 0), (1, 1, 1, 0): (1, 0),
    (1, 1, 1, 1): (1, 0),
}

NPPC_APPROX_TABLE = {
    (0, 0, 0, 0): (0, 1), (0, 0, 0, 1): (1, 0), (0, 0, 1, 0): (1, 0),
    (0, 0, 1, 1): (1, 0), (0, 1, 0, 0): (0, 1), (0, 1, 0, 1): (1, 0),
    (0, 1, 1, 0): (1, 0), (0, 1, 1, 1): (1, 0), (1, 0, 0, 0): (0, 1),
    (1, 0, 0, 1): (1, 0), (1, 0, 1, 0): (1, 0), (1, 0, 1, 1): (1, 0),
    (1, 1, 0, 0): (0, 1), (1, 1, 0, 1): (0, 1), (1, 1, 1, 0): (0, 1),
    (1, 1, 1, 1): (0, 1),
}


@pytest.mark.parametrize("cell,table", [(pe.approx_ppc, PPC_APPROX_TABLE),
                                        (pe.approx_nppc, NPPC_APPROX_TABLE)])
def test_approx_cells_match_table1(cell, table):
    for (a, b, cin, sin), (want_c, want_s) in table.items():
        p = a & b
        out = cell(p, sin, cin)
        assert (int(out.c) & 1, int(out.s) & 1) == (want_c, want_s), (a, b, cin, sin)


def test_ppc_error_cases_match_paper():
    """Paper §III-B: errors exactly at (0,0,1,1),(0,1,1,1),(1,0,1,1),(1,1,0,0),(1,1,1,1)."""
    cases = pe.error_cases(pe.approx_ppc, nppc=False)
    inputs = sorted(c[0] for c in cases)
    assert inputs == sorted([(0, 0, 1, 1), (0, 1, 1, 1), (1, 0, 1, 1),
                             (1, 1, 0, 0), (1, 1, 1, 1)])
    assert len(cases) == 5  # error rate 5/16
    for _, ed in cases:
        assert ed in (-1, 1)  # Table I ED column


def test_nppc_error_rate_5_of_16():
    assert len(pe.error_cases(pe.approx_nppc, nppc=True)) == 5


@pytest.mark.parametrize("cell,nppc", [(pe.approx_ppc, False), (pe.approx_nppc, True)])
def test_error_probability_25_of_256(cell, nppc):
    num, den = pe.cell_error_probability(cell, nppc=nppc)
    assert (num, den) == (25, 256)
