"""Pipeline-parallel (pod-axis) tests: stage split/merge roundtrip, and
numerical equivalence pipeline(S stages) == sequential, incl. gradients —
run with 4 forced host devices in a subprocess (device count is process-global)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.pipeline import merge_stages, split_stages

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_stage_split_roundtrip():
    t = {"w": jnp.arange(24.0).reshape(6, 4)}
    s = split_stages(t, 3)
    assert s["w"].shape == (3, 2, 4)
    np.testing.assert_array_equal(np.asarray(merge_stages(s)["w"]),
                                  np.asarray(t["w"]))


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.pipeline import make_pipelined_apply, split_stages

    L, D, M, B = 4, 8, 3, 2
    rng = np.random.default_rng(0)
    stacked = {"w": jnp.asarray(rng.normal(0, 0.3, (L, D, D)), jnp.float32),
               "b": jnp.asarray(rng.normal(0, 0.1, (L, D)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(M, B, D)), jnp.float32)

    def layer(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    def stage_fn(sp, h):
        def body(h, lp):
            return layer(lp, h), None
        h, _ = jax.lax.scan(body, h, sp)
        return h

    # sequential reference (all L layers)
    def seq(h):
        def body(h, lp):
            return layer(lp, h), None
        h, _ = jax.lax.scan(body, h, stacked)
        return h
    want = jax.vmap(seq)(x)

    for n_stages in (2, 4):
        mesh = jax.make_mesh((n_stages,), ("pod",),
                             devices=jax.devices()[:n_stages])
        staged = split_stages(stacked, n_stages)
        apply_fn = make_pipelined_apply(stage_fn, n_stages, mesh)
        with mesh:
            got = apply_fn(staged, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

        # gradients flow through the pipeline (reverse ppermute)
        def loss(sp):
            with mesh:
                return jnp.sum(apply_fn(sp, x) ** 2)
        g = jax.grad(loss)(staged)

        def loss_seq(st):
            return jnp.sum(jax.vmap(seq)(x) ** 2) if False else None
        def loss_ref(stk):
            def seq2(h):
                def body(h, lp):
                    return layer(lp, h), None
                h, _ = jax.lax.scan(body, h, stk)
                return h
            return jnp.sum(jax.vmap(seq2)(x) ** 2)
        g_ref = jax.grad(loss_ref)(stacked)
        g_merged = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), g)
        for k in ("w", "b"):
            np.testing.assert_allclose(np.asarray(g_merged[k]),
                                       np.asarray(g_ref[k]),
                                       rtol=1e-4, atol=1e-4)
    print("PIPELINE_OK")
""")


def test_pipeline_matches_sequential_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "PIPELINE_OK" in res.stdout
