"""Prefix caching on the paged KV pool: bit-exact block sharing (PR 10).

The contract under test: with ``prefix_cache=True`` (the paged engine's
default) every request's token stream is **bit-identical** to the same trace
served with the cache off — sharing, copy-on-write, LRU eviction, preemption
replay, and quarantine recovery must all be invisible in the streams — while
the counters prove the sharing actually happened:

* shared-system-prompt traces: later admissions attach the resident prefix
  blocks and skip their prefill (``prefix_hits`` / ``prefix_tokens_skipped``);
* multi-turn: a follow-up whose prompt extends a finished conversation
  matches the *generated* blocks too (release keys cover prompt ++ output);
* whole-prompt-cached resume rewrites one position of the last attached
  block — the deterministic copy-on-write site (``prefix_cow_copies``);
* partial-block boundaries: only full blocks carry keys, tails re-prefill;
* pool pressure evicts unreferenced cached blocks (never referenced ones)
  with streams unmoved; preempted victims replay from their cached prefix;
* a cache fault quarantines AND invalidates the prefix index — a corrupted
  shared block is never re-served (the PR-10 bugfix ride-along);
* the bar holds across GEMM backends, bound params, ``multi_step`` horizons,
  and the fused paged-attention kernel; families with per-slot cache state
  outside the pool (ring buffers, SSM, xLSTM) auto-disable and still serve
  bit-identical streams.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core import gemm
from repro.launch import engine as E
from repro.launch import faults as F
from repro.launch.paged import cache_seed, chain_keys
from repro.models import get_model

CFG = reduced(ARCHS["smollm-360m"])
PARAMS = get_model(CFG).init_params(jax.random.PRNGKey(0))
SYS = np.random.default_rng(7).integers(0, CFG.vocab_size, 16).astype(np.int32)

ENGINE_KW = dict(max_slots=2, max_len=32, block_size=4, prefill_chunk=8)


def shared_reqs(n=4, head=None, tail=3, gen=5, stagger=2, seed=0):
    """n requests sharing a system-prompt head, each with a unique tail —
    staggered arrivals so early finishers publish before later admissions."""
    head = SYS if head is None else head
    out = []
    for rid in range(n):
        t = np.random.default_rng(seed * 100 + rid).integers(
            0, CFG.vocab_size, tail).astype(np.int32)
        out.append(E.Request(rid=rid, prompt=np.concatenate([head, t]),
                             max_new_tokens=gen, arrival=rid * stagger))
    return out


def run_pair(reqs_fn, policy=gemm.EXACT, params=PARAMS, cfg=CFG, **kw):
    """Serve the trace warm (prefix cache on) and cold (off); assert every
    stream bit-identical; return (finished, warm engine, cold engine)."""
    merged = dict(ENGINE_KW)
    merged.update(kw)
    warm = E.ServeEngine(cfg, params, policy=policy, prefix_cache=True,
                         **merged)
    fw = warm.run(reqs_fn())
    cold = E.ServeEngine(cfg, params, policy=policy, prefix_cache=False,
                         **merged)
    fc = cold.run(reqs_fn())
    assert set(fw) == set(fc)
    for rid in fw:
        np.testing.assert_array_equal(
            fw[rid].tokens, fc[rid].tokens,
            err_msg=f"rid={rid}: cached stream diverged from uncached")
    warm.pool.check()
    cold.pool.check()
    return fw, warm, cold


# --- key chain unit properties -----------------------------------------------

def test_chain_keys_identify_prefixes():
    seed = cache_seed(CFG, gemm.EXACT)
    toks = np.arange(16, dtype=np.int32)
    keys = chain_keys(seed, toks, 4)
    assert len(keys) == 4                    # full blocks only
    assert len(chain_keys(seed, toks[:15], 4)) == 3
    # a chain key identifies the whole prefix behind it, not just its block
    other = toks.copy()
    other[0] += 1
    assert chain_keys(seed, other, 4)[3] != keys[3]
    # equal leading tokens -> equal leading keys, diverging after
    half = np.concatenate([toks[:8], toks[8:][::-1]])
    k2 = chain_keys(seed, half, 4)
    assert k2[:2] == keys[:2] and k2[2:] != keys[2:]
    # the seed folds in cfg + policy: another backend can never match
    seed2 = cache_seed(CFG, gemm.GemmPolicy(backend="mxu_int8"))
    assert chain_keys(seed2, toks, 4)[0] != keys[0]


# --- sharing, counters, boundaries -------------------------------------------

def test_shared_system_prompt_bit_identical_with_hits():
    fw, warm, cold = run_pair(shared_reqs)
    st = warm.stats
    assert st["prefix_cache"] is True
    assert st["prefix_hits"] >= 2            # every follow-up after the first
    assert st["prefix_tokens_skipped"] >= 2 * (len(SYS) // 4) * 4 - 8
    assert st["prefix_shared_blocks"] >= st["prefix_hits"]
    # skipped prefill is visible in the occupancy split too
    assert st["prefill_tokens"] < cold.stats["prefill_tokens"]
    assert cold.stats["prefix_hits"] == 0


def test_multi_turn_reuses_generated_blocks():
    """Turn 2's prompt = turn 1's prompt ++ its output ++ new user tokens:
    the release-time key chain covers generated blocks, so the follow-up
    skips past the whole recorded conversation, not just the old prompt."""
    kw = dict(ENGINE_KW)
    turn1 = [E.Request(rid=0, prompt=SYS.copy(), max_new_tokens=6)]
    warm = E.ServeEngine(CFG, PARAMS, policy=gemm.EXACT, **kw)
    f1 = warm.run(turn1)
    convo = np.concatenate(
        [SYS, f1[0].tokens,
         np.random.default_rng(1).integers(0, CFG.vocab_size, 2)]
    ).astype(np.int32)
    f2 = warm.run([E.Request(rid=1, prompt=convo, max_new_tokens=5)])
    cold = E.ServeEngine(CFG, PARAMS, policy=gemm.EXACT, prefix_cache=False,
                         **kw)
    ref = cold.run([E.Request(rid=1, prompt=convo.copy(), max_new_tokens=5)])
    np.testing.assert_array_equal(f2[1].tokens, ref[1].tokens)
    # the hit run extends past the old prompt into generated territory
    assert warm.stats["prefix_tokens_skipped"] > len(SYS)
    warm.pool.check()


@pytest.mark.parametrize("plen", (11, 12, 13))
def test_partial_block_prefix_boundaries(plen):
    """Prompt lengths straddling a block boundary (bs=4): only full blocks
    are keyed, the tail re-prefills, and the length-aligned case resumes
    one position early through the COW path — streams unmoved in all."""
    head = SYS[:plen]

    def reqs():
        return [E.Request(rid=0, prompt=head.copy(), max_new_tokens=4,
                          arrival=0),
                E.Request(rid=1, prompt=head.copy(), max_new_tokens=4,
                          arrival=8)]

    _, warm, _ = run_pair(reqs)
    st = warm.stats
    assert st["prefix_hits"] == 1
    assert st["prefix_tokens_skipped"] == min(plen - plen % 4, plen - 1)
    # the publisher already retired, so the whole-cached resume rewrites an
    # exclusively-held block: the pool *detaches* it from the index instead
    # of cloning (COW is for live sharers — see the concurrent test)
    assert st["prefix_cow_copies"] == 0


def test_concurrent_share_cow_while_publisher_live():
    """The second request admits while the first is still generating: it
    attaches blocks published at prefill completion (refcount 2), so its
    boundary rewrite must clone, never touch the shared block."""

    def reqs():
        return [E.Request(rid=0, prompt=SYS.copy(), max_new_tokens=8,
                          arrival=0),
                E.Request(rid=1, prompt=SYS.copy(), max_new_tokens=8,
                          arrival=6)]

    _, warm, _ = run_pair(reqs)
    assert warm.stats["prefix_hits"] == 1
    assert warm.stats["prefix_cow_copies"] >= 1


def test_eviction_under_pressure_streams_unmoved():
    """Distinct-prefix churn through a pool too small to cache everything:
    unreferenced cached blocks are evicted (referenced ones never — the
    allocator asserts), admission never deadlocks on cached residue, and
    every stream still matches the uncached run."""
    def reqs():
        return [E.Request(rid=rid,
                          prompt=np.random.default_rng(50 + rid).integers(
                              0, CFG.vocab_size, 8).astype(np.int32),
                          max_new_tokens=4)
                for rid in range(6)]

    _, warm, _ = run_pair(reqs, n_blocks=8)
    assert warm.stats["prefix_evicted_blocks"] > 0
    assert warm.stats["prefix_hits"] == 0    # all prefixes distinct


def test_preempted_request_replays_from_cached_prefix():
    """A preempted victim's blocks stay in the index: its re-admission
    attaches them and resumes instead of re-prefilling from scratch,
    with the replayed stream bit-identical to an undisturbed run."""
    kw = dict(max_slots=2, max_len=16, block_size=4, n_blocks=6,
              prefill_chunk=8)
    low = E.Request(rid=0, prompt=SYS[:8].copy(), max_new_tokens=8,
                    priority=0, arrival=0)
    high = E.Request(rid=1, prompt=SYS[4:12].copy(), max_new_tokens=8,
                     priority=5, arrival=4)
    warm = E.ServeEngine(CFG, PARAMS, policy=gemm.EXACT, **kw)
    fin = warm.run([dataclasses.replace(low), dataclasses.replace(high)])
    assert fin[0].preemptions >= 1
    assert warm.events["preemptions"] >= 1
    assert warm.stats["prefix_hits"] >= 1    # the replay resumed from cache
    ref = E.ServeEngine(CFG, PARAMS, policy=gemm.EXACT, prefix_cache=False,
                        **kw).run([dataclasses.replace(low),
                                   dataclasses.replace(high)])
    for rid in fin:
        np.testing.assert_array_equal(fin[rid].tokens, ref[rid].tokens,
                                      err_msg=f"rid={rid}")
    warm.pool.check()


def test_prefix_cache_off_flag():
    eng = E.ServeEngine(CFG, PARAMS, policy=gemm.EXACT, prefix_cache=False,
                        **ENGINE_KW)
    assert eng.prefix_cache is False
    eng.run(shared_reqs())
    st = eng.stats
    assert st["prefix_cache"] is False
    assert st["prefix_hits"] == 0 and st["prefix_shared_blocks"] == 0
    assert st["prefix_cached_blocks"] == 0
    # the contiguous engine has no pool at all: flag is inert, not an error
    contig = E.ServeEngine(CFG, PARAMS, policy=gemm.EXACT, paged=False,
                           max_slots=2, max_len=32)
    assert contig.prefix_cache is False


# --- dispatch-path matrix: multi_step, kernel, backends, families ------------

def test_prefix_cache_multi_step_horizons():
    """Fused decode horizons over attached prefixes: ensure_horizon clamps
    to the reservation and never sweeps the shared blocks (horizons only
    run once prefill — including the resumed tail — is complete)."""
    _, warm, _ = run_pair(shared_reqs, multi_step=4)
    assert warm.stats["prefix_hits"] >= 2


@pytest.mark.kernel
def test_prefix_cache_paged_kernel():
    """Fused paged-attention kernel reading through shared block tables."""
    _, warm, _ = run_pair(shared_reqs, paged_kernel=1)
    assert warm.stats["prefix_hits"] >= 2


@pytest.mark.parametrize("backend", ("exact", "mxu_int8", "approx_delta",
                                     "approx_lut", "approx_onehot"))
def test_prefix_cache_backends_bit_identical(backend):
    """Cached == uncached streams for every GEMM backend on dense, served
    weight-stationary (bound params) as in production. The chain seed folds
    the policy in, so backends can never share each other's blocks."""
    pol = gemm.GemmPolicy(backend=backend, k=4)
    p = (get_model(CFG).bind_params(PARAMS, pol)
         if backend != "exact" else PARAMS)
    short = backend in ("approx_lut", "approx_onehot")
    n, gen = (3, 3) if short else (4, 5)
    _, warm, _ = run_pair(lambda: shared_reqs(n=n, gen=gen), policy=pol,
                          params=p)
    assert warm.stats["prefix_hits"] >= 1


def test_prefix_cache_backend_oracle_bit_identical():
    """The bit-level oracle backend, tiny config (it is interpret-slow)."""
    cfg = dataclasses.replace(CFG, n_layers=1, vocab_size=64)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pol = gemm.GemmPolicy(backend="approx_oracle", k=4)
    p = model.bind_params(params, pol)
    head = np.random.default_rng(7).integers(0, 64, 6).astype(np.int32)

    def reqs():
        return [E.Request(rid=0, prompt=head.copy(), max_new_tokens=2,
                          arrival=0),
                E.Request(rid=1, prompt=head.copy(), max_new_tokens=2,
                          arrival=5)]

    _, warm, _ = run_pair(reqs, policy=pol, params=p, cfg=cfg, max_slots=2,
                          max_len=12, block_size=2, prefill_chunk=2)
    assert warm.stats["prefix_hits"] == 1


# families: pool-pure caches share; per-slot-state families auto-disable —
# either way the streams must not move
FAMILY_EXPECT = (("qwen3-moe-30b-a3b", True), ("pixtral-12b", True),
                 ("zamba2-1.2b", False), ("xlstm-350m", False),
                 ("gemma3-12b", False))


@pytest.mark.parametrize("arch,expect_on", FAMILY_EXPECT)
@pytest.mark.parametrize("mode", ("exact", "delta_bound"))
def test_prefix_cache_families(arch, mode, expect_on):
    cfg = reduced(ARCHS[arch])
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    if mode == "exact":
        pol, p = gemm.EXACT, params
    else:
        pol = gemm.GemmPolicy(backend="approx_delta", k=4)
        p = model.bind_params(params, pol)
    head = np.random.default_rng(9).integers(0, cfg.vocab_size, 6).astype(
        np.int32)

    def reqs():
        out = []
        for rid in range(3):
            t = np.random.default_rng(200 + rid).integers(
                0, cfg.vocab_size, 2).astype(np.int32)
            out.append(E.Request(rid=rid, prompt=np.concatenate([head, t]),
                                 max_new_tokens=3, arrival=rid * 2))
        return out

    _, warm, _ = run_pair(reqs, policy=pol, params=p, cfg=cfg, max_slots=2,
                          max_len=24, block_size=4, prefill_chunk=4)
    assert warm.prefix_cache is expect_on
    if expect_on:
        assert warm.stats["prefix_hits"] >= 1
    else:
        assert warm.stats["prefix_hits"] == 0


def test_vlm_embeds_request_skips_cache_per_request():
    """A VLM request carrying patch embeds has prompt content the token key
    chain cannot identify — it must neither publish nor match, while pure
    token requests on the same engine still share."""
    cfg = reduced(ARCHS["pixtral-12b"])
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    head = np.random.default_rng(9).integers(0, cfg.vocab_size, 8).astype(
        np.int32)
    embeds = np.random.default_rng(10).normal(
        size=(2, cfg.d_model)).astype(np.float32)

    def reqs():
        return [E.Request(rid=0, prompt=head.copy(), max_new_tokens=3,
                          arrival=0),
                E.Request(rid=1, prompt=head.copy(), max_new_tokens=3,
                          arrival=4, input_embeds=embeds.copy()),
                E.Request(rid=2, prompt=head.copy(), max_new_tokens=3,
                          arrival=8)]

    _, warm, _ = run_pair(reqs, cfg=cfg, params=params, max_slots=2,
                          max_len=24, block_size=4, prefill_chunk=4)
    # rid=2 hits rid=0's published prefix; rid=1 (embeds) never matches
    assert warm.stats["prefix_hits"] == 1


# --- quarantine: the bugfix ride-along ---------------------------------------

@pytest.mark.faultinject
def test_quarantine_invalidates_prefix_index():
    """A cache fault must drop the prefix index before recovery: a later
    same-prompt request re-prefills cold (zero hits) instead of attaching
    the corrupted shared block — and its stream is still bit-identical."""
    pol = gemm.GemmPolicy(backend="exact", guard="detect")
    eng = E.ServeEngine(CFG, PARAMS, policy=pol, **ENGINE_KW)
    prompt = SYS.copy()                      # 12 tokens = 3 full blocks
    eng.run([E.Request(rid=0, prompt=prompt, max_new_tokens=4)])
    assert eng.stats["prefix_cached_blocks"] >= 3
    # corrupt one *cached* (index-mapped) pool block, bit-for-bit targeted
    blk = next(iter(eng.pool._index.values()))
    inj = F.FaultInjector(3)
    eng.cache, rec = inj.flip_cache_block(eng.cache, int(blk))
    assert rec.note == f"block={int(blk)}"
    fin = eng.run([E.Request(rid=1, prompt=prompt.copy(), max_new_tokens=4)])
    assert eng.events["quarantines"] == 1
    assert eng.stats["prefix_invalidations"] == 1
    assert eng.stats["prefix_hits"] == 0     # replay was cold, never served
    assert eng.stats["prefix_cached_blocks"] >= 3   # rebuilt cache re-indexed
    ref = E.ServeEngine(CFG, PARAMS, policy=gemm.EXACT, prefix_cache=False,
                        **ENGINE_KW).run(
        [E.Request(rid=1, prompt=prompt.copy(), max_new_tokens=4)])
    np.testing.assert_array_equal(fin[1].tokens, ref[1].tokens)
    eng.pool.check()
