"""Property tests for the weight-stationary prepared-operand path and the
pad-and-batch shim (hypothesis, with the PR-1 deterministic fallback).

Invariants:
* ``prepare_delta`` + ``delta_matmul_prepared`` is bit-identical to the
  unprepared ``approx_delta`` path (kernel wrapper and jnp reference) for
  random shapes, ranks, and signedness, on both operand sides.
* The pad-and-batch shim round-trips batched ``(L, M, K) x (K, N)`` and
  ``(M, K) x (L, K, N)`` workloads (non-multiple-of-8 shapes included)
  against a per-item 2D loop.
* ``gemm.dot`` rejects stale/mis-sided prepared operands.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import error_delta, gemm, lut
from repro.kernels import ops


def _rand(shape, rng, lo=-128, hi=128):
    return jnp.asarray(rng.integers(lo, hi, shape), jnp.int32)


# --- prepared == unprepared (property) --------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(1, 32), st.integers(1, 32), st.integers(1, 32),
       st.integers(0, 7), st.integers(0, 1))
def test_property_prepared_matches_unprepared(m, kd, n, kf, signed):
    signed = bool(signed)
    rng = np.random.default_rng(m * 7919 + kd * 131 + n * 17 + kf * 3 + signed)
    lo, hi = (-128, 128) if signed else (0, 256)
    a, b = _rand((m, kd), rng, lo, hi), _rand((kd, n), rng, lo, hi)
    want = np.asarray(error_delta.delta_matmul_ref(a, b, k=kf, signed=signed))
    prep_r = error_delta.prepare_delta(b, side="right", k=kf, signed=signed)
    np.testing.assert_array_equal(
        np.asarray(error_delta.delta_matmul_prepared(a, prep_r)), want)
    prep_l = error_delta.prepare_delta(a, side="left", k=kf, signed=signed)
    np.testing.assert_array_equal(
        np.asarray(error_delta.delta_matmul_prepared(b, prep_l)), want)
    # and the unprepared path is itself the gather-table ground truth
    np.testing.assert_array_equal(
        want, np.asarray(lut.lut_matmul(a, b, k=kf, signed=signed)))


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 24), st.integers(1, 24), st.integers(1, 24),
       st.integers(2, 7), st.integers(0, 10))
def test_property_prepared_truncated_rank_stays_exact(m, kd, n, kf, rank):
    """apply_residual restores bit-exactness at any correction rank."""
    rng = np.random.default_rng(m * 311 + kd * 73 + n * 11 + kf + rank)
    a, b = _rand((m, kd), rng), _rand((kd, n), rng)
    want = np.asarray(lut.lut_matmul(a, b, k=kf))
    prep = error_delta.prepare_delta(b, side="right", k=kf, rank=rank)
    np.testing.assert_array_equal(
        np.asarray(error_delta.delta_matmul_prepared(a, prep)), want)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 20), st.integers(1, 20), st.integers(1, 20),
       st.integers(0, 7))
def test_property_ops_prepared_matmul_matches_kernel(m, kd, n, kf):
    """The ops-level PreparedOperand dispatch equals the Pallas kernel path."""
    rng = np.random.default_rng(m * 101 + kd * 37 + n * 13 + kf)
    a, b = _rand((m, kd), rng), _rand((kd, n), rng)
    want = np.asarray(ops.approx_delta_matmul(a, b, k=kf))
    prep = ops.prepare_operand(b, backend="approx_delta", k=kf)
    np.testing.assert_array_equal(np.asarray(ops.prepared_matmul(a, prep)),
                                  want)


# --- pad-and-batch shim (property) ------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(1, 12), st.integers(1, 12),
       st.integers(1, 12), st.integers(0, 7))
def test_property_shim_batched_right_roundtrip(batch, m, kd, n, kf):
    rng = np.random.default_rng(batch * 997 + m * 89 + kd * 23 + n * 7 + kf)
    a = _rand((batch, m, kd), rng)
    b = _rand((kd, n), rng)
    pol = gemm.GemmPolicy(backend="approx_delta", k=kf)
    prep = gemm.prepare_weights(b, pol)
    for out in (gemm.dot(a, b, pol), gemm.dot(a, prep, pol)):
        out = np.asarray(out)
        assert out.shape == (batch, m, n)
        for i in range(batch):
            np.testing.assert_array_equal(
                out[i], np.asarray(lut.lut_matmul(a[i], b, k=kf)))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(1, 12), st.integers(1, 12),
       st.integers(1, 12), st.integers(0, 7))
def test_property_shim_batched_left_roundtrip(batch, m, kd, n, kf):
    """Fixed left operand (the DCT-matrix case): batch flattened into columns,
    operand order preserved (the product table is not symmetric)."""
    rng = np.random.default_rng(batch * 499 + m * 83 + kd * 29 + n * 5 + kf)
    a = _rand((m, kd), rng)
    b = _rand((batch, kd, n), rng)
    pol = gemm.GemmPolicy(backend="approx_delta", k=kf)
    prep = gemm.prepare_weights(a, pol, side="left")
    for out in (gemm.dot(a, b, pol), gemm.dot(prep, b, pol)):
        out = np.asarray(out)
        assert out.shape == (batch, m, n)
        for i in range(batch):
            np.testing.assert_array_equal(
                out[i], np.asarray(lut.lut_matmul(a, b[i], k=kf)))


def test_shim_multi_lead_dims_and_lut_backend():
    rng = np.random.default_rng(0)
    a = _rand((2, 3, 5, 7), rng)                    # lead dims (2, 3)
    b = _rand((7, 4), rng)
    pol = gemm.GemmPolicy(backend="approx_lut", k=4)
    out = np.asarray(gemm.dot(a, b, pol))
    assert out.shape == (2, 3, 5, 4)
    np.testing.assert_array_equal(
        out[1, 2], np.asarray(lut.lut_matmul(a[1, 2], b, k=4)))


def test_shim_rejects_double_batch():
    rng = np.random.default_rng(1)
    with pytest.raises(ValueError, match="batched"):
        ops.batched_app_matmul(jnp.matmul, _rand((2, 3, 4), rng),
                               _rand((2, 4, 5), rng))


# --- guard rails ------------------------------------------------------------

def test_dot_rejects_stale_prepared():
    rng = np.random.default_rng(2)
    a, b = _rand((6, 8), rng), _rand((8, 4), rng)
    prep = gemm.prepare_weights(b, gemm.GemmPolicy(backend="approx_delta", k=4))
    with pytest.raises(ValueError, match="stale"):
        gemm.dot(a, prep, gemm.GemmPolicy(backend="approx_delta", k=6))
    with pytest.raises(ValueError, match="stale"):
        gemm.dot(a, prep, gemm.GemmPolicy(backend="approx_lut", k=4))
    with pytest.raises(ValueError, match="stale"):
        gemm.dot(a, prep, gemm.GemmPolicy(backend="approx_delta", k=4,
                                     delta_rank=3))


def test_dot_rejects_wrong_side():
    rng = np.random.default_rng(3)
    a, b = _rand((6, 8), rng), _rand((8, 4), rng)
    pol = gemm.GemmPolicy(backend="approx_delta", k=4)
    prep = gemm.prepare_weights(b, pol)                      # side="right"
    with pytest.raises(ValueError, match="side"):
        gemm.dot(prep, b, pol)
    with pytest.raises(ValueError, match="prepared"):
        gemm.dot(prep, prep, pol)


def test_prepare_weights_resolves_layer_overrides():
    pol = gemm.GemmPolicy(backend="approx_delta", k=4,
                          overrides={"tail": "exact"})
    rng = np.random.default_rng(4)
    b = _rand((8, 4), rng)
    assert gemm.prepare_weights(b, pol, layer="head").backend == "approx_delta"
    assert gemm.prepare_weights(b, pol, layer="tail").backend == "exact"


def test_prepared_onehot_caches_t_b():
    rng = np.random.default_rng(5)
    a, b = _rand((10, 6), rng), _rand((6, 4), rng)
    prep = ops.prepare_operand(b, backend="approx_onehot", k=4)
    assert prep.t_b is not None and prep.t_b.shape == (6 * 256, 4)
    np.testing.assert_array_equal(np.asarray(ops.prepared_matmul(a, prep)),
                                  np.asarray(lut.lut_matmul(a, b, k=4)))


# --- adaptive correction-form selection (ROADMAP DCT-k=6 item) ---------------

def test_adaptive_delta_picks_gather_when_rank_exceeds_width():
    """When the weight-restricted rank r' exceeds the output width, the
    adaptive policy prepares the (bit-identical) approx_lut gather path; a
    wide output keeps the rank-r' correction matmuls."""
    from repro.apps.dct import T8
    pol = gemm.GemmPolicy(backend="approx_delta", k=6, delta_adaptive=True)
    r_eff = error_delta.restricted_rank(T8, side="left", k=6)
    assert r_eff > T8.shape[0], "the DCT k=6 regime: r' > 8-wide output"
    prep = gemm.prepare_weights(T8, pol, layer="dct.fwd", side="left")
    assert prep.backend == "approx_lut"
    rng = np.random.default_rng(6)
    wide = _rand((16, 256), rng, -100, 100)
    assert gemm.prepare_weights(wide, pol, layer="w").backend == "approx_delta"


def test_adaptive_delta_bitwise_parity_both_forms():
    """dot() through an adaptive policy == non-adaptive approx_delta ==
    approx_lut, bit for bit, on both sides of the width threshold."""
    from repro.apps.dct import T8
    rng = np.random.default_rng(7)
    x = _rand((8, 24), rng, -100, 100)
    pol_a = gemm.GemmPolicy(backend="approx_delta", k=6, delta_adaptive=True)
    pol_d = gemm.GemmPolicy(backend="approx_delta", k=6)
    pol_l = gemm.GemmPolicy(backend="approx_lut", k=6)
    outs = []
    for pol in (pol_a, pol_d, pol_l):
        prep = gemm.prepare_weights(T8, pol, layer="dct.fwd", side="left")
        outs.append(np.asarray(gemm.dot(prep, x, pol, layer="dct.fwd")))
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
    # wide-output layer: adaptive keeps the delta form, still bit-identical
    w = _rand((24, 128), rng)
    a2 = _rand((5, 24), rng)
    prep_a = gemm.prepare_weights(w, pol_a, layer="w")
    prep_d = gemm.prepare_weights(w, pol_d, layer="w")
    assert prep_a.backend == "approx_delta"
    np.testing.assert_array_equal(
        np.asarray(gemm.dot(a2, prep_a, pol_a, layer="w")),
        np.asarray(gemm.dot(a2, prep_d, pol_d, layer="w")))


def test_adaptive_delta_resolve_hints():
    pol = gemm.GemmPolicy(backend="approx_delta", k=6, delta_adaptive=True)
    assert pol.resolve("x") == "approx_delta"            # no hints: unchanged
    assert pol.resolve("x", out_width=8, delta_rank=11) == "approx_lut"
    assert pol.resolve("x", out_width=16, delta_rank=11) == "approx_delta"
    off = gemm.GemmPolicy(backend="approx_delta", k=6)
    assert off.resolve("x", out_width=8, delta_rank=11) == "approx_delta"


def test_adaptive_delta_truncated_rank_keeps_delta_form():
    """A truncated delta_rank/delta_tol correction is deliberately
    approximate — adaptive selection must not swap it for the exact gather
    path even when the restricted rank exceeds the output width."""
    from repro.apps.dct import T8
    pol = gemm.GemmPolicy(backend="approx_delta", k=6, delta_adaptive=True,
                          delta_rank=3)
    prep = gemm.prepare_weights(T8, pol, layer="dct.fwd", side="left")
    assert prep.backend == "approx_delta" and prep.rank == 3
    pol_t = gemm.GemmPolicy(backend="approx_delta", k=6, delta_adaptive=True,
                            delta_tol=4.0)
    prep_t = gemm.prepare_weights(T8, pol_t, layer="dct.fwd", side="left")
    assert prep_t.backend == "approx_delta"
