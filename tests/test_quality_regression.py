"""Paper-anchored quality-regression tier (Table VI operating points).

The paper's headline application results — 38.21 dB PSNR for k=4 DCT
compression and 30.45 dB for k=2 kernel-based edge detection — are asserted
as *floors* on this repro's synthetic test image (the paper's standard test
images cannot ship in the offline container; the synthetic composite measures
consistently above the paper's numbers, so the paper values act as the
regression floor, with a small tolerance for numeric drift).

Two sub-tiers:
* fast (tier-1): small-size floors, run on every push.
* ``slow``: the full-size (256 px) floors at every paper k, run by the
  scheduled/manual CI quality job (``pytest -m slow``).

Any change that degrades the approximate arithmetic (product table, delta
factors, policy routing, quantization) below the paper's operating points
fails here.
"""
import pytest

from repro.apps import bdcn, dct, edge

# Table VI, signed 8-bit PE: k -> PSNR dB (paper's pretrained-BDCN numbers are
# not reachable by the compact seeded re-implementation; its floors below are
# pinned from this repro instead and guard against regressions).
PAPER_DCT_PSNR = {2: 45.97, 4: 38.21, 6: 35.67, 8: 28.43}
PAPER_EDGE_PSNR = {2: 30.45, 4: 20.51}
TOL_DB = 0.5

FULL_SIZE = 256
FAST_SIZE = 64

# Backends that must all clear the paper floors (bit-identical to each other
# by the parity tier; asserted independently so a routing bug in either path
# cannot hide).
BACKENDS = ("approx_lut", "approx_delta")


# --- fast small-size floors (tier-1) ----------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_dct_fast_floor_paper_k4(backend):
    res = dct.run(size=FAST_SIZE, ks=(4,), policy=backend)
    assert res[4]["psnr"] >= PAPER_DCT_PSNR[4] - TOL_DB


@pytest.mark.parametrize("backend", BACKENDS)
def test_edge_fast_floor_paper_k2(backend):
    res = edge.run(size=FAST_SIZE, ks=(2,), policy=backend)
    assert res[2]["psnr"] >= PAPER_EDGE_PSNR[2] - TOL_DB


def test_bdcn_fast_floor():
    # repro-pinned floor (measured 62.7 dB at k=2, 64 px) with headroom
    res = bdcn.run(size=FAST_SIZE, ks=(2,), policy="approx_delta")
    assert res[2]["psnr"] >= 55.0
    assert res[2]["ssim"] >= 0.995


# --- full-size floors at the paper's operating points (slow tier) -----------

@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_dct_full_size_meets_paper_floors(backend):
    res = dct.run(size=FULL_SIZE, ks=tuple(PAPER_DCT_PSNR), policy=backend)
    for k, floor in PAPER_DCT_PSNR.items():
        assert res[k]["psnr"] >= floor - TOL_DB, (k, res[k])
    # quality must degrade monotonically with deeper approximation
    psnrs = [res[k]["psnr"] for k in sorted(PAPER_DCT_PSNR)]
    assert psnrs == sorted(psnrs, reverse=True)


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_edge_full_size_meets_paper_floors(backend):
    # k=2 is the paper's headline (30.45 dB); k>=6 measures *below* the paper
    # on the synthetic image (hard edges penalize deep approximation more than
    # the paper's photos), so only k<=4 carries a paper-anchored floor.
    res = edge.run(size=FULL_SIZE, ks=tuple(PAPER_EDGE_PSNR), policy=backend)
    for k, floor in PAPER_EDGE_PSNR.items():
        assert res[k]["psnr"] >= floor - TOL_DB, (k, res[k])
    assert res[2]["psnr"] > res[4]["psnr"]


@pytest.mark.slow
def test_bdcn_full_size_hybrid_floors():
    # repro-pinned floors (compact net; paper's 75.98 dB needs the pretrained
    # BDCN) + the paper's key claim at full app scale: the hybrid CNN
    # tolerates approximation far better than the kernel-based detector.
    res = bdcn.run(size=FAST_SIZE, ks=(2, 6), policy="approx_delta")
    assert res[2]["psnr"] >= 55.0
    assert res[2]["psnr"] > res[6]["psnr"]
    e = edge.run(size=FULL_SIZE, ks=(6,), policy="approx_delta")
    assert res[6]["psnr"] > e[6]["psnr"] + 10.0


@pytest.mark.slow
def test_dct_oracle_backend_tracks_table_model_full_size():
    """The fused-MAC oracle (accumulator error included) stays within 3 dB of
    the multiplier-only table model at the paper's k — the approximation error
    is dominated by the multiplier, as the paper's LUT methodology assumes."""
    table = dct.run(size=FULL_SIZE, ks=(4,), policy="approx_lut")
    oracle = dct.run(size=FULL_SIZE, ks=(4,), policy="approx_oracle")
    assert abs(table[4]["psnr"] - oracle[4]["psnr"]) < 3.0
