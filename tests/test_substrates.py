"""Substrate tests: quantization, GEMM policies, optimizer, grad compression,
checkpointing (incl. elastic restore + corruption detection), data pipeline
determinism, fault handling."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see _hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.configs import ARCHS
from repro.core import gemm, quant
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw, grad_compress, schedule
from repro.train import fault


# --- quantization -----------------------------------------------------------

def test_quant_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, (64, 32)), jnp.float32)
    q = quant.quantize(x)
    back = quant.dequantize(q)
    assert float(jnp.abs(back - x).max()) <= float(q.scale) * 0.5 + 1e-6


def test_fake_quant_gradients_pass_through():
    x = jnp.linspace(-2, 2, 32)
    g = jax.grad(lambda z: jnp.sum(quant.fake_quant(z) ** 2))(x)
    assert jnp.all(jnp.isfinite(g))
    assert float(jnp.abs(g).sum()) > 0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8))
def test_property_quant_levels(n_bits):
    rng = np.random.default_rng(n_bits)
    x = jnp.asarray(rng.normal(size=(100,)), jnp.float32)
    q = quant.quantize(x, n_bits=n_bits)
    qmax = (1 << (n_bits - 1)) - 1
    assert int(jnp.abs(q.values).max()) <= qmax


# --- gemm policy routing ----------------------------------------------------

def test_policy_overrides_longest_prefix():
    p = gemm.GemmPolicy(backend="approx_lut",
                        overrides={"block0": "approx_lut",
                                   "block0/conv1": "exact"})
    assert p.resolve("block0/conv2") == "approx_lut"
    assert p.resolve("block0/conv1/w") == "exact"
    assert p.resolve("other") == "approx_lut"


@pytest.mark.parametrize("backend", ["mxu_int8", "approx_lut", "approx_oracle",
                                     "approx_onehot", "approx_delta"])
def test_dot_backends_close_to_float(backend):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 8)), jnp.float32)
    pol = gemm.GemmPolicy(backend=backend, k=2)
    out = gemm.dot(x, w, pol)
    ref = x @ w
    rel = float(jnp.abs(out - ref).mean() / jnp.abs(ref).mean())
    assert rel < 0.08, (backend, rel)


def test_dot_exact_k0_matches_int_quant():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(6, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 6)), jnp.float32)
    lut0 = gemm.dot(x, w, gemm.GemmPolicy(backend="approx_lut", k=0))
    mxu = gemm.dot(x, w, gemm.GemmPolicy(backend="mxu_int8"))
    np.testing.assert_allclose(np.asarray(lut0), np.asarray(mxu), atol=1e-5)


def test_dot_float_rows_are_batch_independent():
    """Per-row activation quantization: a row's output bits don't depend on
    what else shares the batch (the serve-engine ragged-batch invariant)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(5, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 6)), jnp.float32)
    pol = gemm.GemmPolicy(backend="mxu_int8")
    full = np.asarray(gemm.dot(x, w, pol))
    for i in range(x.shape[0]):
        alone = np.asarray(gemm.dot(x[i:i + 1], w, pol))
        np.testing.assert_array_equal(full[i:i + 1], alone)


# --- optimizer / schedule ---------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = adamw.update(grads, state, params, lr=0.05,
                                     weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_schedule_shape():
    s0 = schedule.warmup_cosine(0, peak_lr=1.0, warmup_steps=10, total_steps=100)
    s10 = schedule.warmup_cosine(10, peak_lr=1.0, warmup_steps=10, total_steps=100)
    s100 = schedule.warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                                  total_steps=100)
    assert float(s0) == 0.0
    assert float(s10) == pytest.approx(1.0)
    assert float(s100) == pytest.approx(0.1, abs=1e-6)


# --- gradient compression ---------------------------------------------------

def test_grad_compress_error_feedback_unbiased():
    rng = np.random.default_rng(3)
    g_true = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.float32)}
    err = grad_compress.init_error_state(g_true)
    total_q = jnp.zeros((256,))
    n = 50
    for _ in range(n):
        payload, scales, err = grad_compress.compress(g_true, err)
        total_q = total_q + grad_compress.decompress(payload, scales)["w"]
    # error feedback: the long-run mean of decompressed grads converges
    np.testing.assert_allclose(np.asarray(total_q / n), np.asarray(g_true["w"]),
                               atol=2e-3)


def test_grad_compress_payload_is_int8():
    g = {"w": jnp.asarray([0.5, -1.0, 3.0])}
    payload, scales, _ = grad_compress.compress(g, grad_compress.init_error_state(g))
    assert payload["w"].dtype == jnp.int8


# --- checkpointing ----------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def test_ckpt_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(7, t, str(tmp_path))
    out = ckpt.restore(str(tmp_path), t)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(t["a"]))
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_ckpt_retention_and_resume_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(s, t, str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [4, 5]


def test_ckpt_detects_corruption(tmp_path):
    t = _tree()
    path = ckpt.save(3, t, str(tmp_path))
    # corrupt the payload
    payload = os.path.join(path, "payload.npz")
    data = dict(np.load(payload))
    data["a0"] = data["a0"] + 1
    np.savez(payload, **data)
    with pytest.raises(IOError):
        ckpt.restore(str(tmp_path), t)


def test_ckpt_async(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save_async(11, _tree())
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 11


def test_ckpt_elastic_reshard_device_put(tmp_path):
    """Restore onto explicit shardings (the elastic path on a real mesh)."""
    t = _tree()
    ckpt.save(1, t, str(tmp_path))
    dev = jax.devices()[0]
    shardings = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    out = ckpt.restore(str(tmp_path), t, shardings=shardings)
    assert out["a"].devices() == {dev}


# --- data pipeline ----------------------------------------------------------

def test_data_deterministic_and_shardable():
    cfg = ARCHS["smollm-360m"]
    shape = cfg.shape("train_4k")
    import dataclasses
    shape = dataclasses.replace(shape, seq_len=32, global_batch=8)
    a = SyntheticLM(cfg, shape, DataConfig(seed=1)).batch(5)
    b = SyntheticLM(cfg, shape, DataConfig(seed=1)).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts each produce half the batch; host streams differ
    h0 = SyntheticLM(cfg, shape, DataConfig(seed=1, host_id=0, n_hosts=2)).batch(5)
    h1 = SyntheticLM(cfg, shape, DataConfig(seed=1, host_id=1, n_hosts=2)).batch(5)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_micro_reshape():
    cfg = ARCHS["smollm-360m"]
    import dataclasses
    shape = dataclasses.replace(cfg.shape("train_4k"), seq_len=16,
                                global_batch=8)
    b = SyntheticLM(cfg, shape, DataConfig(n_micro=4)).batch(0)
    assert b["tokens"].shape == (4, 2, 16)


# --- fault tolerance --------------------------------------------------------

def test_straggler_watchdog_flags_slow_step():
    wd = fault.StragglerWatchdog(warmup_steps=2)
    flagged = [wd.observe(i, 1.0) for i in range(8)]
    assert not any(flagged)
    assert wd.observe(9, 5.0) is True
    assert wd.observe(10, 1.0) is False


def test_retries_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise fault.TransientError("flake")
        return "ok"

    assert fault.run_with_retries(flaky, backoff_s=0.0) == "ok"
    assert calls["n"] == 3


def test_retries_exhausted_raises():
    def always_fails():
        raise fault.TransientError("dead")

    with pytest.raises(fault.TransientError):
        fault.run_with_retries(always_fails, max_retries=2, backoff_s=0.0)


def test_ckpt_bfloat16_roundtrip(tmp_path):
    import ml_dtypes
    t = {"w": jnp.asarray(np.linspace(-2, 2, 16), jnp.bfloat16)}
    ckpt.save(1, t, str(tmp_path))
    out = ckpt.restore(str(tmp_path), t)
    assert np.asarray(out["w"]).dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(t["w"], np.float32))
