"""Two-tier windowed KV cache (§Perf cell-C optimization): decode through ring
buffers must match decode through the uniform full cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import get_model, transformer


def _gemma_like(f32: bool = False):
    cfg = reduced(ARCHS["gemma3-12b"])
    # reduced(): window 8, global_every 2, 4 layers, d=64
    if f32:
        cfg = dataclasses.replace(cfg, dtype="float32")
    return cfg


def _decode_seq(cfg, cache, params, prompts, n_gen, dtype=jnp.bfloat16):
    model = get_model(cfg)
    logits, cache = transformer.prefill(params, cfg, prompts, cache)
    outs = [logits]
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    pos = prompts.shape[1]
    for i in range(n_gen):
        logits, cache = transformer.decode_step(params, cfg, tok, cache,
                                                jnp.int32(pos + i))
        outs.append(logits)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("prompt_len", [6, 8, 12, 19])
def test_ring_matches_uniform(prompt_len):
    """f32 everywhere so cache-rounding paths are identical: the ring and the
    uniform cache must produce numerically matching decode logits."""
    cfg = _gemma_like(f32=True)
    assert cfg.window_size and cfg.global_every
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    b, n_gen = 2, 6
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, prompt_len)),
                          jnp.int32)
    max_len = prompt_len + n_gen + 2
    uni = transformer.init_cache(cfg, b, max_len, dtype=jnp.float32,
                                 windowed=False)
    two = transformer.init_cache(cfg, b, max_len, dtype=jnp.float32,
                                 windowed=True)
    assert "k_loc" in two and "k" in uni
    out_uni = _decode_seq(cfg, uni, params, prompts, n_gen)
    out_two = _decode_seq(cfg, two, params, prompts, n_gen)
    np.testing.assert_allclose(np.asarray(out_two), np.asarray(out_uni),
                               rtol=2e-4, atol=2e-4)


def test_int8_cache_close_to_bf16():
    cfg = _gemma_like()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    c16 = transformer.init_cache(cfg, 2, 16, dtype=jnp.bfloat16, windowed=True)
    c8 = transformer.init_cache(cfg, 2, 16, dtype=jnp.int8, windowed=True)
    o16 = _decode_seq(cfg, c16, params, prompts, 4)
    o8 = _decode_seq(cfg, c8, params, prompts, 4)
    # int8 cache trades a little fidelity for 2x bandwidth; logits stay close
    rel = float(jnp.abs(o8 - o16).mean() / (jnp.abs(o16).mean() + 1e-9))
    assert rel < 0.12, rel


def test_cache_memory_ratio():
    """The two-tier cache must be ~(L_loc*W + L_glob*S)/(L*S) of the uniform."""
    cfg = ARCHS["gemma3-12b"]
    b, s = 4, 32768
    uni = jax.eval_shape(lambda: transformer.init_cache(cfg, b, s,
                                                        windowed=False))
    two = jax.eval_shape(lambda: transformer.init_cache(cfg, b, s,
                                                        windowed=True))

    def nbytes(tree):
        return sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(tree))
    ratio = nbytes(two) / nbytes(uni)
    expect = (40 * 1024 + 8 * 32768) / (48 * 32768)
    assert abs(ratio - expect) < 0.02, (ratio, expect)
